//! Multi-process deployment integration.
//!
//! The handshake reject-path suite runs everywhere (no PJRT needed): it
//! drives `cluster::handshake::{admit, join, join_shard}` over real
//! loopback TCP sockets and proves that a bad token, a config-digest
//! mismatch, a duplicate worker id, a protocol-version skew and a
//! mid-handshake disconnect each close that one socket — with the right
//! `Reject` where one is owed — while the acceptor keeps admitting
//! well-behaved peers (no poisoned state). The `ecolora shard` join path
//! gets the mirrored suite: bad token, config mismatch, duplicate shard
//! id, and a shard knocking on a worker-only coordinator.
//!
//! The end-to-end suite — `ecolora serve` + spawned `ecolora worker`
//! processes over loopback, proving bitwise parity of the deterministic
//! round metrics against the in-process mem cluster, and that a worker
//! killed mid-round is absorbed by the quorum/resample machinery — needs
//! the tiny artifacts (`make artifacts`) and a `--features pjrt` build;
//! without them those tests no-op, same convention as the other
//! artifact-backed suites.

use std::cell::RefCell;
use std::collections::HashSet;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ecolora::cluster::handshake::{
    admit, join, join_shard, Admission, AuthToken, HandshakeSpec, Rejected,
};
use ecolora::cluster::protocol::{Message, RejectCode, PROTO_VERSION};
use ecolora::cluster::transport::{dial, Listener, TcpConn};
use ecolora::cluster::{self, ClusterOptions};
use ecolora::fed::{EcoConfig, FedConfig};
use ecolora::runtime::pjrt_available;

// ---- handshake harness (ungated) --------------------------------------------

const DIGEST: u64 = 0x0123_4567_89AB_CDEF;

fn spec(n_workers: usize) -> HandshakeSpec {
    HandshakeSpec {
        token: AuthToken::new("the-right-token").unwrap(),
        config_digest: DIGEST,
        n_workers,
        n_shards: 0,
    }
}

fn spec_with_shards(n_workers: usize, n_shards: usize) -> HandshakeSpec {
    HandshakeSpec { n_shards, ..spec(n_workers) }
}

/// The worker-only coordinator's shard reservation policy: no shard
/// slots exist (mirrors `serve` without `--expect-shards`).
fn no_shard_slots(_req: Option<u32>) -> Result<(u32, bool), (RejectCode, String)> {
    Err((RejectCode::ClusterFull, "this coordinator has no shard slots".into()))
}

/// Loopback listener + a poll-accept helper.
fn accept_one(listener: &Listener) -> TcpConn {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Some((conn, _peer)) = listener.try_accept().unwrap() {
            return conn;
        }
        assert!(Instant::now() < deadline, "accept timed out");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Admit with a permissive single-slot worker reservation (id 0) and no
/// shard slots.
fn admit_simple(conn: &mut TcpConn, sp: &HandshakeSpec) -> anyhow::Result<Admission> {
    admit(conn, sp, |req| Ok((req.unwrap_or(0), false)), |_| {}, no_shard_slots, |_| {}, 7)
}

/// The shard mirror of `admit_simple`: permissive shard reservation, no
/// worker slots.
fn admit_shard_simple(conn: &mut TcpConn, sp: &HandshakeSpec) -> anyhow::Result<Admission> {
    admit(
        conn,
        sp,
        |_| Err((RejectCode::ClusterFull, "no worker slots in this test".into())),
        |_| {},
        |req| Ok((req.unwrap_or(0), false)),
        |_| {},
        7,
    )
}

#[test]
fn good_join_is_welcomed_with_slot_and_round() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join(&mut conn, &token, DIGEST, Some(4)).unwrap()
    });
    let mut server_conn = accept_one(&listener);
    let sp = spec(8);
    match admit_simple(&mut server_conn, &sp).unwrap() {
        Admission::Admitted { worker, rejoin } => {
            assert_eq!(worker, 4);
            assert!(!rejoin);
        }
        other => panic!("expected admission, got {other:?}"),
    }
    let joined = client.join().unwrap();
    assert_eq!(joined.worker, 4);
    assert_eq!(joined.n_workers, 8);
    assert_eq!(joined.resume_round, 7);
}

#[test]
fn bad_token_is_rejected_without_round_state_damage() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    // attempt 1: wrong token
    let bad_addr = addr.clone();
    let bad = std::thread::spawn(move || {
        let mut conn = dial(&bad_addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-wrong-token").unwrap();
        join(&mut conn, &token, DIGEST, None).unwrap_err()
    });
    let mut server_conn = accept_one(&listener);
    let sp = spec(2);
    match admit_simple(&mut server_conn, &sp).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::BadToken),
        other => panic!("expected rejection, got {other:?}"),
    }
    drop(server_conn); // the registry drops a rejected socket
    let err = bad.join().unwrap();
    let rejected = err.downcast_ref::<Rejected>().expect("typed Rejected error");
    assert_eq!(rejected.code, RejectCode::BadToken);
    assert!(
        !format!("{err:#}").contains("the-right-token"),
        "a reject must never echo the expected secret"
    );

    // attempt 2 on the same listener: the right token still gets in
    let good = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join(&mut conn, &token, DIGEST, None).unwrap()
    });
    let mut server_conn = accept_one(&listener);
    match admit_simple(&mut server_conn, &sp).unwrap() {
        Admission::Admitted { worker, .. } => assert_eq!(worker, 0),
        other => panic!("expected admission after the earlier reject, got {other:?}"),
    }
    assert_eq!(good.join().unwrap().worker, 0);
}

#[test]
fn config_digest_mismatch_is_rejected_with_both_digests_named() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join(&mut conn, &token, DIGEST ^ 1, None).unwrap_err()
    });
    let mut server_conn = accept_one(&listener);
    match admit_simple(&mut server_conn, &spec(2)).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::ConfigMismatch),
        other => panic!("expected rejection, got {other:?}"),
    }
    let err = client.join().unwrap();
    let rejected = err.downcast_ref::<Rejected>().unwrap();
    assert_eq!(rejected.code, RejectCode::ConfigMismatch);
    // the reason carries both digests so the operator can diff flags
    assert!(rejected.reason.contains(&format!("{:016x}", DIGEST)), "{}", rejected.reason);
    assert!(rejected.reason.contains(&format!("{:016x}", DIGEST ^ 1)), "{}", rejected.reason);
}

#[test]
fn duplicate_worker_id_is_rejected_while_the_first_stays() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let connected: RefCell<HashSet<u32>> = RefCell::new(HashSet::new());
    let reserve = |req: Option<u32>| {
        let id = req.expect("test joins request explicit ids");
        if connected.borrow().contains(&id) {
            Err((RejectCode::DuplicateWorker, format!("worker id {id} is already connected")))
        } else {
            connected.borrow_mut().insert(id);
            Ok((id, false))
        }
    };
    let sp = spec(4);
    let joiner = |addr: String, expect_ok: bool| {
        std::thread::spawn(move || {
            let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
            let token = AuthToken::new("the-right-token").unwrap();
            let res = join(&mut conn, &token, DIGEST, Some(1));
            assert_eq!(res.is_ok(), expect_ok, "{res:?}");
            res.err()
        })
    };

    let first = joiner(addr.clone(), true);
    let mut c1 = accept_one(&listener);
    match admit(&mut c1, &sp, reserve, |_| {}, no_shard_slots, |_| {}, 0).unwrap() {
        Admission::Admitted { worker: 1, .. } => {}
        other => panic!("first join for slot 1 must land: {other:?}"),
    }
    first.join().unwrap();

    let second = joiner(addr, false);
    let mut c2 = accept_one(&listener);
    match admit(&mut c2, &sp, reserve, |_| {}, no_shard_slots, |_| {}, 0).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::DuplicateWorker),
        other => panic!("second join for slot 1 must be refused: {other:?}"),
    }
    let err = second.join().unwrap().unwrap();
    assert_eq!(err.downcast_ref::<Rejected>().unwrap().code, RejectCode::DuplicateWorker);
    // the first worker's slot is untouched by the duplicate attempt
    assert!(connected.borrow().contains(&1));
    assert_eq!(connected.borrow().len(), 1);
}

/// FNV-1a-32 twin of the envelope checksum (for hand-crafted frames).
fn fnv1a_parts(a: &[u8], b: &[u8]) -> u32 {
    let mut h = 0x811C_9DC5u32;
    for &x in a.iter().chain(b) {
        h ^= x as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[test]
fn protocol_version_skew_fails_at_the_framing_layer() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        // a well-formed v(N-1) Join: current bytes with the version byte
        // patched and the checksum recomputed, so ONLY the version differs
        let mut bytes = Message::Join {
            token: b"the-right-token".to_vec(),
            config_digest: DIGEST,
            requested_worker: 0,
            build: "old".into(),
        }
        .to_envelope()
        .encode();
        bytes[2] = PROTO_VERSION - 1;
        let c = fnv1a_parts(&bytes[0..4], &bytes[8..]);
        bytes[4..8].copy_from_slice(&c.to_le_bytes());
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
        stream.write_all(&bytes).unwrap();
        // the coordinator hard-closes without a Reject (it cannot trust
        // any frame from a different protocol version)
        let mut buf = [0u8; 16];
        let n = stream.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "expected a bare close, got {n} bytes");
    });
    let mut server_conn = accept_one(&listener);
    let err = admit_simple(&mut server_conn, &spec(2)).unwrap_err();
    assert!(format!("{err:#}").contains("version mismatch"), "{err:#}");
    drop(server_conn);
    client.join().unwrap();
}

#[test]
fn mid_handshake_disconnect_leaves_the_acceptor_clean() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let addr_str = addr.to_string();

    // a peer that connects, sends half a frame header, and vanishes
    {
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream.write_all(&[0x30, 0x00]).unwrap(); // 2 of 4 length bytes
    } // dropped: RST/FIN mid-handshake
    let mut half_open = accept_one(&listener);
    let err = admit_simple(&mut half_open, &spec(2)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("waiting for Join"), "{msg}");
    drop(half_open);

    // and a peer that connects and says nothing is also survivable: the
    // handshake read timeout reclaims the acceptor (rather than a hang);
    // exercised with a realistically silent socket only when the slow
    // tests are allowed — the default path covers the disconnect case.

    // the acceptor still admits a well-behaved join afterwards
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr_str, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join(&mut conn, &token, DIGEST, Some(0)).unwrap()
    });
    let mut server_conn = accept_one(&listener);
    match admit_simple(&mut server_conn, &spec(2)).unwrap() {
        Admission::Admitted { worker: 0, .. } => {}
        other => panic!("clean join after the aborted one must land: {other:?}"),
    }
    client.join().unwrap();
}

#[test]
fn non_join_first_message_is_rejected_as_malformed() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        use ecolora::cluster::transport::Conn as _;
        conn.send(&Message::Hello { worker: 0 }.to_envelope()).unwrap();
        conn.recv()
    });
    let mut server_conn = accept_one(&listener);
    match admit_simple(&mut server_conn, &spec(2)).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::Malformed),
        other => panic!("expected Malformed rejection, got {other:?}"),
    }
    let env = client.join().unwrap().unwrap();
    match Message::from_envelope(&env).unwrap() {
        Message::Reject { code, .. } => assert_eq!(code, RejectCode::Malformed),
        other => panic!("expected a Reject on the wire, got {:?}", other.kind()),
    }
}

// ---- shard-join handshake paths (ungated) -----------------------------------
//
// `ecolora shard` peers ride the same admission machinery as workers, so
// the mirrored reject suite proves the shard closure pair is actually
// consulted (and ONLY for ShardJoin first messages). Segment-slice
// overlap needs no dedicated reject: slices are derived from the shard
// id by `ShardMap`, so the duplicate-id reservation check IS the overlap
// guard.

#[test]
fn good_shard_join_is_welcomed_with_slot_and_shard_count() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join_shard(&mut conn, &token, DIGEST, Some(1)).unwrap()
    });
    let mut server_conn = accept_one(&listener);
    let sp = spec_with_shards(8, 2);
    match admit_shard_simple(&mut server_conn, &sp).unwrap() {
        Admission::AdmittedShard { shard, rejoin } => {
            assert_eq!(shard, 1);
            assert!(!rejoin);
        }
        other => panic!("expected shard admission, got {other:?}"),
    }
    let joined = client.join().unwrap();
    assert_eq!(joined.shard, 1);
    assert_eq!(
        joined.n_shards, 2,
        "a shard's Welcome must carry the SHARD count, not the worker count"
    );
    assert_eq!(joined.resume_round, 7);
}

#[test]
fn shard_join_on_a_worker_only_coordinator_is_refused_as_full() {
    // `serve` without --expect-shards keeps the aggregation plane
    // in-process; a shard knocking anyway gets a deterministic refusal
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join_shard(&mut conn, &token, DIGEST, None).unwrap_err()
    });
    let mut server_conn = accept_one(&listener);
    match admit_simple(&mut server_conn, &spec(2)).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::ClusterFull),
        other => panic!("expected rejection, got {other:?}"),
    }
    let err = client.join().unwrap();
    let rejected = err.downcast_ref::<Rejected>().expect("typed Rejected error");
    assert_eq!(rejected.code, RejectCode::ClusterFull);
    assert!(rejected.reason.contains("no shard slots"), "{}", rejected.reason);
}

#[test]
fn shard_join_with_bad_token_never_reaches_a_reservation() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-wrong-token").unwrap();
        join_shard(&mut conn, &token, DIGEST, Some(0)).unwrap_err()
    });
    let mut server_conn = accept_one(&listener);
    let sp = spec_with_shards(2, 2);
    // both reservation closures must stay untouched for an
    // unauthenticated peer, shard or worker
    let res = admit(
        &mut server_conn,
        &sp,
        |_| -> Result<(u32, bool), (RejectCode, String)> {
            panic!("worker reservation ran for an unauthenticated shard")
        },
        |_| {},
        |_| -> Result<(u32, bool), (RejectCode, String)> {
            panic!("shard reservation ran for an unauthenticated shard")
        },
        |_| {},
        0,
    );
    match res.unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::BadToken),
        other => panic!("expected rejection, got {other:?}"),
    }
    drop(server_conn);
    let err = client.join().unwrap();
    assert_eq!(err.downcast_ref::<Rejected>().unwrap().code, RejectCode::BadToken);
    assert!(
        !format!("{err:#}").contains("the-right-token"),
        "a reject must never echo the expected secret"
    );
}

#[test]
fn shard_config_digest_mismatch_names_the_shard_role() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
        let token = AuthToken::new("the-right-token").unwrap();
        join_shard(&mut conn, &token, DIGEST ^ 1, None).unwrap_err()
    });
    let mut server_conn = accept_one(&listener);
    match admit_shard_simple(&mut server_conn, &spec_with_shards(2, 2)).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::ConfigMismatch),
        other => panic!("expected rejection, got {other:?}"),
    }
    let err = client.join().unwrap();
    let rejected = err.downcast_ref::<Rejected>().unwrap();
    assert_eq!(rejected.code, RejectCode::ConfigMismatch);
    // both digests for flag-diffing, plus the role so the operator knows
    // WHICH process of the three tiers diverged
    assert!(rejected.reason.contains(&format!("{:016x}", DIGEST)), "{}", rejected.reason);
    assert!(rejected.reason.contains(&format!("{:016x}", DIGEST ^ 1)), "{}", rejected.reason);
    assert!(rejected.reason.contains("shard"), "{}", rejected.reason);
}

#[test]
fn duplicate_shard_id_is_rejected_while_the_first_stays() {
    let listener = Listener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let connected: RefCell<HashSet<u32>> = RefCell::new(HashSet::new());
    // the serve-side ledger's policy, in miniature: shard slots are
    // reserved once and NEVER reopen within a run
    let reserve_shard = |req: Option<u32>| {
        let id = req.expect("test joins request explicit ids");
        if connected.borrow().contains(&id) {
            Err((RejectCode::DuplicateWorker, format!("shard id {id} is already connected")))
        } else {
            connected.borrow_mut().insert(id);
            Ok((id, false))
        }
    };
    let sp = spec_with_shards(4, 2);
    let joiner = |addr: String, expect_ok: bool| {
        std::thread::spawn(move || {
            let mut conn = dial(&addr, Duration::from_secs(5)).unwrap();
            let token = AuthToken::new("the-right-token").unwrap();
            let res = join_shard(&mut conn, &token, DIGEST, Some(1));
            assert_eq!(res.is_ok(), expect_ok, "{res:?}");
            res.err()
        })
    };

    let no_workers =
        |_: Option<u32>| Err((RejectCode::ClusterFull, "no worker slots in this test".into()));

    let first = joiner(addr.clone(), true);
    let mut c1 = accept_one(&listener);
    match admit(&mut c1, &sp, no_workers, |_| {}, reserve_shard, |_| {}, 0).unwrap() {
        Admission::AdmittedShard { shard: 1, .. } => {}
        other => panic!("first join for shard slot 1 must land: {other:?}"),
    }
    first.join().unwrap();

    let second = joiner(addr, false);
    let mut c2 = accept_one(&listener);
    match admit(&mut c2, &sp, no_workers, |_| {}, reserve_shard, |_| {}, 0).unwrap() {
        Admission::Rejected(code) => assert_eq!(code, RejectCode::DuplicateWorker),
        other => panic!("second join for shard slot 1 must be refused: {other:?}"),
    }
    let err = second.join().unwrap().unwrap();
    assert_eq!(err.downcast_ref::<Rejected>().unwrap().code, RejectCode::DuplicateWorker);
    // the first shard's slot is untouched by the duplicate attempt
    assert!(connected.borrow().contains(&1));
    assert_eq!(connected.borrow().len(), 1);
}

// ---- multi-process end-to-end (gated on artifacts + pjrt) -------------------

fn have_artifacts() -> bool {
    pjrt_available() && Path::new("artifacts/tiny.manifest.json").exists()
}

/// Scratch dir for one e2e test run.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ecolora-deploy-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn free_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().port()
}

/// The run configuration both CLI processes and the in-process reference
/// must share (see `deploy_config_from_args`'s `--test-profile` hook).
fn e2e_cfg(rounds: usize) -> FedConfig {
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.rounds = rounds;
    cfg.eco = Some(EcoConfig::default());
    cfg
}

fn e2e_flags(rounds: usize) -> Vec<String> {
    vec![
        "--test-profile".into(),
        "tiny".into(),
        "--eco".into(),
        "--rounds".into(),
        rounds.to_string(),
    ]
}

fn spawn_logged(bin: &str, args: &[String], log: &Path) -> Child {
    let out = std::fs::File::create(log).unwrap();
    let err = out.try_clone().unwrap();
    Command::new(bin)
        .args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::from(out))
        .stderr(Stdio::from(err))
        .spawn()
        .unwrap_or_else(|e| panic!("spawning {bin}: {e}"))
}

fn wait_with_timeout(child: &mut Child, what: &str, log: &Path, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        match child.try_wait().unwrap() {
            Some(status) => {
                if !status.success() {
                    let tail = std::fs::read_to_string(log).unwrap_or_default();
                    panic!("{what} exited with {status}; log:\n{tail}");
                }
                return true;
            }
            None if Instant::now() >= deadline => {
                let _ = child.kill();
                let _ = child.wait();
                let tail = std::fs::read_to_string(log).unwrap_or_default();
                panic!("{what} did not finish within {timeout:?}; log:\n{tail}");
            }
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

/// CSV columns excluded from bitwise parity: wall-clock measurements,
/// plus the shard-link byte/latency columns — those are deterministic
/// facts about ONE deployment shape (0 for in-process shards, >0 for a
/// remote tier), so a remote-vs-in-process compare asserts them
/// separately instead.
const NONDETERMINISTIC_COLS: &[&str] = &[
    "overhead_s",
    "compute_s",
    "quorum_wait_s",
    "shard_agg_ms_max",
    "router_queue_max",
    "sched_ms",
    "journal_fsync_ms",
    "shard_tx_bytes",
    "shard_rx_bytes",
    "shard_rtt_ms_max",
];

#[test]
fn nondeterministic_cols_allowlist_stays_in_sync_with_csv_header() {
    // a renamed CSV column must not silently fall out of the parity
    // check: every allowlisted name has to exist in the emitted header
    let header: Vec<&str> = ecolora::metrics::CSV_HEADER.split(',').collect();
    for col in NONDETERMINISTIC_COLS {
        assert!(header.contains(col), "allowlisted column {col:?} is not in the CSV header");
    }
    // the robust-aggregation columns are deterministic by design and
    // must stay subject to bitwise parity
    for col in ["aggregator", "clients_trimmed", "clip_applied"] {
        assert!(header.contains(&col), "column {col:?} missing from the CSV header");
        assert!(
            !NONDETERMINISTIC_COLS.contains(&col),
            "column {col:?} is deterministic and must not be allowlisted"
        );
    }
}

/// Parse a round-log CSV into (header, rows).
fn parse_csv(csv: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = csv.lines();
    let header: Vec<String> =
        lines.next().expect("csv header").split(',').map(|s| s.to_string()).collect();
    let rows = lines
        .filter(|l| !l.is_empty())
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect();
    (header, rows)
}

fn assert_deterministic_columns_equal(want_csv: &str, got_csv: &str, what: &str) {
    let (wh, wr) = parse_csv(want_csv);
    let (gh, gr) = parse_csv(got_csv);
    assert_eq!(wh, gh, "{what}: csv headers");
    assert_eq!(wr.len(), gr.len(), "{what}: round count");
    for (round, (w, g)) in wr.iter().zip(&gr).enumerate() {
        for (ci, name) in wh.iter().enumerate() {
            if NONDETERMINISTIC_COLS.contains(&name.as_str()) {
                continue;
            }
            assert_eq!(
                w[ci], g[ci],
                "{what}: column {name} diverged at round {round} \
                 (in-process {:?} vs multi-process {:?})",
                w[ci], g[ci]
            );
        }
    }
}

#[test]
fn serve_with_two_worker_processes_matches_mem_cluster_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: `serve` + 2 spawned `worker`
    // processes over loopback TCP == the in-process mem cluster, on
    // every deterministic round metric
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let dir = scratch("parity");
    let token_path = dir.join("token");
    std::fs::write(&token_path, "e2e-parity-token\n").unwrap();
    let token = token_path.to_str().unwrap().to_string();
    let csv_path = dir.join("serve.csv");
    let addr = format!("127.0.0.1:{}", free_port());
    let rounds = 3;

    let mut serve_args = vec!["serve".to_string()];
    serve_args.extend(e2e_flags(rounds));
    serve_args.extend([
        "--listen".into(),
        addr.clone(),
        "--token-file".into(),
        token.clone(),
        "--expect-workers".into(),
        "2".into(),
        "--join-timeout-s".into(),
        "120".into(),
        "--csv".into(),
        csv_path.to_str().unwrap().into(),
    ]);
    let mut serve = spawn_logged(bin, &serve_args, &dir.join("serve.log"));

    let mut workers = Vec::new();
    for i in 0..2 {
        let mut args = vec!["worker".to_string()];
        args.extend(e2e_flags(rounds));
        args.extend([
            "--connect".into(),
            addr.clone(),
            "--token-file".into(),
            token.clone(),
            "--dial-timeout-s".into(),
            "120".into(),
        ]);
        workers.push(spawn_logged(bin, &args, &dir.join(format!("worker{i}.log"))));
    }

    wait_with_timeout(&mut serve, "serve", &dir.join("serve.log"), Duration::from_secs(300));
    for (i, mut w) in workers.into_iter().enumerate() {
        wait_with_timeout(
            &mut w,
            &format!("worker {i}"),
            &dir.join(format!("worker{i}.log")),
            Duration::from_secs(60),
        );
    }

    // in-process reference: same config, mem transport, 2 workers
    let mem = cluster::run(
        e2e_cfg(rounds),
        &ClusterOptions { workers: Some(2), ..Default::default() },
    )
    .unwrap();
    let got = std::fs::read_to_string(&csv_path).unwrap();
    assert_deterministic_columns_equal(&mem.fed.log.to_csv(), &got, "serve vs mem");
}

#[test]
fn worker_killed_mid_round_is_absorbed_by_quorum_resampling() {
    if !have_artifacts() {
        return;
    }
    // kill one of two workers once the run is underway: the coordinator
    // must finish every round anyway — dead-owner slots expire at the
    // wave timeout and resample to clients the surviving worker hosts —
    // and the outage must surface in the connection telemetry
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let dir = scratch("kill");
    let token_path = dir.join("token");
    std::fs::write(&token_path, "e2e-kill-token\n").unwrap();
    let token = token_path.to_str().unwrap().to_string();
    let csv_path = dir.join("serve.csv");
    let addr = format!("127.0.0.1:{}", free_port());
    let rounds = 4;

    let mut serve_args = vec!["serve".to_string()];
    serve_args.extend(e2e_flags(rounds));
    serve_args.extend([
        "--listen".into(),
        addr.clone(),
        "--token-file".into(),
        token.clone(),
        "--expect-workers".into(),
        "2".into(),
        "--join-timeout-s".into(),
        "120".into(),
        "--round-policy".into(),
        "quorum".into(),
        "--quorum".into(),
        "0.25".into(),
        "--slot-timeout".into(),
        "500".into(),
        "--csv".into(),
        csv_path.to_str().unwrap().into(),
    ]);
    let serve_log = dir.join("serve.log");
    let mut serve = spawn_logged(bin, &serve_args, &serve_log);

    let mut workers = Vec::new();
    for i in 0..2 {
        let mut args = vec!["worker".to_string()];
        args.extend(e2e_flags(rounds));
        args.extend([
            "--connect".into(),
            addr.clone(),
            "--token-file".into(),
            token.clone(),
            "--dial-timeout-s".into(),
            "120".into(),
        ]);
        workers.push(spawn_logged(bin, &args, &dir.join(format!("worker{i}.log"))));
    }

    // wait until the coordinator reports the full first wave, then let
    // round 0 get underway and kill the second worker process
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let log = std::fs::read_to_string(&serve_log).unwrap_or_default();
        if log.contains("all 2 workers connected") {
            break;
        }
        assert!(Instant::now() < deadline, "workers never joined; serve log:\n{log}");
        std::thread::sleep(Duration::from_millis(50));
    }
    std::thread::sleep(Duration::from_millis(300));
    let mut victim = workers.pop().unwrap();
    victim.kill().expect("killing worker 1");
    let _ = victim.wait();

    wait_with_timeout(&mut serve, "serve", &serve_log, Duration::from_secs(300));
    let mut survivor = workers.pop().unwrap();
    wait_with_timeout(&mut survivor, "worker 0", &dir.join("worker0.log"), Duration::from_secs(60));

    let (header, rows) = parse_csv(&std::fs::read_to_string(&csv_path).unwrap());
    assert_eq!(rows.len(), rounds, "every round must close despite the kill");
    let col = |name: &str| header.iter().position(|h| h == name).unwrap();
    let total = |name: &str| -> usize {
        rows.iter().map(|r| r[col(name)].parse::<usize>().unwrap()).sum()
    };
    assert!(
        total("worker_drops") >= 1,
        "the kill must surface in connection telemetry; csv:\n{header:?}\n{rows:?}"
    );
    assert!(
        total("stragglers") + total("resampled") > 0,
        "the dead worker's slots must show up as stragglers/resamples"
    );
    for r in &rows {
        let loss: f64 = r[col("loss")].parse().unwrap();
        assert!(loss.is_finite(), "round loss stays finite after the kill");
    }
}

// ---- distributed aggregation tier e2e (gated on artifacts + pjrt) -----------

fn shard_proc_args(extra: &[String], addr: &str, token: &str) -> Vec<String> {
    let mut args = vec!["shard".to_string()];
    args.extend(extra.iter().cloned());
    args.extend([
        "--connect".into(),
        addr.to_string(),
        "--token-file".into(),
        token.to_string(),
        "--dial-timeout-s".into(),
        "120".into(),
    ]);
    args
}

/// Column lookup + per-round assertions that the remote shard links
/// actually carried the round's aggregation traffic.
fn assert_shard_links_populated(csv: &str, rounds: usize) {
    let (header, rows) = parse_csv(csv);
    let col = |name: &str| {
        header.iter().position(|h| h == name).unwrap_or_else(|| panic!("missing column {name}"))
    };
    assert_eq!(rows.len(), rounds);
    for r in &rows {
        assert!(r[col("shard_tx_bytes")].parse::<u64>().unwrap() > 0, "no shard tx: {r:?}");
        assert!(r[col("shard_rx_bytes")].parse::<u64>().unwrap() > 0, "no shard rx: {r:?}");
        assert!(r[col("shard_rtt_ms_max")].parse::<f64>().unwrap() > 0.0, "no shard rtt: {r:?}");
    }
}

#[test]
fn serve_with_remote_shard_processes_matches_in_process_sharding_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the tentpole acceptance case: `serve --expect-shards 2` + 2 spawned
    // `ecolora shard` processes + 2 `ecolora worker` processes over
    // loopback TCP == the in-process mem cluster with `--shards 2`, on
    // every deterministic round metric — the aggregation tier moving out
    // of process must be invisible to the math
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let dir = scratch("shardtier");
    let token_path = dir.join("token");
    std::fs::write(&token_path, "e2e-shard-token\n").unwrap();
    let token = token_path.to_str().unwrap().to_string();
    let csv_path = dir.join("serve.csv");
    let addr = format!("127.0.0.1:{}", free_port());
    let rounds = 3;

    let mut serve_args = vec!["serve".to_string()];
    serve_args.extend(e2e_flags(rounds));
    serve_args.extend([
        "--listen".into(),
        addr.clone(),
        "--token-file".into(),
        token.clone(),
        "--expect-workers".into(),
        "2".into(),
        "--expect-shards".into(),
        "2".into(),
        "--shards".into(),
        "2".into(),
        "--join-timeout-s".into(),
        "120".into(),
        "--csv".into(),
        csv_path.to_str().unwrap().into(),
    ]);
    let serve_log = dir.join("serve.log");
    let mut serve = spawn_logged(bin, &serve_args, &serve_log);

    let mut shards = Vec::new();
    for i in 0..2 {
        let args = shard_proc_args(&e2e_flags(rounds), &addr, &token);
        shards.push(spawn_logged(bin, &args, &dir.join(format!("shard{i}.log"))));
    }
    let mut workers = Vec::new();
    for i in 0..2 {
        let mut args = vec!["worker".to_string()];
        args.extend(e2e_flags(rounds));
        args.extend([
            "--connect".into(),
            addr.clone(),
            "--token-file".into(),
            token.clone(),
            "--dial-timeout-s".into(),
            "120".into(),
        ]);
        workers.push(spawn_logged(bin, &args, &dir.join(format!("worker{i}.log"))));
    }

    wait_with_timeout(&mut serve, "serve", &serve_log, Duration::from_secs(300));
    for (i, mut w) in workers.into_iter().enumerate() {
        wait_with_timeout(
            &mut w,
            &format!("worker {i}"),
            &dir.join(format!("worker{i}.log")),
            Duration::from_secs(60),
        );
    }
    for (i, mut s) in shards.into_iter().enumerate() {
        wait_with_timeout(
            &mut s,
            &format!("shard {i}"),
            &dir.join(format!("shard{i}.log")),
            Duration::from_secs(60),
        );
    }
    let log = std::fs::read_to_string(&serve_log).unwrap_or_default();
    assert!(log.contains("all 2 shard processes connected"), "serve log:\n{log}");

    // in-process reference: same config, mem transport, same shard count
    let mem = cluster::run(
        e2e_cfg(rounds),
        &ClusterOptions { workers: Some(2), shards: 2, ..Default::default() },
    )
    .unwrap();
    let got = std::fs::read_to_string(&csv_path).unwrap();
    assert_deterministic_columns_equal(&mem.fed.log.to_csv(), &got, "remote shard tier vs mem");
    assert_shard_links_populated(&got, rounds);
}

#[test]
fn quorum_straggler_parity_between_remote_and_in_process_shard_tiers() {
    if !have_artifacts() {
        return;
    }
    // Quorum{0.75} with 4 single-client worker processes and one client
    // whose injected uplink delay exceeds the whole run: every round
    // closes at 3-of-4 with the same deterministic straggler and no late
    // fold ever lands, so the deterministic columns must match bitwise
    // between a remote shard tier and in-process shards under the SAME
    // quorum machinery. (A delay short enough to land mid-run would make
    // the fold round timing-dependent — that regime is covered for
    // robustness, not parity, by the worker-kill test above.)
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let dir = scratch("shardquorum");
    let token_path = dir.join("token");
    std::fs::write(&token_path, "e2e-shard-quorum-token\n").unwrap();
    let token = token_path.to_str().unwrap().to_string();
    let rounds = 3;
    let mut cfg_flags = e2e_flags(rounds);
    cfg_flags.extend(["--clients".into(), "4".into(), "--per-round".into(), "4".into()]);

    // reap a worker that may be asleep in the injected delay: reward the
    // prompt, kill the rest (the coordinator CSV is the assertion)
    let reap = |mut child: Child| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match child.try_wait().unwrap() {
                Some(_) => return,
                None if Instant::now() >= deadline => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return;
                }
                None => std::thread::sleep(Duration::from_millis(100)),
            }
        }
    };

    let run_one = |tag: &str, remote: bool| -> String {
        let csv_path = dir.join(format!("serve-{tag}.csv"));
        let addr = format!("127.0.0.1:{}", free_port());
        let mut serve_args = vec!["serve".to_string()];
        serve_args.extend(cfg_flags.iter().cloned());
        serve_args.extend([
            "--listen".into(),
            addr.clone(),
            "--token-file".into(),
            token.clone(),
            "--expect-workers".into(),
            "4".into(),
            "--shards".into(),
            "2".into(),
            "--join-timeout-s".into(),
            "120".into(),
            "--round-policy".into(),
            "quorum".into(),
            "--quorum".into(),
            "0.75".into(),
            "--slot-timeout".into(),
            "120000".into(),
            "--csv".into(),
            csv_path.to_str().unwrap().into(),
        ]);
        if remote {
            serve_args.extend(["--expect-shards".into(), "2".into()]);
        }
        let serve_log = dir.join(format!("serve-{tag}.log"));
        let mut serve = spawn_logged(bin, &serve_args, &serve_log);

        let mut shards = Vec::new();
        if remote {
            for i in 0..2 {
                let args = shard_proc_args(&cfg_flags, &addr, &token);
                shards.push(spawn_logged(bin, &args, &dir.join(format!("shard-{tag}{i}.log"))));
            }
        }
        let mut workers = Vec::new();
        for i in 0..4 {
            let mut args = vec!["worker".to_string()];
            args.extend(cfg_flags.iter().cloned());
            args.extend([
                "--connect".into(),
                addr.clone(),
                "--token-file".into(),
                token.clone(),
                "--dial-timeout-s".into(),
                "120".into(),
                "--inject-slow".into(),
                "0".into(),
                "--inject-delay-ms".into(),
                "300000".into(),
            ]);
            workers.push(spawn_logged(bin, &args, &dir.join(format!("worker-{tag}{i}.log"))));
        }

        wait_with_timeout(&mut serve, "serve", &serve_log, Duration::from_secs(300));
        for w in workers {
            reap(w);
        }
        for (i, mut s) in shards.into_iter().enumerate() {
            wait_with_timeout(
                &mut s,
                &format!("shard {i} ({tag})"),
                &dir.join(format!("shard-{tag}{i}.log")),
                Duration::from_secs(60),
            );
        }
        std::fs::read_to_string(&csv_path).unwrap()
    };

    let inproc = run_one("inproc", false);
    let remote = run_one("remote", true);
    assert_deterministic_columns_equal(&inproc, &remote, "quorum: remote vs in-process shards");
    assert_shard_links_populated(&remote, rounds);

    // the straggler machinery must actually have engaged, identically
    let (header, rows) = parse_csv(&remote);
    let col = |name: &str| header.iter().position(|h| h == name).unwrap();
    let stragglers: usize =
        rows.iter().map(|r| r[col("stragglers")].parse::<usize>().unwrap()).sum();
    assert!(stragglers >= 1, "the slow client must strand at least once: {rows:?}");
}
