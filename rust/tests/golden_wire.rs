//! Golden wire vectors: byte-exact fixtures captured from the
//! PRE-REFACTOR (byte-at-a-time) encoder, pinning the frozen wire format
//! across codec rewrites. If any of these fail, the wire format changed
//! — that is a protocol break, not a test to update. (Generated once
//! with an independent reimplementation of the historical encoder and
//! verified bit-by-bit by hand; see the word-vs-byte equivalence
//! propcheck in `util::bitstream` for the exhaustive randomized check.)
//!
//! Ungated: runs everywhere, no artifacts needed.

use ecolora::compress::{golomb, wire, Encoding, KindIndex, SparseVec};
use ecolora::model::LoraKind;

fn unhex(s: &str) -> Vec<u8> {
    assert!(s.len() % 2 == 0);
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).unwrap())
        .collect()
}

fn kinds_16_interleaved(n: usize) -> Vec<LoraKind> {
    (0..n)
        .map(|i| if (i / 16) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect()
}

/// The shared fixture update: ascending indices over a 64-param vector
/// with alternating 16-wide A/B blocks, all values exactly f16.
fn fixture_sv() -> SparseVec {
    SparseVec {
        idx: vec![1, 5, 14, 16, 18, 30, 33, 47, 50, 63],
        vals: vec![1.0, -2.0, 0.5, 0.25, -0.75, 3.0, -1.5, 8.0, -0.125, 2.5],
    }
}

#[test]
fn golden_rice_params() {
    // pinned Golomb parameters for the fixture densities
    assert_eq!(golomb::rice_param_for_density(0.5), 0);
    assert_eq!(golomb::rice_param_for_density(0.3), 1);
    assert_eq!(golomb::rice_param_for_density(0.2), 2);
    assert_eq!(golomb::rice_param_for_density(0.1), 3);
}

#[test]
fn golden_golomb_streams() {
    let idx: Vec<u32> = vec![0, 3, 4, 11, 12, 13, 40, 41, 96, 255];
    let cases = [
        (0u32, 256u64, "67e3ffffff3fffffffffffff7ffffffffffffffffffffffffffffffffffffffe"),
        (1, 143, "21c0fff87ffffff3fffffffffffffffffff8"),
        (2, 89, "08501fa1fff5fffffffffd00"),
        (4, 63, "00806002a0737fdc"),
    ];
    for (b, bits, hex) in cases {
        let w = golomb::encode_indices(&idx, b);
        assert_eq!(w.bit_len(), bits, "b={b} bit length");
        let bytes = w.into_bytes();
        assert_eq!(bytes, unhex(hex), "b={b} stream bytes");
        // and the word-at-a-time decoder reads the historical bytes back
        let mut decoded = Vec::new();
        let consumed = golomb::decode_indices_into(&bytes, idx.len(), b, &mut decoded).unwrap();
        assert_eq!(decoded, idx, "b={b} decode");
        assert_eq!(consumed, bits, "b={b} bits consumed");
    }
}

#[test]
fn golden_wire_message_full_range() {
    let kinds = kinds_16_interleaved(64);
    let kidx = KindIndex::new(&kinds);
    let sv = fixture_sv();
    let golden = unhex(
        "010002000105000000030000006f93f4003c00c0003800be00480102050000000300\
         0000076f80003400ba004200b00041",
    );
    let enc = wire::encode(&sv, &(0..64), &kidx, (0.3, 0.2), Encoding::Golomb).unwrap();
    assert_eq!(enc, golden, "allocating encoder diverges from golden bytes");

    let mut scratch = wire::EncodeScratch::new();
    let mut out = Vec::new();
    wire::encode_into(&sv, &(0..64), &kidx, (0.3, 0.2), Encoding::Golomb, &mut scratch, &mut out)
        .unwrap();
    assert_eq!(out, golden, "scratch encoder diverges from golden bytes");

    assert_eq!(wire::decode(&golden, &(0..64), &kidx).unwrap(), sv);
    let mut dec = wire::Decoder::new();
    let mut dsv = SparseVec::default();
    dec.decode_into(&golden, &(0..64), &kidx, &mut dsv).unwrap();
    assert_eq!(dsv, sv);
}

#[test]
fn golden_wire_message_segment_range() {
    let kinds = kinds_16_interleaved(64);
    let kidx = KindIndex::new(&kinds);
    let sv = fixture_sv();
    let range = 10..50;
    let golden = unhex(
        "01000200000300000003000000f6fff8003800be0048010303000000020000000198\
         003400ba0042",
    );
    // sv spans beyond the range on both sides: the encoder must window
    let enc = wire::encode(&sv, &range, &kidx, (0.5, 0.1), Encoding::Golomb).unwrap();
    assert_eq!(enc, golden, "segment encoder diverges from golden bytes");
    assert_eq!(wire::decode(&golden, &range, &kidx).unwrap(), sv.restrict(&range));
}

#[test]
fn golden_wire_message_fixed_encoding() {
    let kinds = kinds_16_interleaved(64);
    let kidx = KindIndex::new(&kinds);
    let sv = fixture_sv();
    let golden = unhex(
        "0101020001050000001400000000000001000000050000000e000000110000001f00\
         3c00c0003800be00480102050000001400000000000000000000020000000e000000\
         120000001f003400ba004200b00041",
    );
    let enc = wire::encode(&sv, &(0..64), &kidx, (0.3, 0.2), Encoding::Fixed).unwrap();
    assert_eq!(enc, golden, "fixed-encoding diverges from golden bytes");
    assert_eq!(wire::decode(&golden, &(0..64), &kidx).unwrap(), sv);
}
