//! Integration: cross-module compression invariants at realistic scale —
//! the full EcoLoRA pipeline (adaptive top-k → residual → f16 → segment →
//! Golomb wire → decode → aggregate) against a straight-line reference.

use std::sync::Arc;

use ecolora::compress::{
    wire, AdaptiveSparsifier, Compressor, Encoding, KindIndex, SparsMode,
};
use ecolora::fed::round_robin;
use ecolora::fed::server::SegmentAggregator;
use ecolora::model::{segment_ranges, LoraKind};
use ecolora::util::propcheck::propcheck;
use ecolora::util::rng::Rng;

fn layout(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>) {
    // real layouts alternate A/B tensor blocks
    let kinds: Vec<LoraKind> = (0..n)
        .map(|i| if (i / 64) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect();
    let kidx = KindIndex::new(&kinds);
    (Arc::new(kinds), Arc::new(kidx))
}

#[test]
fn pipeline_transmits_every_coordinate_eventually() {
    // Error feedback across RR segments: over enough rounds every
    // coordinate must be updated at the server.
    let n = 4096;
    let n_s = 4;
    let n_clients = 4;
    let (kinds, kidx) = layout(n);
    let mut comps: Vec<Compressor> = (0..n_clients)
        .map(|_| {
            Compressor::new(
                SparsMode::Adaptive(AdaptiveSparsifier::default()),
                Encoding::Golomb,
                kinds.clone(),
                kidx.clone(),
            )
        })
        .collect();
    let mut rng = Rng::new(0);
    let mut touched = vec![false; n];
    for t in 0..3 * n_s {
        let mut agg = SegmentAggregator::new(n, n_s);
        for (slot, comp) in comps.iter_mut().enumerate() {
            let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            let out = comp.compress(&update, 3.0, 2.0);
            let seg = round_robin::segment_for(slot, t, n_s);
            let range = agg.range(seg).clone();
            let sv = out.sv.restrict(&range);
            let bytes = wire::encode(&sv, &range, &kidx, out.k, Encoding::Golomb).unwrap();
            let dec = wire::decode(&bytes, &range, &kidx).unwrap();
            for &i in &dec.idx {
                touched[i as usize] = true;
            }
            agg.add_sparse(seg, &dec, 1.0);
        }
        assert!(agg.covered().iter().all(|&c| c), "round {t} left a segment empty");
        let _ = agg.finish();
    }
    let covered = touched.iter().filter(|&&t| t).count();
    assert!(covered as f64 > 0.999 * n as f64, "covered {covered}/{n}");
}

#[test]
fn segment_restriction_never_leaks_across_boundaries() {
    propcheck(100, |rng| {
        let n = 512 + rng.below(2048);
        let n_s = 1 + rng.below(6);
        let (kinds, kidx) = layout(n);
        let mut comp = Compressor::new(
            SparsMode::Fixed(0.3),
            Encoding::Golomb,
            kinds,
            kidx.clone(),
        );
        let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let out = comp.compress(&update, 1.0, 1.0);
        for range in segment_ranges(n, n_s) {
            let sv = out.sv.restrict(&range);
            let bytes = wire::encode(&sv, &range, &kidx, out.k, Encoding::Golomb).unwrap();
            let dec = wire::decode(&bytes, &range, &kidx).unwrap();
            assert_eq!(dec, sv);
            for &i in &dec.idx {
                assert!((i as usize) >= range.start && (i as usize) < range.end);
            }
        }
    });
}

#[test]
fn quantization_error_never_compounds_beyond_f16_ulp_per_transmit() {
    // With keep-all sparsification, receiver-side accumulation tracks the
    // true sum within f16 relative error per round (error feedback).
    let n = 256;
    let (kinds, kidx) = layout(n);
    let mut comp = Compressor::new(SparsMode::Off, Encoding::Golomb, kinds, kidx);
    let mut rng = Rng::new(5);
    let mut true_sum = vec![0.0f64; n];
    let mut recv_sum = vec![0.0f64; n];
    for _ in 0..50 {
        let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
        for (s, u) in true_sum.iter_mut().zip(&update) {
            *s += *u as f64;
        }
        let out = comp.compress(&update, 1.0, 1.0);
        for (&i, &v) in out.sv.idx.iter().zip(&out.sv.vals) {
            recv_sum[i as usize] += v as f64;
        }
    }
    for i in 0..n {
        let err = (true_sum[i] - recv_sum[i]).abs();
        // residual keeps the outstanding error bounded by ~one f16 ulp of
        // the typical magnitude, NOT 50 accumulated ulps
        assert!(err < 2e-3, "coord {i}: err {err}");
    }
}

#[test]
fn adaptive_beats_fixed_at_matched_budget_on_heavy_tailed_updates() {
    // The mechanism behind Table 5: with B-heavy concentration, adaptive
    // (smaller k_B, larger k_A) captures more update mass than uniform k at
    // the same kept-parameter budget.
    let n = 8192;
    let (kinds, kidx) = layout(n);
    let mut rng = Rng::new(9);
    // B entries spiky-sparse, A entries dense-small (the Fig. 2 pattern)
    let update: Vec<f32> = (0..n)
        .map(|i| {
            if kinds[i] == LoraKind::B {
                if rng.below(10) == 0 { rng.normal() as f32 * 3.0 } else { 0.01 * rng.normal() as f32 }
            } else {
                0.3 * rng.normal() as f32
            }
        })
        .collect();

    let captured = |mode: SparsMode| -> (usize, f64) {
        let mut comp = Compressor::new(mode, Encoding::Golomb, kinds.clone(), kidx.clone());
        let out = comp.compress(&update, 3.0, -100.0); // fully decayed schedule
        let mass: f64 = out.sv.vals.iter().map(|v| v.abs() as f64).sum();
        (out.sv.len(), mass)
    };

    let (n_adaptive, mass_adaptive) =
        captured(SparsMode::Adaptive(AdaptiveSparsifier::with_k_mins(0.6, 0.25)));
    // matched budget: uniform k with the same total kept count
    let k_uniform = n_adaptive as f64 / n as f64;
    let (n_fixed, mass_fixed) = captured(SparsMode::Fixed(k_uniform));
    assert!((n_adaptive as i64 - n_fixed as i64).abs() < (n / 50) as i64);
    assert!(
        mass_adaptive > mass_fixed * 0.98,
        "adaptive {mass_adaptive:.1} vs fixed {mass_fixed:.1}"
    );
}
