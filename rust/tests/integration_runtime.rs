//! Integration: PJRT runtime + Session against the real AOT artifacts
//! (requires `make artifacts`). Verifies the python→HLO→rust bridge
//! end-to-end: shapes, training descent, mask semantics, merge identity,
//! DPO margin growth.

use std::path::Path;

use ecolora::fed::session::Session;
use ecolora::model::Schema;
use ecolora::util::rng::Rng;

fn artifacts() -> &'static Path {
    Path::new("artifacts")
}

fn have_artifacts() -> bool {
    ecolora::runtime::pjrt_available() && artifacts().join("tiny.manifest.json").exists()
}

fn session() -> Session {
    let mut rng = Rng::new(7);
    Session::new(artifacts(), "tiny", &mut rng).expect("session")
}

fn batch(schema: &Schema, rng: &mut Rng) -> Vec<i32> {
    let b = schema.config.batch;
    let seq = schema.config.seq_len + 1;
    (0..b * seq)
        .map(|_| 1 + rng.below(schema.config.vocab - 1) as i32)
        .collect()
}

#[test]
fn schema_loads_and_validates_for_all_built_presets() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for preset in ["tiny", "small", "small_va", "medium"] {
        if artifacts().join(format!("{preset}.manifest.json")).exists() {
            let s = Schema::load(artifacts(), preset).expect(preset);
            assert!(s.lora_total > 0 && s.base_total > s.lora_total);
            assert!(s.artifacts.contains_key("train"));
            assert!(s.artifacts.contains_key("eval"));
        }
    }
}

#[test]
fn train_step_roundtrip_and_descent() {
    if !have_artifacts() {
        return;
    }
    let sess = session();
    let mut rng = Rng::new(1);
    let lora = sess.schema.init_lora(&mut rng);
    let mask = sess.upload_mask(&sess.schema.mask_all()).unwrap();
    let tokens = batch(&sess.schema, &mut rng);

    let (l1, first_loss) = sess.train_step(&lora, &tokens, 2.0, &mask).unwrap();
    assert_eq!(l1.len(), sess.schema.lora_total);
    assert!(first_loss.is_finite() && first_loss > 0.0);

    // Repeated steps on the same batch must reduce the loss. (LoRA starts
    // with B = 0, so dL/dA = 0 at step one and SGD descent ramps slowly —
    // hence the generous step budget.)
    let mut cur = l1;
    let mut last = first_loss;
    for _ in 0..25 {
        let (next, loss) = sess.train_step(&cur, &tokens, 2.0, &mask).unwrap();
        cur = next;
        last = loss;
    }
    assert!(
        last < first_loss - 0.01,
        "loss did not descend: {first_loss} -> {last}"
    );
}

#[test]
fn ffa_mask_freezes_a_entries() {
    if !have_artifacts() {
        return;
    }
    let sess = session();
    let mut rng = Rng::new(2);
    let lora = sess.schema.init_lora(&mut rng);
    let mask_b = sess.upload_mask(&sess.schema.mask_b_only()).unwrap();
    let tokens = batch(&sess.schema, &mut rng);
    let (new_lora, _) = sess.train_step(&lora, &tokens, 0.5, &mask_b).unwrap();
    for t in &sess.schema.lora_tensors {
        let before = &lora[t.offset..t.offset + t.size];
        let after = &new_lora[t.offset..t.offset + t.size];
        match t.kind {
            Some(ecolora::model::LoraKind::A) => assert_eq!(before, after, "{} moved", t.name),
            _ => {}
        }
    }
    // and B did move
    let moved = sess
        .schema
        .lora_tensors
        .iter()
        .filter(|t| t.kind == Some(ecolora::model::LoraKind::B))
        .any(|t| lora[t.offset..t.offset + t.size] != new_lora[t.offset..t.offset + t.size]);
    assert!(moved, "B entries should train");
}

#[test]
fn eval_rows_shape_and_finiteness() {
    if !have_artifacts() {
        return;
    }
    let sess = session();
    let mut rng = Rng::new(3);
    let lora = sess.schema.init_lora(&mut rng);
    let be = sess.schema.config.eval_batch;
    let seq = sess.schema.config.seq_len + 1;
    let tokens: Vec<i32> = (0..be * seq)
        .map(|_| 1 + rng.below(sess.schema.config.vocab - 1) as i32)
        .collect();
    let rows = sess.eval_rows(&lora, &tokens).unwrap();
    assert_eq!(rows.len(), be);
    assert!(rows.iter().all(|x| x.is_finite() && *x > 0.0));
}

#[test]
fn zero_lr_is_identity() {
    if !have_artifacts() {
        return;
    }
    let sess = session();
    let mut rng = Rng::new(4);
    let lora = sess.schema.init_lora(&mut rng);
    let mask = sess.upload_mask(&sess.schema.mask_all()).unwrap();
    let tokens = batch(&sess.schema, &mut rng);
    let (new_lora, _) = sess.train_step(&lora, &tokens, 0.0, &mask).unwrap();
    assert_eq!(lora, new_lora);
}

#[test]
fn merge_scale_zero_keeps_base() {
    if !have_artifacts() {
        return;
    }
    let mut sess = session();
    let mut rng = Rng::new(5);
    let lora = sess.schema.init_lora(&mut rng);
    let before = sess.base_host().to_vec();
    sess.merge_lora(&lora, 0.0).unwrap();
    assert_eq!(before, sess.base_host());
}

#[test]
fn merge_matches_adapter_semantics_through_eval() {
    if !have_artifacts() {
        return;
    }
    let mut sess = session();
    let mut rng = Rng::new(6);
    // make a LoRA with nonzero B so the adapter acts
    let mut lora = sess.schema.init_lora(&mut rng);
    for v in lora.iter_mut() {
        if *v == 0.0 {
            *v = 0.03 * rng.normal() as f32;
        }
    }
    let be = sess.schema.config.eval_batch;
    let seq = sess.schema.config.seq_len + 1;
    let tokens: Vec<i32> = (0..be * seq)
        .map(|_| 1 + rng.below(sess.schema.config.vocab - 1) as i32)
        .collect();
    let with_adapter = sess.eval_rows(&lora, &tokens).unwrap();
    sess.merge_lora(&lora, 1.0).unwrap();
    let zeros = vec![0.0f32; sess.schema.lora_total];
    let with_merged = sess.eval_rows(&zeros, &tokens).unwrap();
    for (a, b) in with_adapter.iter().zip(&with_merged) {
        assert!((a - b).abs() < 2e-2, "{a} vs {b}");
    }
}

#[test]
fn pretrain_descends_and_persists() {
    if !have_artifacts() {
        return;
    }
    let mut sess = session();
    let mut rng = Rng::new(8);
    let tokens = batch(&sess.schema, &mut rng);
    let first = sess.pretrain_step(&tokens, 0.5).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = sess.pretrain_step(&tokens, 0.5).unwrap();
    }
    assert!(last < first, "pretrain loss {first} -> {last}");

    // checkpoint roundtrip
    let tmp = std::env::temp_dir().join("ecolora_test_base.bin");
    sess.save_base(&tmp).unwrap();
    let before = sess.base_host().to_vec();
    let mut sess2 = session();
    sess2.load_base(&tmp).unwrap();
    assert_eq!(before, sess2.base_host());
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn dpo_step_runs_and_margin_grows() {
    if !have_artifacts() {
        return;
    }
    let sess = session();
    let mut rng = Rng::new(9);
    let mut lora = sess.schema.init_lora(&mut rng);
    let mask = sess.upload_mask(&sess.schema.mask_all()).unwrap();
    let b = sess.schema.config.batch;
    let seq = sess.schema.config.seq_len + 1;
    let chosen: Vec<i32> =
        (0..b * seq).map(|_| 1 + rng.below(sess.schema.config.vocab - 1) as i32).collect();
    let rejected: Vec<i32> =
        (0..b * seq).map(|_| 1 + rng.below(sess.schema.config.vocab - 1) as i32).collect();

    let (_, loss0, m0) = sess.dpo_step(&lora, &chosen, &rejected, 0.0, 0.5, &mask).unwrap();
    assert!(loss0.is_finite());
    let mut margin = m0;
    let mut loss = loss0;
    for _ in 0..10 {
        let (next, l, m) = sess.dpo_step(&lora, &chosen, &rejected, 0.5, 0.5, &mask).unwrap();
        lora = next;
        margin = m;
        loss = l;
    }
    assert!(margin > m0, "margin {m0} -> {margin}");
    assert!(loss < loss0, "dpo loss {loss0} -> {loss}");
}
