//! Byzantine-robustness integration suite (PR: robust aggregation plane).
//!
//! Everything here runs on the artifact-free `synthetic` preset over the
//! mux client plane, so no PJRT or `make artifacts` is needed. The cheap
//! properties — injection determinism, degenerate-config ≡ mean,
//! counter/label plumbing — run unconditionally; the full attack matrix
//! ({sign-flip, scale, noise} × {mean, trimmed-mean, median} ×
//! {Sync, Quorum{0.75}}, each run twice for bitwise determinism) and the
//! robustness acceptance criterion are heavier and gate on
//! `ECOLORA_ROBUST_TESTS=1`, same convention as the scale smoke in
//! integration_cluster (CI's robustness-smoke job sets the variable).

use std::time::Duration;

use ecolora::cluster::{
    self, Attack, ClusterMode, ClusterOptions, FaultSpec, MaliciousSpec, RoundPolicy, SlowSpec,
};
use ecolora::fed::robust::Aggregator;
use ecolora::fed::{FedConfig, FedOutcome};

fn robust_tests_enabled() -> bool {
    std::env::var("ECOLORA_ROBUST_TESTS").map_or(false, |v| v == "1")
}

/// Synthetic population where every client is active each round
/// (rotor sampling with n == N_t) and the default 5 segments each
/// receive 40/5 = 8 contributions — enough for trimming to engage:
/// beta = 0.3 gives t = min(floor(0.3·8), 3) = 2 per extreme.
fn cfg40(aggregator: Aggregator) -> FedConfig {
    let mut cfg = FedConfig::synthetic_profile(40);
    cfg.aggregator = aggregator;
    cfg
}

const TRIM: Aggregator = Aggregator::TrimmedMean { beta: 0.3 };
/// 2·t per segment × 5 segments (see [`cfg40`]) — the exact
/// `clients_trimmed` value every Sync round must report under [`TRIM`].
const TRIMMED_PER_SYNC_ROUND: u64 = 2 * 2 * 5;

fn sync_opts(fault: Option<FaultSpec>) -> ClusterOptions {
    ClusterOptions { mode: ClusterMode::Mem, workers: Some(4), fault, ..Default::default() }
}

fn run(cfg: FedConfig, opts: &ClusterOptions) -> FedOutcome {
    cluster::run(cfg, opts).unwrap().fed
}

fn assert_bitwise(a: &FedOutcome, b: &FedOutcome, what: &str) {
    assert_eq!(a.final_lora.len(), b.final_lora.len(), "{what}: lora length");
    for (i, (x, y)) in a.final_lora.iter().zip(&b.final_lora).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_lora[{i}] {x} vs {y}");
    }
    assert_eq!(a.log.rounds.len(), b.log.rounds.len(), "{what}: round count");
    for (ra, rb) in a.log.rounds.iter().zip(&b.log.rounds) {
        assert_eq!(ra.global_loss.to_bits(), rb.global_loss.to_bits(), "{what}: loss r{}", ra.round);
        assert_eq!(ra.clients_trimmed, rb.clients_trimmed, "{what}: trimmed r{}", ra.round);
        assert_eq!(ra.clip_applied, rb.clip_applied, "{what}: clipped r{}", ra.round);
    }
}

/// Relative L2 distance ‖a − b‖ / ‖b‖.
fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut d, mut n) = (0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        d += (x as f64 - y as f64).powi(2);
        n += (y as f64).powi(2);
    }
    (d / n.max(1e-30)).sqrt()
}

// ---- ungated: injection machinery ------------------------------------------

#[test]
fn attack_parse_roundtrips_and_rejects_garbage() {
    assert_eq!(Attack::parse("sign-flip").unwrap(), Attack::SignFlip);
    assert_eq!(Attack::parse("scale:-8").unwrap(), Attack::Scale(-8.0));
    assert_eq!(Attack::parse("noise:1.5").unwrap(), Attack::Noise(1.5));
    for spec in ["sign-flip", "scale:-8", "noise:1.5"] {
        assert_eq!(Attack::parse(spec).unwrap().name(), spec);
    }
    assert!(Attack::parse("scale").is_err(), "scale requires a factor");
    assert!(Attack::parse("scale:inf").is_err());
    assert!(Attack::parse("noise:-1").is_err());
    assert!(Attack::parse("dropout").is_err());
}

#[test]
fn malicious_mask_is_deterministic_and_seed_dependent() {
    let spec = MaliciousSpec { n: 7, attack: Attack::SignFlip };
    let a = spec.mask(42, 100);
    let b = spec.mask(42, 100);
    let c = spec.mask(43, 100);
    assert_eq!(a, b, "same seed, same cohort");
    assert_eq!(a.iter().filter(|&&m| m).count(), 7);
    assert_eq!(c.iter().filter(|&&m| m).count(), 7);
    assert_ne!(a, c, "the cohort must move with the seed");
    // more attackers than clients: everyone is malicious, no panic
    let all = MaliciousSpec { n: 10, attack: Attack::SignFlip }.mask(1, 4);
    assert_eq!(all, vec![true; 4]);
}

#[test]
fn identity_attack_is_bitwise_invisible() {
    // scale:1 multiplies every update coordinate by 1.0 — a bitwise
    // no-op — so a run with the full injection machinery engaged must
    // reproduce the fault-free run exactly. This pins the ISSUE
    // requirement that the malicious cohort comes from a DEDICATED rng
    // stream: if injection perturbed honest client sampling, scheduling,
    // or the wire path in any way, these bits would diverge.
    let clean = run(cfg40(Aggregator::Mean), &sync_opts(None));
    let inert = run(
        cfg40(Aggregator::Mean),
        &sync_opts(Some(FaultSpec::malicious(3, Attack::Scale(1.0)))),
    );
    assert_bitwise(&clean, &inert, "identity attack");
}

#[test]
fn attacked_run_is_bitwise_deterministic() {
    let mk = || {
        (
            cfg40(TRIM),
            sync_opts(Some(FaultSpec::malicious(2, Attack::SignFlip))),
        )
    };
    let (cfg_a, opts_a) = mk();
    let (cfg_b, opts_b) = mk();
    let a = run(cfg_a, &opts_a);
    let b = run(cfg_b, &opts_b);
    assert_bitwise(&a, &b, "sign-flip run-twice");
}

// ---- ungated: plumbing from shard stats to the round log -------------------

#[test]
fn robust_labels_and_counters_reach_the_round_log() {
    // mean / median never trim or clip; trimmed-mean:0.3 over 8
    // contributions per segment trims exactly 2 per extreme in all 5
    // segments; a vanishing clip threshold rescales every uplink.
    let cases: &[(Aggregator, u64, bool)] = &[
        (Aggregator::Mean, 0, false),
        (Aggregator::Median, 0, false),
        (TRIM, TRIMMED_PER_SYNC_ROUND, false),
        (Aggregator::NormClip { c: 1e-6 }, 0, true),
    ];
    for &(kind, want_trimmed, want_clipped) in cases {
        let out = run(cfg40(kind), &sync_opts(None));
        assert_eq!(out.log.rounds.len(), 2);
        for r in &out.log.rounds {
            assert_eq!(r.aggregator, kind.name(), "round {} label", r.round);
            assert_eq!(r.clients_trimmed, want_trimmed, "{} r{}", kind.name(), r.round);
            if want_clipped {
                assert!(
                    r.clip_applied > 0 && r.clip_applied <= 40,
                    "{} r{}: clip_applied = {}",
                    kind.name(),
                    r.round,
                    r.clip_applied
                );
            } else {
                assert_eq!(r.clip_applied, 0, "{} r{}", kind.name(), r.round);
            }
            assert!(r.global_loss.is_finite(), "{} r{}", kind.name(), r.round);
        }
    }
}

#[test]
fn degenerate_robust_configs_match_mean_bitwise_end_to_end() {
    // the satellite property at full-run scope: trimmed-mean{beta=0}
    // and norm-clip{c=inf} must reproduce the Eq. 2 mean BIT FOR BIT
    // through the whole cluster stack (mux plane, wire codecs, shard
    // fold), not just at the aggregator unit boundary.
    let mean = run(cfg40(Aggregator::Mean), &sync_opts(None));
    for kind in [Aggregator::TrimmedMean { beta: 0.0 }, Aggregator::NormClip { c: f64::INFINITY }]
    {
        let got = run(cfg40(kind), &sync_opts(None));
        assert_bitwise(&mean, &got, &kind.name());
        for r in &got.log.rounds {
            assert_eq!(r.aggregator, kind.name(), "label still reports the configured kind");
        }
    }
}

// ---- gated matrix + acceptance criterion (ECOLORA_ROBUST_TESTS=1) ----------

/// Quorum arm of the matrix: the deterministic-straggler construction
/// from integration_cluster — every client active (n == N_t == 4),
/// q = 0.75 closes at exactly the 3 fast clients, and the injected slow
/// client is the one deterministic straggler whose uplink folds into the
/// next round through the (robust) late-buffer path.
fn quorum_cfg(aggregator: Aggregator) -> FedConfig {
    let mut cfg = FedConfig::synthetic_profile(4);
    cfg.aggregator = aggregator;
    cfg
}

fn quorum_fault(attack: Attack) -> FaultSpec {
    FaultSpec {
        slow: Some(SlowSpec { client: 1, delay: Duration::from_millis(1_200) }),
        malicious: Some(MaliciousSpec { n: 2, attack }),
    }
}

fn quorum_opts(fault: FaultSpec) -> ClusterOptions {
    ClusterOptions {
        policy: RoundPolicy::Quorum { q: 0.75, timeout: Duration::from_millis(600_000) },
        ..sync_opts(Some(fault))
    }
}

#[test]
fn attack_matrix_completes_and_is_run_twice_deterministic() {
    if !robust_tests_enabled() {
        return;
    }
    let attacks = [Attack::SignFlip, Attack::Scale(-8.0), Attack::Noise(0.5)];
    let aggregators = [Aggregator::Mean, TRIM, Aggregator::Median];
    for attack in attacks {
        for kind in aggregators {
            for sync in [true, false] {
                let what = format!(
                    "{} × {} × {}",
                    attack.name(),
                    kind.name(),
                    if sync { "sync" } else { "quorum:0.75" }
                );
                let once = || {
                    if sync {
                        run(cfg40(kind), &sync_opts(Some(FaultSpec::malicious(2, attack))))
                    } else {
                        run(quorum_cfg(kind), &quorum_opts(quorum_fault(attack)))
                    }
                };
                let a = once();
                let b = once();
                assert_bitwise(&a, &b, &what);
                assert_eq!(a.log.rounds.len(), 2, "{what}");
                for r in &a.log.rounds {
                    assert_eq!(r.aggregator, kind.name(), "{what} r{}", r.round);
                    assert!(r.global_loss.is_finite(), "{what} r{}", r.round);
                    assert_eq!(r.clip_applied, 0, "{what} r{}: nothing clips here", r.round);
                    // trimming engages only where segments see ≥ 4
                    // contributions: the 40-client Sync arm. The cohort-4
                    // quorum arm has t = 0 everywhere (m ≤ 2 per segment).
                    let want_trimmed =
                        if kind == TRIM && sync { TRIMMED_PER_SYNC_ROUND } else { 0 };
                    assert_eq!(r.clients_trimmed, want_trimmed, "{what} r{}", r.round);
                }
                if !sync {
                    assert_eq!(a.log.rounds[0].stragglers, 1, "{what}: slow client left behind");
                    assert_eq!(a.log.rounds[1].late_folds, 1, "{what}: and folded late");
                }
                assert!(
                    a.final_lora.iter().all(|v| v.is_finite()),
                    "{what}: attacked global must stay finite"
                );
            }
        }
    }
}

#[test]
fn robust_aggregators_absorb_minority_attack_while_mean_degrades() {
    if !robust_tests_enabled() {
        return;
    }
    // The acceptance criterion. 2 malicious clients rebroadcast their
    // update scaled by −25; with beta = 0.3 the per-segment trim budget
    // is t = 2 per extreme, so even both attackers landing in one
    // segment stay under it. The robust runs must stay near their
    // attack-free twins while the unprotected mean is dragged far off
    // its own.
    let attack = FaultSpec::malicious(2, Attack::Scale(-25.0));
    let errs: Vec<(String, f64)> = [Aggregator::Mean, TRIM, Aggregator::Median]
        .into_iter()
        .map(|kind| {
            let clean = run(cfg40(kind), &sync_opts(None));
            let attacked = run(cfg40(kind), &sync_opts(Some(attack)));
            (kind.name(), rel_l2(&attacked.final_lora, &clean.final_lora))
        })
        .collect();
    let (mean_err, trim_err, median_err) = (errs[0].1, errs[1].1, errs[2].1);
    assert!(
        mean_err > 0.05,
        "the attack must visibly move the unprotected mean: rel err {mean_err:.4}"
    );
    for (name, err) in &errs[1..] {
        assert!(err.is_finite(), "{name}: rel err {err}");
        assert!(
            *err < 0.25 * mean_err,
            "{name} must absorb what mean cannot: rel err {err:.4} vs mean {mean_err:.4}"
        );
    }
    assert!(
        trim_err < 0.5 && median_err < 0.5,
        "robust runs stay within tolerance of attack-free: trim {trim_err:.4}, median {median_err:.4}"
    );
}
