//! Coordinator crash-recovery integration (journal + `serve --resume`).
//!
//! The headline durability claim of the round journal: a coordinator
//! SIGKILLed mid-round and relaunched with `--resume` produces a round
//! log **bitwise identical** on every deterministic CSV column to an
//! uninterrupted run of the same configuration. The suite proves it
//! end-to-end with real processes:
//!
//!  1. baseline: `serve --journal` + 2 `worker` processes, run to
//!     completion (the baseline journals too — `journal_bytes` is a
//!     deterministic column, so both runs must pay the same write path);
//!  2. crashed: the same topology with the undocumented
//!     `--hold-after-dispatch <t>` crash hook; once the serve log shows
//!     round `t` dispatched, the coordinator is killed with SIGKILL —
//!     no drop handlers, no flush-on-exit, exactly the crash the
//!     journal exists for;
//!  3. resumed: `serve --journal <same> --resume` on the same port
//!     replays closed rounds, discards the torn round-`t` tail, and
//!     re-runs it live against the workers (which redial under
//!     `--reconnect` and re-send cached results through the rejoin
//!     handshake's exactly-once machinery).
//!
//! Both round policies are covered: `sync`, and `quorum 0.75` with a
//! deterministic injected straggler so late-fold accounting crosses the
//! crash boundary. The kill-9 cases need the tiny artifacts and a
//! `--features pjrt` build (same gating convention as the other e2e
//! suites); the CLI-contract tests at the bottom run everywhere.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ecolora::runtime::pjrt_available;

// ---- harness (mirrors tests/integration_deploy.rs) --------------------------

fn have_artifacts() -> bool {
    pjrt_available() && Path::new("artifacts/tiny.manifest.json").exists()
}

/// Scratch dir for one crash-test run.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ecolora-journal-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn free_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().port()
}

fn e2e_flags(rounds: usize) -> Vec<String> {
    vec![
        "--test-profile".into(),
        "tiny".into(),
        "--eco".into(),
        "--rounds".into(),
        rounds.to_string(),
    ]
}

fn spawn_logged(bin: &str, args: &[String], log: &Path) -> Child {
    let out = std::fs::File::create(log).unwrap();
    let err = out.try_clone().unwrap();
    Command::new(bin)
        .args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::from(out))
        .stderr(Stdio::from(err))
        .spawn()
        .unwrap_or_else(|e| panic!("spawning {bin}: {e}"))
}

fn wait_with_timeout(child: &mut Child, what: &str, log: &Path, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        match child.try_wait().unwrap() {
            Some(status) => {
                if !status.success() {
                    let tail = std::fs::read_to_string(log).unwrap_or_default();
                    panic!("{what} exited with {status}; log:\n{tail}");
                }
                return true;
            }
            None if Instant::now() >= deadline => {
                let _ = child.kill();
                let _ = child.wait();
                let tail = std::fs::read_to_string(log).unwrap_or_default();
                panic!("{what} did not finish within {timeout:?}; log:\n{tail}");
            }
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

/// Poll a process log until `needle` shows up (the crash trigger).
fn wait_for_log(log: &Path, needle: &str, what: &str, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    loop {
        let text = std::fs::read_to_string(log).unwrap_or_default();
        if text.contains(needle) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{what}: never logged {needle:?} within {timeout:?}; log:\n{text}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Wall-clock CSV columns that legitimately differ between runs.
const NONDETERMINISTIC_COLS: &[&str] = &[
    "overhead_s",
    "compute_s",
    "quorum_wait_s",
    "shard_agg_ms_max",
    "router_queue_max",
    "sched_ms",
    "journal_fsync_ms",
];

#[test]
fn nondeterministic_cols_allowlist_stays_in_sync_with_csv_header() {
    // a renamed CSV column must not silently fall out of the crash-
    // recovery parity check: every allowlisted name has to exist in the
    // emitted header, and the deterministic robust-aggregation columns
    // (whose replay parity `--resume` guarantees) must not be listed
    let header: Vec<&str> = ecolora::metrics::CSV_HEADER.split(',').collect();
    for col in NONDETERMINISTIC_COLS {
        assert!(header.contains(col), "allowlisted column {col:?} is not in the CSV header");
    }
    for col in ["aggregator", "clients_trimmed", "clip_applied"] {
        assert!(header.contains(&col), "column {col:?} missing from the CSV header");
        assert!(
            !NONDETERMINISTIC_COLS.contains(&col),
            "column {col:?} is deterministic and must not be allowlisted"
        );
    }
}

/// Parse a round-log CSV into (header, rows).
fn parse_csv(csv: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = csv.lines();
    let header: Vec<String> =
        lines.next().expect("csv header").split(',').map(|s| s.to_string()).collect();
    let rows = lines
        .filter(|l| !l.is_empty())
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect();
    (header, rows)
}

fn assert_deterministic_columns_equal(want_csv: &str, got_csv: &str, what: &str) {
    let (wh, wr) = parse_csv(want_csv);
    let (gh, gr) = parse_csv(got_csv);
    assert_eq!(wh, gh, "{what}: csv headers");
    assert_eq!(wr.len(), gr.len(), "{what}: round count");
    for (round, (w, g)) in wr.iter().zip(&gr).enumerate() {
        for (ci, name) in wh.iter().enumerate() {
            if NONDETERMINISTIC_COLS.contains(&name.as_str()) {
                continue;
            }
            assert_eq!(
                w[ci], g[ci],
                "{what}: column {name} diverged at round {round} \
                 (uninterrupted {:?} vs crash-resumed {:?})",
                w[ci], g[ci]
            );
        }
    }
}

// ---- the kill-9 crash-recovery scenario -------------------------------------

struct Fleet {
    serve: Child,
    serve_log: PathBuf,
    workers: Vec<(Child, PathBuf)>,
}

/// Launch `serve` + 2 `worker` processes for one run of the scenario.
#[allow(clippy::too_many_arguments)]
fn launch(
    bin: &str,
    dir: &Path,
    run: &str,
    addr: &str,
    token: &str,
    rounds: usize,
    serve_extra: &[String],
    worker_extra: &[String],
) -> Fleet {
    let mut serve_args = vec!["serve".to_string()];
    serve_args.extend(e2e_flags(rounds));
    serve_args.extend([
        "--listen".into(),
        addr.to_string(),
        "--token-file".into(),
        token.to_string(),
        "--expect-workers".into(),
        "2".into(),
        "--join-timeout-s".into(),
        "120".into(),
    ]);
    serve_args.extend(serve_extra.iter().cloned());
    let serve_log = dir.join(format!("{run}-serve.log"));
    let serve = spawn_logged(bin, &serve_args, &serve_log);

    let mut workers = Vec::new();
    for i in 0..2 {
        let mut args = vec!["worker".to_string()];
        args.extend(e2e_flags(rounds));
        args.extend([
            "--connect".into(),
            addr.to_string(),
            "--token-file".into(),
            token.to_string(),
            "--dial-timeout-s".into(),
            "120".into(),
        ]);
        args.extend(worker_extra.iter().cloned());
        let log = dir.join(format!("{run}-worker{i}.log"));
        let child = spawn_logged(bin, &args, &log);
        workers.push((child, log));
    }
    Fleet { serve, serve_log, workers }
}

fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// The full scenario: uninterrupted baseline, then crash + resume, then
/// bitwise comparison of every deterministic round-log column.
fn crash_recovery_case(tag: &str, policy_flags: &[&str], fault_flags: &[&str]) {
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let dir = scratch(tag);
    let token_path = dir.join("token");
    std::fs::write(&token_path, format!("e2e-journal-{tag}-token\n")).unwrap();
    let token = token_path.to_str().unwrap().to_string();
    let rounds = 4;
    let crash_round = 2; // rounds 0–1 closed in the journal, round 2 torn

    // -- run 1: uninterrupted baseline (journaling enabled for parity) --------
    let base_csv = dir.join("baseline.csv");
    let base_addr = format!("127.0.0.1:{}", free_port());
    let mut serve_extra = strs(policy_flags);
    serve_extra.extend(strs(&[
        "--journal",
        dir.join("baseline.journal").to_str().unwrap(),
        "--csv",
        base_csv.to_str().unwrap(),
    ]));
    let mut base =
        launch(bin, &dir, "base", &base_addr, &token, rounds, &serve_extra, &strs(fault_flags));
    wait_with_timeout(&mut base.serve, "baseline serve", &base.serve_log, Duration::from_secs(300));
    for (i, (mut w, log)) in base.workers.into_iter().enumerate() {
        wait_with_timeout(&mut w, &format!("baseline worker {i}"), &log, Duration::from_secs(60));
    }

    // -- run 2: identical config, crash-hold at round 2, SIGKILL --------------
    let journal = dir.join("crash.journal");
    let addr = format!("127.0.0.1:{}", free_port());
    let mut serve_extra = strs(policy_flags);
    serve_extra.extend(strs(&[
        "--journal",
        journal.to_str().unwrap(),
        "--csv",
        dir.join("crash.csv").to_str().unwrap(),
        "--hold-after-dispatch",
        &crash_round.to_string(),
    ]));
    // workers must survive the coordinator outage and rejoin on their own
    let mut worker_extra = strs(&["--reconnect", "8"]);
    worker_extra.extend(strs(fault_flags));
    let mut crash =
        launch(bin, &dir, "crash", &addr, &token, rounds, &serve_extra, &worker_extra);
    wait_for_log(
        &crash.serve_log,
        &format!("crash-hold: round {crash_round} dispatched"),
        "crashed serve",
        Duration::from_secs(240),
    );
    // give the dispatched tasks a moment to land in the worker sockets,
    // then kill -9: no drop handlers, no flush, the real failure mode
    std::thread::sleep(Duration::from_millis(300));
    crash.serve.kill().expect("SIGKILL the held coordinator");
    let _ = crash.serve.wait();

    // -- run 3: resume from the journal on the same port ----------------------
    let resumed_csv = dir.join("resumed.csv");
    let mut serve_extra = strs(policy_flags);
    serve_extra.extend(strs(&[
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--csv",
        resumed_csv.to_str().unwrap(),
    ]));
    let mut resumed =
        launch0(bin, &dir, "resumed", &addr, &token, rounds, &serve_extra);
    wait_for_log(
        &resumed.serve_log,
        "resumed from journal",
        "resumed serve",
        Duration::from_secs(120),
    );
    wait_with_timeout(
        &mut resumed.serve,
        "resumed serve",
        &resumed.serve_log,
        Duration::from_secs(300),
    );
    // the original worker processes rejoin the resumed coordinator and
    // must run to a clean shutdown
    for (i, (mut w, log)) in crash.workers.into_iter().enumerate() {
        wait_with_timeout(&mut w, &format!("worker {i}"), &log, Duration::from_secs(120));
    }

    // -- the durability claim --------------------------------------------------
    let resumed_log = std::fs::read_to_string(&resumed.serve_log).unwrap();
    assert!(
        resumed_log.contains(&format!("{crash_round} round(s) replayed")),
        "resume must replay exactly the closed rounds; log:\n{resumed_log}"
    );
    let want = std::fs::read_to_string(&base_csv).unwrap();
    let got = std::fs::read_to_string(&resumed_csv).unwrap();
    let (_, rows) = parse_csv(&got);
    assert_eq!(rows.len(), rounds, "resumed log must span replayed + live rounds");
    assert_deterministic_columns_equal(&want, &got, tag);
}

/// Launch a serve alone (the resume leg reuses the crashed run's workers).
fn launch0(
    bin: &str,
    dir: &Path,
    run: &str,
    addr: &str,
    token: &str,
    rounds: usize,
    serve_extra: &[String],
) -> Fleet {
    let mut serve_args = vec!["serve".to_string()];
    serve_args.extend(e2e_flags(rounds));
    serve_args.extend([
        "--listen".into(),
        addr.to_string(),
        "--token-file".into(),
        token.to_string(),
        "--expect-workers".into(),
        "2".into(),
        "--join-timeout-s".into(),
        "120".into(),
    ]);
    serve_args.extend(serve_extra.iter().cloned());
    let serve_log = dir.join(format!("{run}-serve.log"));
    let serve = spawn_logged(bin, &serve_args, &serve_log);
    Fleet { serve, serve_log, workers: Vec::new() }
}

#[test]
fn sigkill_mid_round_resume_is_bitwise_identical_under_sync() {
    if !have_artifacts() {
        return;
    }
    crash_recovery_case("sync", &[], &[]);
}

#[test]
fn sigkill_mid_round_resume_is_bitwise_identical_under_quorum_with_straggler() {
    if !have_artifacts() {
        return;
    }
    // quorum 0.75 of a 4-slot cohort closes at 3 results; client 0's
    // uplink is delayed 1s on whichever worker hosts it, so its result
    // folds in late — the late-buffer accounting must replay across the
    // crash boundary bit-for-bit. The slot timeout (20s) dwarfs the
    // injected delay so no resample wave fires.
    crash_recovery_case(
        "quorum",
        &["--round-policy", "quorum", "--quorum", "0.75", "--slot-timeout", "20000"],
        &["--inject-slow", "0", "--inject-delay-ms", "1000"],
    );
}

#[test]
fn sigkill_mid_round_resume_is_bitwise_identical_under_robust_aggregation() {
    if !have_artifacts() {
        return;
    }
    // the robust plane across the crash boundary: the coordinator runs
    // trimmed-mean against a deterministic sign-flip client, so the
    // journal's closed rounds carry the aggregator label and robustness
    // counter columns — replay must reproduce them bit-for-bit. The
    // worker leg repeats --aggregator because the statistic is part of
    // the config digest (a resumed coordinator or joining worker with a
    // different --aggregator is refused at handshake).
    crash_recovery_case(
        "robust",
        &["--aggregator", "trimmed-mean:0.3"],
        &["--aggregator", "trimmed-mean:0.3", "--inject-malicious", "1", "--attack", "sign-flip"],
    );
}

// ---- CLI contract (ungated) -------------------------------------------------

/// Run `ecolora serve` with the given trailing flags and return
/// (success, combined output) — for flag-validation assertions that
/// must fail before any socket or artifact work.
fn serve_cli(extra: &[&str]) -> (bool, String) {
    let bin = env!("CARGO_BIN_EXE_ecolora");
    let mut args: Vec<String> = vec!["serve".into()];
    args.extend(e2e_flags(2));
    args.extend(strs(&["--token", "cli-contract", "--expect-workers", "2"]));
    args.extend(strs(extra));
    let out = Command::new(bin).args(&args).output().unwrap();
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn worker_side_attack_flags_are_refused_by_serve() {
    let (ok, text) = serve_cli(&["--inject-malicious", "2", "--attack", "sign-flip"]);
    assert!(!ok, "attack injection lives in the worker processes");
    assert!(text.contains("belongs to the `worker` subcommand"), "got: {text}");
}

#[test]
fn bad_aggregator_spec_is_refused_by_name() {
    let (ok, text) = serve_cli(&["--aggregator", "krum"]);
    assert!(!ok, "an unknown robust statistic must be an error");
    assert!(text.contains("unknown aggregator"), "got: {text}");
}

#[test]
fn resume_without_journal_is_refused() {
    let (ok, text) = serve_cli(&["--resume"]);
    assert!(!ok, "--resume without --journal must be an error");
    assert!(text.contains("--resume requires --journal"), "got: {text}");
}

#[test]
fn journal_sync_without_journal_is_refused() {
    let (ok, text) = serve_cli(&["--journal-sync", "always"]);
    assert!(!ok, "--journal-sync without --journal must be an error");
    assert!(text.contains("--journal-sync requires --journal"), "got: {text}");
}

#[test]
fn bad_journal_sync_policy_is_refused_by_name() {
    let (ok, text) = serve_cli(&["--journal", "/tmp/never-created.journal", "--journal-sync", "sometimes"]);
    assert!(!ok, "an unknown sync policy must be an error");
    assert!(text.contains("always|round|off"), "got: {text}");
}
