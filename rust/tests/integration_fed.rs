//! Integration: full federated runs (FedRunner) over the real tiny
//! artifacts — every method, with and without EcoLoRA, plus federated DPO.
//! Asserts the paper's headline communication claims hold mechanically:
//! EcoLoRA's uplink is ~1/N_s × sparsity of the dense baseline.

use ecolora::baselines::Method;
use ecolora::compress::{Encoding, SparsMode};
use ecolora::data::PartitionKind;
use ecolora::fed::{EcoConfig, FedConfig, FedRunner};

fn have_artifacts() -> bool {
    ecolora::runtime::pjrt_available()
        && std::path::Path::new("artifacts/tiny.manifest.json").exists()
}

fn base_cfg() -> FedConfig {
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.lr = 2.0;
    cfg
}

#[test]
fn fedit_dense_runs_and_accounts_comm() {
    if !have_artifacts() {
        return;
    }
    let mut runner = FedRunner::new(base_cfg()).unwrap();
    let lora_total = runner.schema().lora_total as u64;
    let out = runner.run().unwrap();
    assert_eq!(out.log.rounds.len(), 4);
    // dense: every sampled client ships the whole module both ways
    let per_round_up = 4 * lora_total;
    assert_eq!(out.log.total_up().params, 4 * per_round_up);
    assert_eq!(out.log.total_down().params, 4 * per_round_up);
    assert!(out.final_acc >= 0.0 && out.final_acc <= 1.0);
    assert!(out.log.final_loss().is_finite());
}

#[test]
fn ecolora_cuts_upload_by_segments_times_sparsity() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.eco = Some(EcoConfig { n_s: 4, ..Default::default() });
    let mut runner = FedRunner::new(cfg).unwrap();
    let lora_total = runner.schema().lora_total as u64;
    let out = runner.run().unwrap();

    let dense_up = 4u64 * 4 * lora_total; // rounds * clients * module
    let eco_up = out.log.total_up().params;
    // RR alone gives 1/4; sparsification adds k<=0.95 on top
    assert!(
        eco_up < dense_up / 3,
        "eco upload {eco_up} vs dense {dense_up}"
    );
    // uplink bytes beat dense f16 too
    assert!(out.log.total_up().bytes < 2 * dense_up / 3);
    // loss signal drove the schedule
    let last = out.log.rounds.last().unwrap();
    assert!(last.k_a > 0.0 && last.k_a <= 0.95 + 1e-9);
    assert!(last.k_b <= last.k_a + 1e-9);
}

#[test]
fn ffa_halves_dense_traffic() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::FfaLora;
    let mut runner = FedRunner::new(cfg).unwrap();
    let lora_total = runner.schema().lora_total as u64;
    let out = runner.run().unwrap();
    assert_eq!(out.log.total_up().params, 4 * 4 * lora_total / 2);
}

#[test]
fn flora_download_is_stacked() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::FLoRa;
    cfg.rounds = 2;
    let mut runner = FedRunner::new(cfg).unwrap();
    let lora_total = runner.schema().lora_total as u64;
    let out = runner.run().unwrap();
    // each of 4 clients downloads N_t x module per round
    assert_eq!(out.log.total_down().params, 2 * 4 * 4 * lora_total);
    assert!(out.log.final_loss().is_finite());
}

#[test]
fn eco_with_fixed_spars_and_no_encoding_variants_run() {
    if !have_artifacts() {
        return;
    }
    for (spars, encoding) in [
        (SparsMode::Fixed(0.5), Encoding::Golomb),
        (SparsMode::Adaptive(Default::default()), Encoding::Fixed),
        (SparsMode::Off, Encoding::Golomb),
    ] {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig { spars, encoding, ..Default::default() });
        let mut runner = FedRunner::new(cfg).unwrap();
        let out = runner.run().unwrap();
        assert!(out.log.final_loss().is_finite());
        assert!(out.log.total_up().params > 0);
    }
}

#[test]
fn golomb_encoding_beats_fixed_positions_on_the_wire() {
    if !have_artifacts() {
        return;
    }
    let run = |encoding| {
        let mut cfg = base_cfg();
        cfg.rounds = 3;
        cfg.eco = Some(EcoConfig {
            spars: SparsMode::Fixed(0.25),
            encoding,
            downlink_sparse: false,
            ..Default::default()
        });
        let mut r = FedRunner::new(cfg).unwrap();
        r.run().unwrap().log.total_up()
    };
    let golomb = run(Encoding::Golomb);
    let fixed = run(Encoding::Fixed);
    assert_eq!(golomb.params, fixed.params, "same selection, different coding");
    assert!(
        golomb.bytes < fixed.bytes,
        "golomb {} vs fixed {}",
        golomb.bytes,
        fixed.bytes
    );
}

#[test]
fn task_domain_partition_run() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.partition = PartitionKind::TaskDomain;
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let out = FedRunner::new(cfg).unwrap().run().unwrap();
    assert!(out.log.final_loss().is_finite());
}

#[test]
fn dpo_mode_runs_and_reports_margin() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.dpo = true;
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let out = FedRunner::new(cfg).unwrap().run().unwrap();
    assert!(out.final_margin.is_some());
    assert!(out.final_margin.unwrap().is_finite());
}

#[test]
fn run_is_seed_deterministic() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        let mut r = FedRunner::new(cfg).unwrap();
        let out = r.run().unwrap();
        (
            out.log.total_up().bytes,
            out.log.final_loss(),
            out.final_lora.iter().map(|x| x.abs() as f64).sum::<f64>(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0);
    assert_eq!(a.1, b.1);
    assert!((a.2 - b.2).abs() < 1e-9);
}

#[test]
fn gini_tracked_per_round() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 3;
    cfg.eco = Some(EcoConfig::default());
    let out = FedRunner::new(cfg).unwrap().run().unwrap();
    for r in &out.log.rounds {
        assert!(r.gini_a >= 0.0 && r.gini_a <= 1.0);
        assert!(r.gini_b >= 0.0 && r.gini_b <= 1.0);
    }
}
