//! Cluster integration.
//!
//! Transport/protocol behavior runs everywhere (no PJRT needed),
//! including the late-buffer fold property tests. The parity suite —
//! proving the message-passing cluster reproduces the monolithic
//! `FedRunner` BITWISE for a fixed seed, and that `Quorum{q: 1.0}` with
//! no timeouts reproduces the sync path — additionally needs the tiny
//! artifacts (`make artifacts`) and a `--features pjrt` build; without
//! them those tests no-op, same convention as integration_fed.

use std::time::Duration;

use ecolora::cluster::coordinator::{FoldCtx, LateBuffer, RoundPolicy};
use ecolora::cluster::protocol::{TrainResult, UpPayload};
use ecolora::cluster::{self, ClusterMode, ClusterOptions, FaultSpec, SimProfile};
use ecolora::compress::{wire, Encoding, KindIndex, SparseVec};
use ecolora::fed::server::SegmentAggregator;
use ecolora::fed::{sampling, staleness, EcoConfig, FedConfig, FedOutcome, FedRunner};
use ecolora::metrics::RoundRecord;
use ecolora::model::LoraKind;
use ecolora::netsim::Scenario;
use ecolora::runtime::pjrt_available;
use ecolora::util::propcheck::propcheck;
use ecolora::util::rng::Rng;

fn have_artifacts() -> bool {
    pjrt_available() && std::path::Path::new("artifacts/tiny.manifest.json").exists()
}

fn base_cfg() -> FedConfig {
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.lr = 2.0;
    cfg
}

fn assert_bitwise_equal(mono: &FedOutcome, clus: &FedOutcome, what: &str) {
    assert_eq!(mono.final_lora.len(), clus.final_lora.len(), "{what}: lora length");
    for (i, (a, b)) in mono.final_lora.iter().zip(&clus.final_lora).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: final_lora[{i}] {a} vs {b}");
    }
    assert_eq!(mono.final_acc.to_bits(), clus.final_acc.to_bits(), "{what}: final_acc");
    assert_eq!(mono.log.rounds.len(), clus.log.rounds.len(), "{what}: round count");
    for (mr, cr) in mono.log.rounds.iter().zip(&clus.log.rounds) {
        assert_eq!(mr.global_loss.to_bits(), cr.global_loss.to_bits(), "{what}: loss r{}", mr.round);
        assert_eq!(mr.up, cr.up, "{what}: uplink accounting r{}", mr.round);
        assert_eq!(mr.down, cr.down, "{what}: downlink accounting r{}", mr.round);
        assert_eq!(mr.eval_acc, cr.eval_acc, "{what}: eval r{}", mr.round);
        assert_eq!(mr.k_a, cr.k_a, "{what}: k_a r{}", mr.round);
    }
}

fn mem_opts(workers: usize) -> ClusterOptions {
    ClusterOptions { mode: ClusterMode::Mem, workers: Some(workers), ..Default::default() }
}

fn run_both(cfg: FedConfig, workers: usize, what: &str) {
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let clus = cluster::run(cfg, &mem_opts(workers)).unwrap();
    assert_eq!(clus.workers, workers);
    assert_bitwise_equal(&mono, &clus.fed, what);
}

#[test]
fn one_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: one full EcoLoRA round over the
    // in-memory cluster == the monolithic path, bit for bit
    let mut cfg = base_cfg();
    cfg.rounds = 1;
    cfg.eco = Some(EcoConfig::default());
    run_both(cfg, 3, "eco 1 round");
}

#[test]
fn multi_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // staleness mixing, error-feedback residuals and the downlink
    // references all carry state across rounds — parity must survive them
    let mut cfg = base_cfg();
    cfg.eco = Some(EcoConfig { n_s: 3, ..Default::default() });
    run_both(cfg, 2, "eco 4 rounds");
}

#[test]
fn dense_fedit_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    run_both(cfg, 4, "dense fedit");
}

#[test]
fn flora_parity_bitwise_with_base_sync() {
    if !have_artifacts() {
        return;
    }
    // FLoRA merges into the frozen base every round: exercises BaseSync
    let mut cfg = base_cfg();
    cfg.method = ecolora::baselines::Method::FLoRa;
    cfg.rounds = 2;
    run_both(cfg, 2, "flora dense");
}

#[test]
fn worker_count_does_not_change_results() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let one = cluster::run(mk(), &mem_opts(1)).unwrap();
    let four = cluster::run(mk(), &mem_opts(4)).unwrap();
    assert_bitwise_equal(&one.fed, &four.fed, "1 vs 4 workers");
}

#[test]
fn tcp_loopback_runs_and_matches_mem() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let mem = cluster::run(mk(), &mem_opts(2)).unwrap();
    let tcp = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Tcp, workers: Some(2), ..Default::default() },
    )
    .unwrap();
    assert_eq!(tcp.transport, "tcp");
    assert_bitwise_equal(&mem.fed, &tcp.fed, "mem vs tcp");
}

#[test]
fn netsim_shim_reports_round_timings() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let scenario = Scenario { name: "1/5 Mbps", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
    let out = cluster::run(
        cfg,
        &ClusterOptions {
            mode: ClusterMode::Mem,
            workers: Some(2),
            netsim: Some(SimProfile::uniform(scenario)),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.timings.len(), 2);
    for t in &out.timings {
        assert!(t.round_s > 0.0 && t.round_s.is_finite(), "{t:?}");
        assert!(t.comm_s > 0.0, "{t:?}");
    }
}

#[test]
fn dpo_over_cluster_parity() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.dpo = true;
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let clus = cluster::run(cfg, &mem_opts(2)).unwrap();
    assert_bitwise_equal(&mono, &clus.fed, "dpo");
    assert_eq!(
        mono.final_margin.unwrap().to_bits(),
        clus.fed.final_margin.unwrap().to_bits(),
        "dpo margin"
    );
}

// ---- quorum / straggler rounds ---------------------------------------------

fn quorum_opts(workers: usize, q: f64, timeout_ms: u64) -> ClusterOptions {
    ClusterOptions {
        mode: ClusterMode::Mem,
        workers: Some(workers),
        policy: RoundPolicy::Quorum { q, timeout: Duration::from_millis(timeout_ms) },
        ..Default::default()
    }
}

#[test]
fn full_quorum_matches_sync_and_monolith_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: Quorum{q: 1.0} with a timeout that
    // never fires IS the sync path, bit for bit — including against the
    // monolithic reference
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let mono = FedRunner::new(mk()).unwrap().run().unwrap();
    let sync = cluster::run(mk(), &mem_opts(3)).unwrap();
    let quorum = cluster::run(mk(), &quorum_opts(3, 1.0, 600_000)).unwrap();
    assert_bitwise_equal(&mono, &sync.fed, "mono vs sync");
    assert_bitwise_equal(&sync.fed, &quorum.fed, "sync vs quorum(1.0)");
    assert_eq!(quorum.fed.log.total_stragglers(), 0);
    assert_eq!(quorum.fed.log.total_late_folds(), 0);
    assert_eq!(quorum.fed.log.total_resampled(), 0);
}

#[test]
fn quorum_round_closes_past_straggler_and_discounts_its_uplink() {
    if !have_artifacts() {
        return;
    }
    // Every round samples the same 4-client cohort (n == N_t, rotor
    // sampling) on 2 workers: worker 1 hosts clients 1 and 3, and client
    // 1's injected sleep blocks client 3 behind it on that worker's
    // queue. Clients 0 and 2 report in milliseconds; the quorum of 3
    // completes when client 1's sleep ends — at which point the round
    // closes with client 3 as the straggler every single round. Client
    // 3's result lands during the NEXT round's collect and folds in with
    // the e^{−β·1} staleness discount.
    let mk = || {
        let mut cfg = base_cfg();
        cfg.n_clients = 4;
        cfg.clients_per_round = 4;
        cfg.rounds = 3;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let opts = |fault_delay_ms| ClusterOptions {
        fault: Some(FaultSpec { client: 1, delay: Duration::from_millis(fault_delay_ms) }),
        ..quorum_opts(2, 0.75, 600_000)
    };
    let a = cluster::run(mk(), &opts(1_500)).unwrap();

    let rounds = &a.fed.log.rounds;
    assert_eq!(rounds.len(), 3);
    for r in rounds {
        assert_eq!(r.cohort, 4, "round {}", r.round);
        assert_eq!(r.stragglers, 1, "round {}: quorum 3 of 4 leaves one behind", r.round);
        assert_eq!(r.resampled, 0, "round {}: generous timeout, no re-dispatch", r.round);
    }
    assert_eq!(rounds[0].late_folds, 0, "nothing buffered before round 0");
    assert_eq!(rounds[1].late_folds, 1, "round 0's straggler folds into round 1");
    assert_eq!(rounds[2].late_folds, 1, "round 1's straggler folds into round 2");
    assert!((a.fed.log.dropout_rate() - 0.25).abs() < 1e-12);
    assert!(a.fed.final_acc.is_finite());
    assert!(rounds.iter().all(|r| r.global_loss.is_finite()));

    // "deterministically": an identical run reproduces the same bits —
    // the straggler pattern is fixed by the fault spec, and the fold
    // order is (origin round, slot), not arrival order
    let b = cluster::run(mk(), &opts(1_500)).unwrap();
    assert_bitwise_equal(&a.fed, &b.fed, "quorum straggler run repeated");
    for (ra, rb) in a.fed.log.rounds.iter().zip(&b.fed.log.rounds) {
        assert_eq!(ra.stragglers, rb.stragglers);
        assert_eq!(ra.late_folds, rb.late_folds);
    }
}

#[test]
fn timed_out_slot_is_resampled_and_originals_still_win() {
    if !have_artifacts() {
        return;
    }
    // Single worker, client 2's uplink sleeps 1.5 s, slot timeout 200 ms:
    // the coordinator re-dispatches the outstanding slots to replacement
    // clients (deterministically drawn from the unsampled population)
    // while the originals grind on. The originals land first (FIFO on the
    // one worker), fill their slots, and close the full quorum — so the
    // final model must equal the plain sync run bit for bit even though
    // replacement downlinks were spent.
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 1;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let sync = cluster::run(mk(), &mem_opts(1)).unwrap();
    let quorum = cluster::run(
        mk(),
        &ClusterOptions {
            fault: Some(FaultSpec { client: 2, delay: Duration::from_millis(1_500) }),
            ..quorum_opts(1, 1.0, 200)
        },
    )
    .unwrap();

    let r = &quorum.fed.log.rounds[0];
    assert!(r.resampled >= 2, "both blocked slots re-dispatched at least once: {r:?}");
    assert_eq!(r.stragglers, 0, "every original slot eventually reported");
    assert_eq!(
        sync.fed.log.rounds[0].global_loss.to_bits(),
        r.global_loss.to_bits(),
        "originals filled every slot: loss identical to sync"
    );
    for (a, b) in sync.fed.final_lora.iter().zip(&quorum.fed.final_lora) {
        assert_eq!(a.to_bits(), b.to_bits(), "model identical to sync");
    }
    assert_eq!(sync.fed.final_acc.to_bits(), quorum.fed.final_acc.to_bits());
    // replacement downlinks are real traffic and must be accounted
    assert!(
        quorum.fed.log.rounds[0].down.bytes > sync.fed.log.rounds[0].down.bytes,
        "re-dispatch downlinks charged"
    );

    // With a second round, the losing racers' results (round-0 slots that
    // the originals already filled) arrive during round 1's collect —
    // the coordinator must reject them, NOT staleness-fold them: their
    // slots already contributed to round 0's aggregate.
    let mk2 = || {
        let mut cfg = mk();
        cfg.rounds = 2;
        cfg
    };
    let two = cluster::run(
        mk2(),
        &ClusterOptions {
            fault: Some(FaultSpec { client: 2, delay: Duration::from_millis(1_500) }),
            ..quorum_opts(1, 1.0, 200)
        },
    )
    .unwrap();
    assert_eq!(
        two.fed.log.rounds[1].late_folds,
        0,
        "a racer for an already-aggregated slot must never double-fold"
    );
}

// ---- late-buffer fold properties (no PJRT needed) --------------------------

fn test_kidx(n: usize) -> KindIndex {
    let kinds: Vec<LoraKind> = (0..n)
        .map(|i| if (i / 16) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect();
    KindIndex::new(&kinds)
}

/// A late SparseWire result for (origin round, slot) covering `seg`.
fn late_result(
    rng: &mut Rng,
    kidx: &KindIndex,
    agg_total: usize,
    n_s: usize,
    origin: u64,
    slot: u32,
    client: u32,
) -> TrainResult {
    let ranges = ecolora::model::segment_ranges(agg_total, n_s);
    let seg = rng.below(n_s);
    let range = ranges[seg].clone();
    let mut idx: Vec<u32> = (range.start..range.end)
        .filter(|_| rng.below(4) == 0)
        .map(|i| i as u32)
        .collect();
    if idx.is_empty() {
        idx.push(range.start as u32);
    }
    let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
    let sv = SparseVec { idx, vals };
    let bytes = wire::encode(&sv, &range, kidx, (0.5, 0.5), Encoding::Golomb).unwrap();
    TrainResult {
        round: origin,
        slot,
        client,
        segment: seg as u32,
        n_samples: rng.below(40) as u32 + 1,
        mean_loss: rng.normal(),
        k_a: 0.5,
        k_b: 0.5,
        exec_s: 0.0,
        stale_from_round: origin,
        up: UpPayload::SparseWire(bytes),
    }
}

#[test]
fn late_fold_is_arrival_order_invariant_and_matches_slot_ordered_fold() {
    propcheck(60, |rng| {
        let n_s = rng.below(3) + 1;
        let total = 32 * (rng.below(4) + n_s); // multiple of the kind blocks
        let kidx = test_kidx(total);
        let beta = 0.7;
        let now = 10u64;
        let n_clients = 8;
        let weights: Vec<f64> = (0..n_clients).map(|c| (c + 1) as f64).collect();

        // unique (origin round, slot) straggler set, arbitrary subset size
        let mut entries = Vec::new();
        for origin in 7..10u64 {
            for slot in 0..4u32 {
                if rng.below(2) == 0 {
                    let client = rng.below(n_clients) as u32;
                    entries.push(late_result(rng, &kidx, total, n_s, origin, slot, client));
                }
            }
        }

        // reference: slot-ordered fold straight into an aggregator
        let mut reference = SegmentAggregator::new(total, n_s);
        let mut sorted = entries.clone();
        sorted.sort_by_key(|e| (e.stale_from_round, e.slot));
        for e in &sorted {
            let UpPayload::SparseWire(bytes) = &e.up else { unreachable!() };
            let staleness = now - e.stale_from_round;
            let w = weights[e.client as usize] * staleness::stale_discount(beta, staleness);
            reference.add_wire(e.segment as usize, bytes, &kidx, w).unwrap();
        }
        let want = reference.finish();

        // property: ANY arrival order through the buffer gives those bits
        let mut shuffled = entries.clone();
        rng.shuffle(&mut shuffled);
        let mut buf = LateBuffer::new();
        for e in shuffled {
            assert!(buf.push(e), "unique (round, slot) entries are always kept");
        }
        let mut agg = SegmentAggregator::new(total, n_s);
        let mut rec = RoundRecord::default();
        let ctx = FoldCtx { weights: &weights, beta, now_round: now, dense_params: 0 };
        let folded = buf.fold_into(&mut agg, &kidx, ctx, &mut rec);
        assert_eq!(folded.len(), sorted.len(), "every entry reports its folded identity");
        assert_eq!(rec.late_folds, sorted.len());
        assert_eq!(buf.dropped, 0);
        assert!(buf.is_empty(), "fold drains the buffer");
        let got = agg.finish();

        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "late fold diverged at {i}");
        }
    });
}

#[test]
fn late_buffer_dedupes_and_rejects_unfoldable_entries() {
    let mut rng = Rng::new(7);
    let total = 64;
    let kidx = test_kidx(total);
    let weights = vec![10.0; 4];
    let mut buf = LateBuffer::new();

    let first = late_result(&mut rng, &kidx, total, 1, 5, 0, 1);
    assert!(buf.push(first.clone()));
    // same (origin round, slot): first arrival wins
    let dup = late_result(&mut rng, &kidx, total, 1, 5, 0, 2);
    assert!(!buf.push(dup));
    assert_eq!(buf.dropped, 1);

    // FLoRA modules cannot fold late
    let module = TrainResult {
        up: UpPayload::DenseModule(vec![0.0; total]),
        ..late_result(&mut rng, &kidx, total, 1, 5, 1, 3)
    };
    assert!(!buf.push(module));
    assert_eq!(buf.dropped, 2);

    // a segment id beyond the folding round's geometry is dropped, not fatal
    let misfit = TrainResult { segment: 9, ..late_result(&mut rng, &kidx, total, 1, 6, 2, 3) };
    assert!(buf.push(misfit));
    let mut agg = SegmentAggregator::new(total, 1);
    let mut rec = RoundRecord::default();
    let ctx = FoldCtx { weights: &weights, beta: 0.7, now_round: 8, dense_params: 0 };
    let folded = buf.fold_into(&mut agg, &kidx, ctx, &mut rec);
    assert_eq!(folded, vec![(5, 0)], "only the clean entry reports a folded identity");
    assert_eq!(rec.late_folds, 1, "only the clean entry folds");
    assert_eq!(rec.orphaned, 1, "the misfit is surfaced in telemetry");
    assert_eq!(buf.dropped, 3);

    // the folded entry landed with a discounted weight: the aggregate is
    // scaled by e^{-beta*3} relative to an undiscounted fold
    let UpPayload::SparseWire(bytes) = &first.up else { unreachable!() };
    let mut plain = SegmentAggregator::new(total, 1);
    plain.add_wire(0, bytes, &kidx, 10.0).unwrap();
    let plain = plain.finish();
    let discounted = agg.finish();
    // weighted average over a single contribution is scale-invariant in
    // the weight — so compare against a mixed fold to see the discount
    assert_eq!(plain.len(), discounted.len());
    for (a, b) in plain.iter().zip(&discounted) {
        assert_eq!(a.to_bits(), b.to_bits(), "single-entry average ignores scale");
    }
}

#[test]
fn quorum_policy_arithmetic() {
    let q = |frac: f64, n: usize| {
        RoundPolicy::Quorum { q: frac, timeout: Duration::from_millis(100) }.quorum_of(n)
    };
    assert_eq!(q(1.0, 4), 4);
    assert_eq!(q(0.75, 4), 3);
    assert_eq!(q(0.8, 4), 4, "ceil(3.2) = 4");
    assert_eq!(q(0.7, 4), 3, "ceil(2.8) = 3");
    assert_eq!(q(0.01, 4), 1, "floor at one result");
    assert_eq!(q(0.5, 0), 0, "empty cohort needs nothing");
    assert_eq!(RoundPolicy::Sync.quorum_of(7), 7);
    assert_eq!(RoundPolicy::Sync.deadline_ms(), 0);
    assert_eq!(q(0.5, 10), 5);
    assert_eq!(
        RoundPolicy::Quorum { q: 0.5, timeout: Duration::from_millis(250) }.deadline_ms(),
        250
    );
}
