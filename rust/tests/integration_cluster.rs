//! Cluster integration.
//!
//! Transport/protocol behavior runs everywhere (no PJRT needed),
//! including the late-buffer fold properties and the router/shard parity
//! suite (`--shards N` must be bitwise-identical to `--shards 1`). The
//! full-run parity suite — proving the message-passing cluster reproduces
//! the monolithic `FedRunner` BITWISE for a fixed seed, that
//! `Quorum{q: 1.0}` with no timeouts reproduces the sync path, and that
//! shard counts 2 and 4 reproduce shard count 1 under both policies —
//! additionally needs the tiny artifacts (`make artifacts`) and a
//! `--features pjrt` build; without them those tests no-op, same
//! convention as integration_fed.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ecolora::cluster::router::RoutedAdd;
use ecolora::cluster::shard::Payload;
use ecolora::cluster::{
    self, AggStats, ClientPlane, ClusterMode, ClusterOptions, ControlPlane, EngineCache,
    FaultSpec, FoldCtx, LateBuffer, RoundPolicy, Router, SimProfile, LATE_BUFFER_MAX_BYTES,
};
use ecolora::cluster::protocol::{TrainResult, UpPayload};
use ecolora::compress::{wire, Encoding, KindIndex, SparseVec};
use ecolora::fed::robust::{Aggregator, RobustAggregator};
use ecolora::fed::server::SegmentAggregator;
use ecolora::fed::world::{self, WorldSeed};
use ecolora::fed::{round_robin, sampling, staleness, EcoConfig, FedConfig, FedOutcome, FedRunner};
use ecolora::metrics::CommTotals;
use ecolora::model::LoraKind;
use ecolora::netsim::Scenario;
use ecolora::runtime::pjrt_available;
use ecolora::util::propcheck::propcheck;
use ecolora::util::rng::Rng;

fn have_artifacts() -> bool {
    pjrt_available() && std::path::Path::new("artifacts/tiny.manifest.json").exists()
}

fn base_cfg() -> FedConfig {
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.lr = 2.0;
    cfg
}

fn assert_bitwise_equal(mono: &FedOutcome, clus: &FedOutcome, what: &str) {
    assert_eq!(mono.final_lora.len(), clus.final_lora.len(), "{what}: lora length");
    for (i, (a, b)) in mono.final_lora.iter().zip(&clus.final_lora).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: final_lora[{i}] {a} vs {b}");
    }
    assert_eq!(mono.final_acc.to_bits(), clus.final_acc.to_bits(), "{what}: final_acc");
    assert_eq!(mono.log.rounds.len(), clus.log.rounds.len(), "{what}: round count");
    for (mr, cr) in mono.log.rounds.iter().zip(&clus.log.rounds) {
        assert_eq!(mr.global_loss.to_bits(), cr.global_loss.to_bits(), "{what}: loss r{}", mr.round);
        assert_eq!(mr.up, cr.up, "{what}: uplink accounting r{}", mr.round);
        assert_eq!(mr.down, cr.down, "{what}: downlink accounting r{}", mr.round);
        assert_eq!(mr.eval_acc, cr.eval_acc, "{what}: eval r{}", mr.round);
        assert_eq!(mr.k_a, cr.k_a, "{what}: k_a r{}", mr.round);
        // deterministic client-plane columns (mux_workers/sched_ms are
        // host-local timing facts and deliberately excluded)
        assert_eq!(mr.population, cr.population, "{what}: population r{}", mr.round);
        assert_eq!(mr.active_cohort, cr.active_cohort, "{what}: active_cohort r{}", mr.round);
    }
}

fn mem_opts(workers: usize) -> ClusterOptions {
    ClusterOptions { mode: ClusterMode::Mem, workers: Some(workers), ..Default::default() }
}

fn sharded_opts(workers: usize, shards: usize) -> ClusterOptions {
    ClusterOptions { shards, ..mem_opts(workers) }
}

fn run_both(cfg: FedConfig, workers: usize, what: &str) {
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let clus = cluster::run(cfg, &mem_opts(workers)).unwrap();
    assert_eq!(clus.workers, workers);
    assert_bitwise_equal(&mono, &clus.fed, what);
}

#[test]
fn one_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: one full EcoLoRA round over the
    // in-memory cluster == the monolithic path, bit for bit
    let mut cfg = base_cfg();
    cfg.rounds = 1;
    cfg.eco = Some(EcoConfig::default());
    run_both(cfg, 3, "eco 1 round");
}

#[test]
fn multi_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // staleness mixing, error-feedback residuals and the downlink
    // references all carry state across rounds — parity must survive them
    let mut cfg = base_cfg();
    cfg.eco = Some(EcoConfig { n_s: 3, ..Default::default() });
    run_both(cfg, 2, "eco 4 rounds");
}

#[test]
fn dense_fedit_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    run_both(cfg, 4, "dense fedit");
}

#[test]
fn flora_parity_bitwise_with_base_sync() {
    if !have_artifacts() {
        return;
    }
    // FLoRA merges into the frozen base every round: exercises BaseSync
    let mut cfg = base_cfg();
    cfg.method = ecolora::baselines::Method::FLoRa;
    cfg.rounds = 2;
    run_both(cfg, 2, "flora dense");
}

#[test]
fn worker_count_does_not_change_results() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let one = cluster::run(mk(), &mem_opts(1)).unwrap();
    let four = cluster::run(mk(), &mem_opts(4)).unwrap();
    assert_bitwise_equal(&one.fed, &four.fed, "1 vs 4 workers");
}

#[test]
fn shard_count_does_not_change_results_under_sync() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: the sharded aggregation plane is
    // bitwise-invisible — shards 2 and 4 == shard 1 == the monolith
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig { n_s: 3, ..Default::default() });
        cfg
    };
    let mono = FedRunner::new(mk()).unwrap().run().unwrap();
    let one = cluster::run(mk(), &sharded_opts(2, 1)).unwrap();
    let two = cluster::run(mk(), &sharded_opts(2, 2)).unwrap();
    let four = cluster::run(mk(), &sharded_opts(2, 4)).unwrap();
    assert_eq!(two.shards, 2);
    assert_eq!(four.shards, 4);
    assert_bitwise_equal(&mono, &one.fed, "mono vs 1 shard");
    assert_bitwise_equal(&one.fed, &two.fed, "1 vs 2 shards");
    assert_bitwise_equal(&one.fed, &four.fed, "1 vs 4 shards");
    for r in &four.fed.log.rounds {
        assert_eq!(r.shards, 4, "round telemetry records the shard count");
    }
}

#[test]
fn shard_count_does_not_change_results_under_quorum() {
    if !have_artifacts() {
        return;
    }
    // quorum rounds with a real straggler: the late fold crosses the
    // shard boundary too, and must stay bitwise-invariant in the shard
    // count (the straggler pattern itself is pinned by the fault spec)
    let mk = || {
        let mut cfg = base_cfg();
        cfg.n_clients = 4;
        cfg.clients_per_round = 4;
        cfg.rounds = 3;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let opts = |shards| ClusterOptions {
        fault: Some(FaultSpec::slow(1, Duration::from_millis(1_500))),
        shards,
        ..quorum_opts(2, 0.75, 600_000)
    };
    let one = cluster::run(mk(), &opts(1)).unwrap();
    let two = cluster::run(mk(), &opts(2)).unwrap();
    let four = cluster::run(mk(), &opts(4)).unwrap();
    assert_bitwise_equal(&one.fed, &two.fed, "quorum 1 vs 2 shards");
    assert_bitwise_equal(&one.fed, &four.fed, "quorum 1 vs 4 shards");
    for (ra, rb) in one.fed.log.rounds.iter().zip(&four.fed.log.rounds) {
        assert_eq!(ra.stragglers, rb.stragglers, "straggler pattern invariant");
        assert_eq!(ra.late_folds, rb.late_folds, "fold pattern invariant");
    }
    assert!(one.fed.log.total_late_folds() > 0, "the scenario exercises late folds");
}

#[test]
fn tcp_loopback_runs_and_matches_mem() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let mem = cluster::run(mk(), &mem_opts(2)).unwrap();
    let tcp = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Tcp, workers: Some(2), ..Default::default() },
    )
    .unwrap();
    assert_eq!(tcp.transport, "tcp");
    assert_bitwise_equal(&mem.fed, &tcp.fed, "mem vs tcp");
}

#[test]
fn netsim_shim_reports_round_timings() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let scenario = Scenario { name: "1/5 Mbps", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
    let out = cluster::run(
        cfg,
        &ClusterOptions {
            mode: ClusterMode::Mem,
            workers: Some(2),
            netsim: Some(SimProfile::uniform(scenario)),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.timings.len(), 2);
    for t in &out.timings {
        assert!(t.round_s > 0.0 && t.round_s.is_finite(), "{t:?}");
        assert!(t.comm_s > 0.0, "{t:?}");
    }
}

#[test]
fn dpo_over_cluster_parity() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.dpo = true;
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let clus = cluster::run(cfg, &mem_opts(2)).unwrap();
    assert_bitwise_equal(&mono, &clus.fed, "dpo");
    assert_eq!(
        mono.final_margin.unwrap().to_bits(),
        clus.fed.final_margin.unwrap().to_bits(),
        "dpo margin"
    );
}

// ---- quorum / straggler rounds ---------------------------------------------

fn quorum_opts(workers: usize, q: f64, timeout_ms: u64) -> ClusterOptions {
    ClusterOptions {
        mode: ClusterMode::Mem,
        workers: Some(workers),
        policy: RoundPolicy::Quorum { q, timeout: Duration::from_millis(timeout_ms) },
        ..Default::default()
    }
}

#[test]
fn full_quorum_matches_sync_and_monolith_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: Quorum{q: 1.0} with a timeout that
    // never fires IS the sync path, bit for bit — including against the
    // monolithic reference
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let mono = FedRunner::new(mk()).unwrap().run().unwrap();
    let sync = cluster::run(mk(), &mem_opts(3)).unwrap();
    let quorum = cluster::run(mk(), &quorum_opts(3, 1.0, 600_000)).unwrap();
    assert_bitwise_equal(&mono, &sync.fed, "mono vs sync");
    assert_bitwise_equal(&sync.fed, &quorum.fed, "sync vs quorum(1.0)");
    assert_eq!(quorum.fed.log.total_stragglers(), 0);
    assert_eq!(quorum.fed.log.total_late_folds(), 0);
    assert_eq!(quorum.fed.log.total_resampled(), 0);
}

#[test]
fn quorum_round_closes_past_straggler_and_discounts_its_uplink() {
    if !have_artifacts() {
        return;
    }
    // Every round samples the same 4-client cohort (n == N_t, rotor
    // sampling) on 2 workers: worker 1 hosts clients 1 and 3, and client
    // 1's injected sleep blocks client 3 behind it on that worker's
    // queue. Clients 0 and 2 report in milliseconds; the quorum of 3
    // completes when client 1's sleep ends — at which point the round
    // closes with client 3 as the straggler every single round. Client
    // 3's result lands during the NEXT round's collect and folds in with
    // the e^{−β·1} staleness discount.
    let mk = || {
        let mut cfg = base_cfg();
        cfg.n_clients = 4;
        cfg.clients_per_round = 4;
        cfg.rounds = 3;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let opts = |fault_delay_ms| ClusterOptions {
        fault: Some(FaultSpec::slow(1, Duration::from_millis(fault_delay_ms))),
        ..quorum_opts(2, 0.75, 600_000)
    };
    let a = cluster::run(mk(), &opts(1_500)).unwrap();

    let rounds = &a.fed.log.rounds;
    assert_eq!(rounds.len(), 3);
    for r in rounds {
        assert_eq!(r.cohort, 4, "round {}", r.round);
        assert_eq!(r.stragglers, 1, "round {}: quorum 3 of 4 leaves one behind", r.round);
        assert_eq!(r.resampled, 0, "round {}: generous timeout, no re-dispatch", r.round);
    }
    assert_eq!(rounds[0].late_folds, 0, "nothing buffered before round 0");
    assert_eq!(rounds[1].late_folds, 1, "round 0's straggler folds into round 1");
    assert_eq!(rounds[2].late_folds, 1, "round 1's straggler folds into round 2");
    assert!((a.fed.log.dropout_rate() - 0.25).abs() < 1e-12);
    assert!(a.fed.final_acc.is_finite());
    assert!(rounds.iter().all(|r| r.global_loss.is_finite()));

    // "deterministically": an identical run reproduces the same bits —
    // the straggler pattern is fixed by the fault spec, and the fold
    // order is (origin round, slot), not arrival order
    let b = cluster::run(mk(), &opts(1_500)).unwrap();
    assert_bitwise_equal(&a.fed, &b.fed, "quorum straggler run repeated");
    for (ra, rb) in a.fed.log.rounds.iter().zip(&b.fed.log.rounds) {
        assert_eq!(ra.stragglers, rb.stragglers);
        assert_eq!(ra.late_folds, rb.late_folds);
    }
}

#[test]
fn timed_out_slot_is_resampled_and_originals_still_win() {
    if !have_artifacts() {
        return;
    }
    // Single worker, client 2's uplink sleeps 1.5 s, slot timeout 200 ms:
    // the coordinator re-dispatches the outstanding slots to replacement
    // clients (deterministically drawn from the unsampled population)
    // while the originals grind on. The originals land first (FIFO on the
    // one worker), fill their slots, and close the full quorum — so the
    // final model must equal the plain sync run bit for bit even though
    // replacement downlinks were spent.
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 1;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let sync = cluster::run(mk(), &mem_opts(1)).unwrap();
    let quorum = cluster::run(
        mk(),
        &ClusterOptions {
            fault: Some(FaultSpec::slow(2, Duration::from_millis(1_500))),
            ..quorum_opts(1, 1.0, 200)
        },
    )
    .unwrap();

    let r = &quorum.fed.log.rounds[0];
    assert!(r.resampled >= 2, "both blocked slots re-dispatched at least once: {r:?}");
    assert_eq!(r.stragglers, 0, "every original slot eventually reported");
    assert_eq!(
        sync.fed.log.rounds[0].global_loss.to_bits(),
        r.global_loss.to_bits(),
        "originals filled every slot: loss identical to sync"
    );
    for (a, b) in sync.fed.final_lora.iter().zip(&quorum.fed.final_lora) {
        assert_eq!(a.to_bits(), b.to_bits(), "model identical to sync");
    }
    assert_eq!(sync.fed.final_acc.to_bits(), quorum.fed.final_acc.to_bits());
    // replacement downlinks are real traffic and must be accounted
    assert!(
        quorum.fed.log.rounds[0].down.bytes > sync.fed.log.rounds[0].down.bytes,
        "re-dispatch downlinks charged"
    );

    // With a second round, the losing racers' results (round-0 slots that
    // the originals already filled) arrive during round 1's collect —
    // the coordinator must reject them, NOT staleness-fold them: their
    // slots already contributed to round 0's aggregate.
    let mk2 = || {
        let mut cfg = mk();
        cfg.rounds = 2;
        cfg
    };
    let two = cluster::run(
        mk2(),
        &ClusterOptions {
            fault: Some(FaultSpec::slow(2, Duration::from_millis(1_500))),
            ..quorum_opts(1, 1.0, 200)
        },
    )
    .unwrap();
    assert_eq!(
        two.fed.log.rounds[1].late_folds,
        0,
        "a racer for an already-aggregated slot must never double-fold"
    );
}

// ---- late-buffer fold properties (no PJRT needed) --------------------------

fn test_kidx(n: usize) -> KindIndex {
    let kinds: Vec<LoraKind> = (0..n)
        .map(|i| if (i / 16) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect();
    KindIndex::new(&kinds)
}

/// Sparse wire bytes for `seg` of a `total`-parameter, `n_s`-segment
/// space, with ~1/4 of the segment's indices populated.
fn wire_for_segment(rng: &mut Rng, kidx: &KindIndex, total: usize, n_s: usize, seg: usize) -> Vec<u8> {
    let ranges = ecolora::model::segment_ranges(total, n_s);
    let range = ranges[seg].clone();
    let mut idx: Vec<u32> = (range.start..range.end)
        .filter(|_| rng.below(4) == 0)
        .map(|i| i as u32)
        .collect();
    if idx.is_empty() {
        idx.push(range.start as u32);
    }
    let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
    let sv = SparseVec { idx, vals };
    wire::encode(&sv, &range, kidx, (0.5, 0.5), Encoding::Golomb).unwrap()
}

/// A late SparseWire result for (origin round, slot) covering a random
/// segment.
fn late_result(
    rng: &mut Rng,
    kidx: &KindIndex,
    agg_total: usize,
    n_s: usize,
    origin: u64,
    slot: u32,
    client: u32,
) -> TrainResult {
    let seg = rng.below(n_s);
    let bytes = wire_for_segment(rng, kidx, agg_total, n_s, seg);
    TrainResult {
        round: origin,
        slot,
        client,
        segment: seg as u32,
        n_samples: rng.below(40) as u32 + 1,
        mean_loss: rng.normal(),
        k_a: 0.5,
        k_b: 0.5,
        exec_s: 0.0,
        stale_from_round: origin,
        up: UpPayload::SparseWire(bytes),
    }
}

#[test]
fn late_fold_is_arrival_order_invariant_and_matches_slot_ordered_fold() {
    propcheck(60, |rng| {
        let n_s = rng.below(3) + 1;
        let total = 32 * (rng.below(4) + n_s); // multiple of the kind blocks
        let kidx = test_kidx(total);
        let beta = 0.7;
        let now = 10u64;
        let n_clients = 8;
        let weights: Vec<f64> = (0..n_clients).map(|c| (c + 1) as f64).collect();

        // unique (origin round, slot) straggler set, arbitrary subset size
        let mut entries = Vec::new();
        for origin in 7..10u64 {
            for slot in 0..4u32 {
                if rng.below(2) == 0 {
                    let client = rng.below(n_clients) as u32;
                    entries.push(late_result(rng, &kidx, total, n_s, origin, slot, client));
                }
            }
        }

        // reference: slot-ordered fold straight into an aggregator
        let mut reference = SegmentAggregator::new(total, n_s);
        let mut sorted = entries.clone();
        sorted.sort_by_key(|e| (e.stale_from_round, e.slot));
        for e in &sorted {
            let UpPayload::SparseWire(bytes) = &e.up else { unreachable!() };
            let staleness = now - e.stale_from_round;
            let w = weights[e.client as usize] * staleness::stale_discount(beta, staleness);
            reference.add_wire(e.segment as usize, bytes, &kidx, w).unwrap();
        }
        let want = reference.finish();

        // property: ANY arrival order through the buffer gives those bits
        let mut shuffled = entries.clone();
        rng.shuffle(&mut shuffled);
        let mut buf = LateBuffer::new();
        for e in shuffled {
            assert!(buf.push(e), "unique (round, slot) entries are always kept");
        }
        let mut agg = RobustAggregator::new(Aggregator::Mean, total, n_s);
        let mut stats = AggStats::default();
        let ctx = FoldCtx { weights: &weights, beta, now_round: now, dense_params: 0 };
        let folded = buf.fold_into(&mut agg, &kidx, ctx, &mut stats);
        assert_eq!(folded.len(), sorted.len(), "every entry reports its folded identity");
        assert_eq!(stats.late_folds, sorted.len());
        assert_eq!(buf.dropped, 0);
        assert_eq!(buf.evicted, 0);
        assert!(buf.is_empty(), "fold drains the buffer");
        let (got, _) = agg.finish();

        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "late fold diverged at {i}");
        }
    });
}

#[test]
fn late_buffer_dedupes_and_rejects_unfoldable_entries() {
    let mut rng = Rng::new(7);
    let total = 64;
    let kidx = test_kidx(total);
    let weights = vec![10.0; 4];
    let mut buf = LateBuffer::new();

    let first = late_result(&mut rng, &kidx, total, 1, 5, 0, 1);
    assert!(buf.push(first.clone()));
    // same (origin round, slot): first arrival wins
    let dup = late_result(&mut rng, &kidx, total, 1, 5, 0, 2);
    assert!(!buf.push(dup));
    assert_eq!(buf.dropped, 1);

    // FLoRA modules cannot fold late
    let module = TrainResult {
        up: UpPayload::DenseModule(vec![0.0; total]),
        ..late_result(&mut rng, &kidx, total, 1, 5, 1, 3)
    };
    assert!(!buf.push(module));
    assert_eq!(buf.dropped, 2);

    // a segment id beyond the folding round's geometry is dropped, not fatal
    let misfit = TrainResult { segment: 9, ..late_result(&mut rng, &kidx, total, 1, 6, 2, 3) };
    assert!(buf.push(misfit));
    let mut agg = RobustAggregator::new(Aggregator::Mean, total, 1);
    let mut stats = AggStats::default();
    let ctx = FoldCtx { weights: &weights, beta: 0.7, now_round: 8, dense_params: 0 };
    let folded = buf.fold_into(&mut agg, &kidx, ctx, &mut stats);
    assert_eq!(folded, vec![(5, 0)], "only the clean entry reports a folded identity");
    assert_eq!(stats.late_folds, 1, "only the clean entry folds");
    assert_eq!(stats.orphaned, 1, "the misfit is surfaced in telemetry");
    assert_eq!(buf.dropped, 3);

    // the folded entry landed with a discounted weight: the aggregate is
    // scaled by e^{-beta*3} relative to an undiscounted fold
    let UpPayload::SparseWire(bytes) = &first.up else { unreachable!() };
    let mut plain = SegmentAggregator::new(total, 1);
    plain.add_wire(0, bytes, &kidx, 10.0).unwrap();
    let plain = plain.finish();
    let (discounted, _) = agg.finish();
    // weighted average over a single contribution is scale-invariant in
    // the weight — so compare against a mixed fold to see the discount
    assert_eq!(plain.len(), discounted.len());
    for (a, b) in plain.iter().zip(&discounted) {
        assert_eq!(a.to_bits(), b.to_bits(), "single-entry average ignores scale");
    }
}

#[test]
fn quorum_policy_arithmetic() {
    let q = |frac: f64, n: usize| {
        RoundPolicy::Quorum { q: frac, timeout: Duration::from_millis(100) }.quorum_of(n)
    };
    assert_eq!(q(1.0, 4), 4);
    assert_eq!(q(0.75, 4), 3);
    assert_eq!(q(0.8, 4), 4, "ceil(3.2) = 4");
    assert_eq!(q(0.7, 4), 3, "ceil(2.8) = 3");
    assert_eq!(q(0.01, 4), 1, "floor at one result");
    assert_eq!(q(0.5, 0), 0, "empty cohort needs nothing");
    assert_eq!(RoundPolicy::Sync.quorum_of(7), 7);
    assert_eq!(RoundPolicy::Sync.deadline_ms(), 0);
    assert_eq!(q(0.5, 10), 5);
    assert_eq!(
        RoundPolicy::Quorum { q: 0.5, timeout: Duration::from_millis(250) }.deadline_ms(),
        250
    );
}

// ---- router / shard plane (no PJRT needed) ---------------------------------

/// Run one synthetic round through a fresh `shards`-wide router: on-time
/// adds (in the given arrival order) plus late stragglers, then close.
fn route_round(
    shards: usize,
    total: usize,
    n_s: usize,
    round: u64,
    weights: &Arc<Vec<f64>>,
    kidx: &Arc<KindIndex>,
    adds: &[(u32, usize, f64, Vec<u8>)],
    lates: &[TrainResult],
) -> cluster::GatheredAgg {
    let mut router =
        Router::new(total, shards, weights.clone(), kidx.clone(), 0.7, 0, Aggregator::Mean)
            .unwrap();
    router.begin_round(round, n_s).unwrap();
    for (slot, seg, w, bytes) in adds {
        router
            .route(RoutedAdd {
                slot: *slot,
                segment: *seg,
                weight: *w,
                payload: Payload::Wire(bytes.clone()),
            })
            .unwrap();
    }
    for late in lates {
        router.route_late(late.clone()).unwrap();
    }
    let gathered = router.close_round(round).unwrap();
    router.shutdown().unwrap();
    gathered
}

#[test]
fn router_shard_count_is_bitwise_invariant() {
    // the ungated heart of the acceptance criteria: identical on-time +
    // late traffic through 1, 2 and 4 shards produces identical bits,
    // equal to a slot-ordered single-aggregator reference
    propcheck(10, |rng| {
        let n_s = rng.below(5) + 1;
        let total = 32 * (n_s + rng.below(3) + 1);
        let kidx = Arc::new(test_kidx(total));
        let weights: Arc<Vec<f64>> = Arc::new((0..8).map(|c| (c + 1) as f64).collect());
        let round = 5u64;
        let n_t = n_s + rng.below(4);

        // on-time adds: round-robin segments, shuffled arrival order
        let mut adds: Vec<(u32, usize, f64, Vec<u8>)> = (0..n_t)
            .map(|slot| {
                let seg = round_robin::segment_for(slot, round as usize, n_s);
                let w = (rng.below(8) + 1) as f64;
                (slot as u32, seg, w, wire_for_segment(rng, &kidx, total, n_s, seg))
            })
            .collect();
        rng.shuffle(&mut adds);

        // a few stragglers from earlier rounds
        let mut lates = Vec::new();
        for origin in 3..5u64 {
            if rng.below(2) == 0 {
                let client = rng.below(8) as u32;
                lates.push(late_result(rng, &kidx, total, n_s, origin, origin as u32, client));
            }
        }

        // reference: slot order through one whole-space aggregator, then
        // the buffered fold — tracking the expected comm accounting
        let mut reference = RobustAggregator::new(Aggregator::Mean, total, n_s);
        let mut expect_up = CommTotals::default();
        let mut sorted = adds.clone();
        sorted.sort_by_key(|a| a.0);
        for (_, seg, w, bytes) in &sorted {
            let params = reference.add_wire(*seg, bytes, &kidx, *w).unwrap();
            expect_up.add(params, bytes.len());
        }
        let mut buf = LateBuffer::new();
        for l in &lates {
            buf.push(l.clone());
        }
        let mut stats = AggStats::default();
        let ctx = FoldCtx { weights: &weights, beta: 0.7, now_round: round, dense_params: 0 };
        buf.fold_into(&mut reference, &kidx, ctx, &mut stats);
        expect_up.merge(&stats.up);
        let (want, _) = reference.finish();

        for shards in [1usize, 2, 4] {
            let got = route_round(shards, total, n_s, round, &weights, &kidx, &adds, &lates);
            assert_eq!(got.shards, shards);
            assert_eq!(got.delta.len(), want.len());
            for (i, (a, b)) in want.iter().zip(&got.delta).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{shards} shards diverged at {i}");
            }
            assert_eq!(got.stats.late_folds, stats.late_folds, "{shards} shards fold count");
            assert_eq!(got.stats.up, expect_up, "{shards} shards accounting");
        }
    });
}

#[test]
fn partial_coverage_round_reports_gaps_and_zero_deltas() {
    // quorum semantics at the router level: only slots 0 and 4 of a
    // 5-slot, 3-segment round report — segment 2 stays uncovered and its
    // delta span stays exactly zero, at every shard count
    let total = 96;
    let n_s = 3;
    let round = 0u64;
    let kidx = Arc::new(test_kidx(total));
    let weights: Arc<Vec<f64>> = Arc::new(vec![1.0; 8]);
    let mut rng = Rng::new(11);
    let adds: Vec<(u32, usize, f64, Vec<u8>)> = [0usize, 4]
        .iter()
        .map(|&slot| {
            let seg = round_robin::segment_for(slot, round as usize, n_s);
            (slot as u32, seg, 1.0, wire_for_segment(&mut rng, &kidx, total, n_s, seg))
        })
        .collect();
    let want_covered = round_robin::covered_segments(&[0, 4], round as usize, n_s);
    assert_eq!(want_covered, vec![true, true, false]);
    let seg_ranges = ecolora::model::segment_ranges(total, n_s);
    for shards in [1usize, 2, 3] {
        let got = route_round(shards, total, n_s, round, &weights, &kidx, &adds, &[]);
        assert_eq!(got.covered, want_covered, "{shards} shards coverage");
        for i in seg_ranges[2].clone() {
            assert_eq!(got.delta[i].to_bits(), 0.0f32.to_bits(), "{shards} shards: leak at {i}");
        }
    }
}

#[test]
fn shard_parallel_aggregation_beats_single_shard_wall_clock() {
    // the measured-speedup acceptance criterion: an aggregation-dominated
    // round (heavy decode volume) must close faster through 4 shard
    // threads than through 1. Both asserts are wall-clock — on a machine
    // with fewer cores than shard threads, each shard's elapsed time
    // absorbs the others' descheduling — so parity is checked everywhere
    // but the timing asserts only run when all 4 shards can truly run in
    // parallel.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let total = 64 * 1024;
    let n_s = 4;
    let kidx = Arc::new(test_kidx(total));
    let weights: Arc<Vec<f64>> = Arc::new(vec![1.0; 8]);
    let mut rng = Rng::new(3);
    // one heavy wire message per segment, re-routed many times under
    // distinct slots: ~1024 decodes of ~4k-index payloads
    let per_seg: Vec<Vec<u8>> =
        (0..n_s).map(|seg| wire_for_segment(&mut rng, &kidx, total, n_s, seg)).collect();
    let adds: Vec<(u32, usize, f64, Vec<u8>)> = (0..1024u32)
        .map(|slot| {
            let seg = (slot as usize) % n_s;
            (slot, seg, 1.0, per_seg[seg].clone())
        })
        .collect();

    let t0 = Instant::now();
    let one = route_round(1, total, n_s, 0, &weights, &kidx, &adds, &[]);
    let wall_one = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let four = route_round(4, total, n_s, 0, &weights, &kidx, &adds, &[]);
    let wall_four = t1.elapsed().as_secs_f64();

    for (a, b) in one.delta.iter().zip(&four.delta) {
        assert_eq!(a.to_bits(), b.to_bits(), "speedup must not cost parity");
    }
    if cores >= 4 && wall_one > 0.02 {
        assert!(
            four.shard_agg_s_max < one.shard_agg_s_max * 0.8,
            "per-shard critical path must shrink: 1 shard {:.1} ms vs 4 shards {:.1} ms",
            one.shard_agg_s_max * 1e3,
            four.shard_agg_s_max * 1e3,
        );
        assert!(
            wall_four < wall_one,
            "shard-parallel close must beat single-shard wall clock: {:.1} ms vs {:.1} ms",
            wall_four * 1e3,
            wall_one * 1e3,
        );
    }
}

// ---- client plane: mux vs threads (PJRT-gated) -----------------------------

fn plane_opts(workers: usize, plane: ClientPlane) -> ClusterOptions {
    ClusterOptions { client_plane: plane, ..mem_opts(workers) }
}

#[test]
fn mux_plane_matches_threads_plane_and_monolith_bitwise_under_sync() {
    if !have_artifacts() {
        return;
    }
    // the tentpole acceptance criterion: the event-driven mux plane is
    // bitwise-invisible — mux == threads == the monolithic reference,
    // with stateful sparse downlinks and error feedback across rounds
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig { n_s: 3, ..Default::default() });
        cfg
    };
    let mono = FedRunner::new(mk()).unwrap().run().unwrap();
    let threads = cluster::run(mk(), &plane_opts(3, ClientPlane::Threads)).unwrap();
    let mux = cluster::run(mk(), &plane_opts(3, ClientPlane::Mux)).unwrap();
    assert_bitwise_equal(&mono, &threads.fed, "mono vs threads plane");
    assert_bitwise_equal(&threads.fed, &mux.fed, "threads vs mux plane");
    // the compute-pool width is a pure throughput knob: one compute
    // thread must produce the same bits as the default pool
    let narrow = cluster::run(
        mk(),
        &ClusterOptions { mux_workers: Some(1), ..plane_opts(3, ClientPlane::Mux) },
    )
    .unwrap();
    assert_bitwise_equal(&mux.fed, &narrow.fed, "mux pool default vs 1");
    for r in &mux.fed.log.rounds {
        assert!(r.mux_workers >= 1, "mux rounds report the resolved pool width");
    }
    for r in &threads.fed.log.rounds {
        assert_eq!(r.mux_workers, 0, "threads rounds report no mux pool");
    }
}

#[test]
fn mux_plane_matches_threads_plane_under_quorum_with_straggler() {
    if !have_artifacts() {
        return;
    }
    // same scenario as the shard-invariance quorum test: client 1's
    // injected sleep makes client 3 (behind it on the same lane/worker)
    // the straggler every round. Lane ownership is ci % n_workers on
    // both planes and the mux keeps per-lane FIFO, so the straggler
    // pattern — and every deterministic column — must agree bitwise.
    let mk = || {
        let mut cfg = base_cfg();
        cfg.n_clients = 4;
        cfg.clients_per_round = 4;
        cfg.rounds = 3;
        cfg.sampling = sampling::Sampling::RoundRobinCohorts;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let opts = |plane| ClusterOptions {
        fault: Some(FaultSpec::slow(1, Duration::from_millis(1_500))),
        client_plane: plane,
        ..quorum_opts(2, 0.75, 600_000)
    };
    let threads = cluster::run(mk(), &opts(ClientPlane::Threads)).unwrap();
    let mux = cluster::run(mk(), &opts(ClientPlane::Mux)).unwrap();
    assert_bitwise_equal(&threads.fed, &mux.fed, "quorum threads vs mux");
    for (ra, rb) in threads.fed.log.rounds.iter().zip(&mux.fed.log.rounds) {
        assert_eq!(ra.stragglers, rb.stragglers, "straggler pattern invariant");
        assert_eq!(ra.late_folds, rb.late_folds, "fold pattern invariant");
    }
    assert!(mux.fed.log.total_late_folds() > 0, "the scenario exercises late folds");
}

#[test]
fn shared_engine_cache_matches_private_sessions_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the session-pool property: two clients trained through ONE cached
    // engine/session produce the same bits as two clients with fully
    // private engines — the cache is a resource optimization, never a
    // semantic one
    let cfg = base_cfg();
    let seed = Arc::new(WorldSeed::build(&cfg).unwrap());
    let mask_host = cfg.method.grad_mask(&seed.schema);

    let mut private = Vec::new();
    for ci in [0usize, 1] {
        let engine = Arc::new(ecolora::runtime::Engine::new(&cfg.artifacts_dir).unwrap());
        let session = ecolora::fed::session::Session::from_seed(engine, &seed).unwrap();
        let mask = session.upload_mask(&mask_host).unwrap();
        let mut client = seed.client_state(&cfg, ci);
        let mut rng = Rng::new(cfg.seed).fork(world::batch_salt(cfg.dpo, 0, ci));
        let (lora, loss) = world::local_train(
            &session, &cfg, &seed.ds, &seed.pairs, &mut client,
            seed.lora_init.clone(), &mut rng, &mask,
        )
        .unwrap();
        private.push((lora, loss));
    }

    let cache = EngineCache::new(&cfg, seed.clone()).unwrap();
    for (ci, (want_lora, want_loss)) in private.iter().enumerate() {
        let lease = cache.checkout().unwrap();
        let mut client = seed.client_state(&cfg, ci);
        let mut rng = Rng::new(cfg.seed).fork(world::batch_salt(cfg.dpo, 0, ci));
        let (lora, loss) = world::local_train(
            &lease.session, &cfg, &seed.ds, &seed.pairs, &mut client,
            seed.lora_init.clone(), &mut rng, &lease.mask,
        )
        .unwrap();
        assert_eq!(lora.len(), want_lora.len());
        for (i, (a, b)) in want_lora.iter().zip(&lora).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "client {ci}: shared vs private lora[{i}]");
        }
        assert_eq!(want_loss.to_bits(), loss.to_bits(), "client {ci}: loss");
    }
    let stats = cache.stats();
    assert_eq!(stats.misses, 1, "one session constructed");
    assert_eq!(stats.hits, 1, "the second client reused it");
    assert_eq!(cache.idle_sessions(), 1);
}

// ---- synthetic scale plane (no PJRT needed) --------------------------------

#[test]
fn synthetic_mux_plane_runs_end_to_end_and_is_worker_count_invariant() {
    // the artifact-free scale path: a full cluster run over the mux
    // plane with the synthetic trainer, deterministic across compute
    // topologies (worker/lane count AND mux pool width)
    let mk = || {
        let mut cfg = FedConfig::synthetic_profile(200);
        cfg.clients_per_round = 16;
        cfg
    };
    let opts = |workers, pool| ClusterOptions {
        workers: Some(workers),
        mux_workers: pool,
        ..Default::default()
    };
    let two = cluster::run(mk(), &opts(2, Some(1))).unwrap();
    let five = cluster::run(mk(), &opts(5, Some(3))).unwrap();
    assert_bitwise_equal(&two.fed, &five.fed, "synthetic 2 vs 5 lanes");
    assert_eq!(two.fed.log.rounds.len(), 2);
    for r in &two.fed.log.rounds {
        assert_eq!(r.population, 200);
        assert_eq!(r.active_cohort, 16);
        assert_eq!(r.cohort, 16);
        assert!(r.global_loss.is_finite() && r.global_loss > 0.0, "{r:?}");
        assert!(r.up.bytes > 0, "sparse uplinks carry real wire traffic");
        assert!(r.down.bytes > 0);
        assert!(r.sched_ms >= 0.0);
    }
    assert!(two.fed.final_acc.is_nan(), "synthetic runs have no eval model");
    assert!(two.fed.final_lora.iter().any(|&x| x != 0.0), "training moved the global");
}

#[test]
fn synthetic_preset_refuses_the_threads_plane() {
    let cfg = FedConfig::synthetic_profile(32);
    let err = cluster::run(
        cfg,
        &ClusterOptions {
            workers: Some(2),
            client_plane: ClientPlane::Threads,
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(err.to_string().contains("mux"), "unexpected error: {err:#}");
}

#[test]
fn late_admission_meter_evicts_deterministically_past_byte_cap() {
    // satellite: the global straggler admission meter. Flood it past
    // LATE_BUFFER_MAX_BYTES and every overflow arrival must be refused
    // AND counted — a function of arrival order alone, so the eviction
    // set is identical at any shard count or client-plane choice.
    let cfg = FedConfig::synthetic_profile(8);
    let mut control = ControlPlane::new(cfg, RoundPolicy::Sync).unwrap();
    // dense payloads cost 4 bytes/param: four of these fill the cap
    let params = LATE_BUFFER_MAX_BYTES / 4 / 4;
    let mk = |slot: u32| TrainResult {
        round: 0,
        slot,
        client: slot % 8,
        segment: 0,
        n_samples: 1,
        mean_loss: 1.0,
        k_a: 0.5,
        k_b: 0.5,
        exec_s: 0.0,
        stale_from_round: 0,
        up: UpPayload::DenseUpdate(vec![0.0; params]),
    };
    for slot in 0..4 {
        assert!(control.accept_late(mk(slot)).is_some(), "slot {slot} fits under the cap");
        assert_eq!(control.late_evicted(), 0);
    }
    for (i, slot) in (4..10).enumerate() {
        assert!(control.accept_late(mk(slot)).is_none(), "slot {slot} must be evicted");
        assert_eq!(control.late_evicted(), i + 1, "each overflow increments the meter");
    }
    // a tiny arrival still fails once the budget is exactly exhausted
    let tiny = TrainResult { up: UpPayload::DenseUpdate(vec![0.0; 1]), ..mk(10) };
    assert!(control.accept_late(tiny).is_none());
    assert_eq!(control.late_evicted(), 7);
}

// ---- gated scale smoke (ECOLORA_SCALE_TESTS=1) -----------------------------

fn scale_tests_enabled() -> bool {
    std::env::var("ECOLORA_SCALE_TESTS").map_or(false, |v| v == "1")
}

#[test]
fn scale_smoke_100k_clients_two_rounds() {
    if !scale_tests_enabled() {
        return;
    }
    let t0 = Instant::now();
    let out = cluster::run(
        FedConfig::synthetic_profile(100_000),
        &ClusterOptions { workers: Some(8), ..Default::default() },
    )
    .unwrap();
    let wall = t0.elapsed();
    assert_eq!(out.fed.log.rounds.len(), 2);
    for r in &out.fed.log.rounds {
        assert_eq!(r.population, 100_000);
        assert_eq!(r.active_cohort, 64);
        assert!(r.global_loss.is_finite());
    }
    assert!(
        wall < Duration::from_secs(300),
        "100k-client smoke must stay inside the CI budget: took {wall:?}"
    );
}

#[test]
fn scale_sched_cost_is_o_active_cohort_not_o_population() {
    if !scale_tests_enabled() {
        return;
    }
    // the O(active cohort) acceptance criterion: doubling the INACTIVE
    // population must not move per-round scheduling cost by more than
    // 10%. Medians over several rounds damp scheduler noise.
    let run = |population: usize| {
        let mut cfg = FedConfig::synthetic_profile(population);
        cfg.rounds = 7;
        cluster::run(cfg, &ClusterOptions { workers: Some(8), ..Default::default() }).unwrap()
    };
    let median_sched = |out: &cluster::ClusterOutcome| {
        // skip round 0 (lazy per-client state and wire scratch warm up)
        let mut xs: Vec<f64> =
            out.fed.log.rounds.iter().skip(1).map(|r| r.sched_ms).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };
    let small = run(100_000);
    let large = run(200_000);
    let (s, l) = (median_sched(&small), median_sched(&large));
    assert!(s > 0.0 && l > 0.0, "sched_ms must be measured ({s} vs {l})");
    assert!(
        l < s * 1.10 + 1.0,
        "doubling the inactive population moved median sched_ms {s:.3} -> {l:.3} \
         (>10% + 1ms slack): scheduling is not O(active cohort)"
    );
}
