//! Cluster integration.
//!
//! Transport/protocol behavior runs everywhere (no PJRT needed). The
//! parity suite — proving the message-passing cluster reproduces the
//! monolithic `FedRunner` BITWISE for a fixed seed — additionally needs
//! the tiny artifacts (`make artifacts`) and a `--features pjrt` build;
//! without them those tests no-op, same convention as integration_fed.

use ecolora::cluster::{self, ClusterMode, ClusterOptions};
use ecolora::fed::{EcoConfig, FedConfig, FedOutcome, FedRunner};
use ecolora::netsim::Scenario;
use ecolora::runtime::pjrt_available;

fn have_artifacts() -> bool {
    pjrt_available() && std::path::Path::new("artifacts/tiny.manifest.json").exists()
}

fn base_cfg() -> FedConfig {
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.lr = 2.0;
    cfg
}

fn assert_bitwise_equal(mono: &FedOutcome, clus: &FedOutcome, what: &str) {
    assert_eq!(mono.final_lora.len(), clus.final_lora.len(), "{what}: lora length");
    for (i, (a, b)) in mono.final_lora.iter().zip(&clus.final_lora).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: final_lora[{i}] {a} vs {b}");
    }
    assert_eq!(mono.final_acc.to_bits(), clus.final_acc.to_bits(), "{what}: final_acc");
    assert_eq!(mono.log.rounds.len(), clus.log.rounds.len(), "{what}: round count");
    for (mr, cr) in mono.log.rounds.iter().zip(&clus.log.rounds) {
        assert_eq!(mr.global_loss.to_bits(), cr.global_loss.to_bits(), "{what}: loss r{}", mr.round);
        assert_eq!(mr.up, cr.up, "{what}: uplink accounting r{}", mr.round);
        assert_eq!(mr.down, cr.down, "{what}: downlink accounting r{}", mr.round);
        assert_eq!(mr.eval_acc, cr.eval_acc, "{what}: eval r{}", mr.round);
        assert_eq!(mr.k_a, cr.k_a, "{what}: k_a r{}", mr.round);
    }
}

fn run_both(cfg: FedConfig, workers: usize, what: &str) {
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let opts =
        ClusterOptions { mode: ClusterMode::Mem, workers: Some(workers), netsim: None };
    let clus = cluster::run(cfg, &opts).unwrap();
    assert_eq!(clus.workers, workers);
    assert_bitwise_equal(&mono, &clus.fed, what);
}

#[test]
fn one_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // the acceptance-criteria case: one full EcoLoRA round over the
    // in-memory cluster == the monolithic path, bit for bit
    let mut cfg = base_cfg();
    cfg.rounds = 1;
    cfg.eco = Some(EcoConfig::default());
    run_both(cfg, 3, "eco 1 round");
}

#[test]
fn multi_round_eco_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    // staleness mixing, error-feedback residuals and the downlink
    // references all carry state across rounds — parity must survive them
    let mut cfg = base_cfg();
    cfg.eco = Some(EcoConfig { n_s: 3, ..Default::default() });
    run_both(cfg, 2, "eco 4 rounds");
}

#[test]
fn dense_fedit_parity_bitwise() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    run_both(cfg, 4, "dense fedit");
}

#[test]
fn flora_parity_bitwise_with_base_sync() {
    if !have_artifacts() {
        return;
    }
    // FLoRA merges into the frozen base every round: exercises BaseSync
    let mut cfg = base_cfg();
    cfg.method = ecolora::baselines::Method::FLoRa;
    cfg.rounds = 2;
    run_both(cfg, 2, "flora dense");
}

#[test]
fn worker_count_does_not_change_results() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let one = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Mem, workers: Some(1), netsim: None },
    )
    .unwrap();
    let four = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Mem, workers: Some(4), netsim: None },
    )
    .unwrap();
    assert_bitwise_equal(&one.fed, &four.fed, "1 vs 4 workers");
}

#[test]
fn tcp_loopback_runs_and_matches_mem() {
    if !have_artifacts() {
        return;
    }
    let mk = || {
        let mut cfg = base_cfg();
        cfg.rounds = 2;
        cfg.eco = Some(EcoConfig::default());
        cfg
    };
    let mem = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Mem, workers: Some(2), netsim: None },
    )
    .unwrap();
    let tcp = cluster::run(
        mk(),
        &ClusterOptions { mode: ClusterMode::Tcp, workers: Some(2), netsim: None },
    )
    .unwrap();
    assert_eq!(tcp.transport, "tcp");
    assert_bitwise_equal(&mem.fed, &tcp.fed, "mem vs tcp");
}

#[test]
fn netsim_shim_reports_round_timings() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let scenario = Scenario { name: "1/5 Mbps", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
    let out = cluster::run(
        cfg,
        &ClusterOptions { mode: ClusterMode::Mem, workers: Some(2), netsim: Some(scenario) },
    )
    .unwrap();
    assert_eq!(out.timings.len(), 2);
    for t in &out.timings {
        assert!(t.round_s > 0.0 && t.round_s.is_finite(), "{t:?}");
        assert!(t.comm_s > 0.0, "{t:?}");
    }
}

#[test]
fn dpo_over_cluster_parity() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base_cfg();
    cfg.dpo = true;
    cfg.rounds = 2;
    cfg.eco = Some(EcoConfig::default());
    let mono = FedRunner::new(cfg.clone()).unwrap().run().unwrap();
    let clus = cluster::run(
        cfg,
        &ClusterOptions { mode: ClusterMode::Mem, workers: Some(2), netsim: None },
    )
    .unwrap();
    assert_bitwise_equal(&mono, &clus.fed, "dpo");
    assert_eq!(
        mono.final_margin.unwrap().to_bits(),
        clus.fed.final_margin.unwrap().to_bits(),
        "dpo margin"
    );
}
