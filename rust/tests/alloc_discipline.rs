//! Steady-state allocation discipline (§Perf, codec hot path): after
//! warm-up, `Compressor::compress_into` + `encode_range_into` rounds and
//! `Decoder::decode_into` rounds must perform ZERO heap allocations —
//! every buffer in the sparsify→quantize→Golomb-encode pipeline is
//! reusable scratch.
//!
//! Gated behind `ECOLORA_ALLOC_TESTS=1` (the CI perf-smoke job sets it):
//! a counting global allocator needs a quiet, dedicated test process —
//! this file is its own integration-test binary with exactly these
//! tests, run with `cargo test --release --test alloc_discipline`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use ecolora::compress::{wire, Compressed, Compressor, Encoding, KindIndex, SparsMode, SparseVec};
use ecolora::model::LoraKind;
use ecolora::util::rng::Rng;

/// Pass-through allocator that counts alloc/realloc events while armed.
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
static REALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            REALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The counters are process-global and libtest runs `#[test]`s on
/// parallel threads, so the armed window of one test must never overlap
/// another test's setup allocations: every test body runs under this
/// lock (CI additionally passes `--test-threads=1`, but the lock makes
/// the binary safe to run bare).
static SERIAL: Mutex<()> = Mutex::new(());

fn gated() -> bool {
    if std::env::var_os("ECOLORA_ALLOC_TESTS").is_none() {
        eprintln!(
            "alloc_discipline: skipped (set ECOLORA_ALLOC_TESTS=1; needs a quiet dedicated process)"
        );
        return false;
    }
    true
}

fn arm() {
    ALLOCS.store(0, Ordering::SeqCst);
    REALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
}

fn disarm() -> (u64, u64) {
    ARMED.store(false, Ordering::SeqCst);
    (ALLOCS.load(Ordering::SeqCst), REALLOCS.load(Ordering::SeqCst))
}

fn setup(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>, Vec<f32>) {
    let kinds: Vec<LoraKind> = (0..n)
        .map(|i| if (i / 32) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect();
    let kidx = Arc::new(KindIndex::new(&kinds));
    let mut rng = Rng::new(404);
    let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.05).collect();
    (Arc::new(kinds), kidx, update)
}

#[test]
fn steady_state_compress_and_encode_do_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let n = 8_192;
    let (kinds, kidx, update) = setup(n);
    let mut comp = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx);
    let mut out = Compressed::default();
    let mut bytes = Vec::new();
    // full-vector range: the window size (and so every scratch high-water
    // mark) is identical round over round, while the error-feedback
    // rotation still changes WHICH indices are kept each round
    let range = 0..n;

    // warm up: grow every scratch buffer to its steady-state capacity
    for _ in 0..5 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        comp.encode_range_into(&out, &range, &mut bytes).unwrap();
    }
    // generous headroom for the payload buffer: the encoded length
    // breathes a few bytes round-to-round as the kept set rotates
    bytes.reserve(4096);

    arm();
    for _ in 0..3 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        comp.encode_range_into(&out, &range, &mut bytes).unwrap();
    }
    let (allocs, reallocs) = disarm();
    assert!(!out.sv.is_empty() && !bytes.is_empty(), "pipeline must have produced output");
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state compress+encode rounds allocated: {allocs} allocs, {reallocs} reallocs"
    );
}

#[test]
fn steady_state_decode_does_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let n = 8_192;
    let (kinds, kidx, update) = setup(n);
    let mut comp = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx.clone());
    let out = comp.compress(&update, 3.0, 2.0);
    let range = 0..n;
    let msg = comp.encode_range(&out, &range).unwrap();

    let mut dec = wire::Decoder::new();
    let mut sv = SparseVec::default();
    for _ in 0..3 {
        dec.decode_into(&msg, &range, &kidx, &mut sv).unwrap();
    }

    arm();
    for _ in 0..3 {
        dec.decode_into(&msg, &range, &kidx, &mut sv).unwrap();
    }
    let (allocs, reallocs) = disarm();
    assert_eq!(sv, out.sv, "decode must reconstruct the update");
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state decode rounds allocated: {allocs} allocs, {reallocs} reallocs"
    );
}
