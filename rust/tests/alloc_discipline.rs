//! Steady-state allocation discipline (§Perf, codec hot path): after
//! warm-up, `Compressor::compress_into` + `encode_range_into` rounds and
//! `Decoder::decode_into` rounds must perform ZERO heap allocations —
//! every buffer in the sparsify→quantize→Golomb-encode pipeline is
//! reusable scratch, and the OWNED payload `Vec<u8>` itself cycles
//! through a [`PayloadArena`] (take → encode → send → recycle). The
//! coordinator's round-journal append path rides the same bar:
//! journaling an uplink on the accept hot path must not allocate either.
//!
//! Gated behind `ECOLORA_ALLOC_TESTS=1` (the CI perf-smoke job sets it):
//! a counting global allocator needs a quiet, dedicated test process —
//! this file is its own integration-test binary with exactly these
//! tests, run with `cargo test --release --test alloc_discipline`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use ecolora::cluster::journal::{JournalWriter, Record, SyncPolicy};
use ecolora::cluster::protocol::Message;
use ecolora::compress::{
    wire, Compressed, Compressor, Encoding, KindIndex, PayloadArena, SparsMode, SparseVec,
};
use ecolora::model::LoraKind;
use ecolora::util::rng::Rng;

/// Pass-through allocator that counts alloc/realloc events while armed.
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
static REALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            REALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The counters are process-global and libtest runs `#[test]`s on
/// parallel threads, so the armed window of one test must never overlap
/// another test's setup allocations: every test body runs under this
/// lock (CI additionally passes `--test-threads=1`, but the lock makes
/// the binary safe to run bare).
static SERIAL: Mutex<()> = Mutex::new(());

fn gated() -> bool {
    if std::env::var_os("ECOLORA_ALLOC_TESTS").is_none() {
        eprintln!(
            "alloc_discipline: skipped (set ECOLORA_ALLOC_TESTS=1; needs a quiet dedicated process)"
        );
        return false;
    }
    true
}

fn arm() {
    ALLOCS.store(0, Ordering::SeqCst);
    REALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
}

fn disarm() -> (u64, u64) {
    ARMED.store(false, Ordering::SeqCst);
    (ALLOCS.load(Ordering::SeqCst), REALLOCS.load(Ordering::SeqCst))
}

fn setup(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>, Vec<f32>) {
    let kinds: Vec<LoraKind> = (0..n)
        .map(|i| if (i / 32) % 2 == 0 { LoraKind::A } else { LoraKind::B })
        .collect();
    let kidx = Arc::new(KindIndex::new(&kinds));
    let mut rng = Rng::new(404);
    let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.05).collect();
    (Arc::new(kinds), kidx, update)
}

#[test]
fn steady_state_compress_and_encode_do_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let n = 8_192;
    let (kinds, kidx, update) = setup(n);
    let mut comp = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx);
    let mut out = Compressed::default();
    let mut bytes = Vec::new();
    // full-vector range: the window size (and so every scratch high-water
    // mark) is identical round over round, while the error-feedback
    // rotation still changes WHICH indices are kept each round
    let range = 0..n;

    // warm up: grow every scratch buffer to its steady-state capacity
    for _ in 0..5 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        comp.encode_range_into(&out, &range, &mut bytes).unwrap();
    }
    // generous headroom for the payload buffer: the encoded length
    // breathes a few bytes round-to-round as the kept set rotates
    bytes.reserve(4096);

    arm();
    for _ in 0..3 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        comp.encode_range_into(&out, &range, &mut bytes).unwrap();
    }
    let (allocs, reallocs) = disarm();
    assert!(!out.sv.is_empty() && !bytes.is_empty(), "pipeline must have produced output");
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state compress+encode rounds allocated: {allocs} allocs, {reallocs} reallocs"
    );
}

#[test]
fn steady_state_arena_pooled_payload_cycle_does_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let n = 8_192;
    let (kinds, kidx, update) = setup(n);
    let mut comp = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx);
    let mut out = Compressed::default();
    // the participant's cycle: the payload Vec leaves the arena, would be
    // sent over a transport, and comes back via recycle — with the pool
    // warm, even the OWNED payload buffer stops allocating
    let mut arena = PayloadArena::new(4);
    let range = 0..n;

    for _ in 0..5 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        let bytes = comp.encode_range_arena(&out, &range, &mut arena).unwrap();
        arena.recycle(bytes);
    }

    arm();
    for _ in 0..3 {
        comp.compress_into(&update, 3.0, 2.0, &mut out);
        let bytes = comp.encode_range_arena(&out, &range, &mut arena).unwrap();
        assert!(!bytes.is_empty());
        arena.recycle(bytes);
    }
    let (allocs, reallocs) = disarm();
    assert!(arena.watermark() > 0 && arena.pooled() > 0, "arena must be warm");
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state arena payload cycle allocated: {allocs} allocs, {reallocs} reallocs"
    );
}

#[test]
fn steady_state_decode_does_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let n = 8_192;
    let (kinds, kidx, update) = setup(n);
    let mut comp = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx.clone());
    let out = comp.compress(&update, 3.0, 2.0);
    let range = 0..n;
    let msg = comp.encode_range(&out, &range).unwrap();

    let mut dec = wire::Decoder::new();
    let mut sv = SparseVec::default();
    for _ in 0..3 {
        dec.decode_into(&msg, &range, &kidx, &mut sv).unwrap();
    }

    arm();
    for _ in 0..3 {
        dec.decode_into(&msg, &range, &kidx, &mut sv).unwrap();
    }
    let (allocs, reallocs) = disarm();
    assert_eq!(sv, out.sv, "decode must reconstruct the update");
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state decode rounds allocated: {allocs} allocs, {reallocs} reallocs"
    );
}

#[test]
fn steady_state_journal_appends_do_not_allocate() {
    let _serial = SERIAL.lock().unwrap();
    if !gated() {
        return;
    }
    let path =
        std::env::temp_dir().join(format!("ecolora-alloc-journal-{}.bin", std::process::id()));
    let genesis = Record::Genesis {
        config_digest: 0xE7,
        n_workers: 2,
        shards: 1,
        policy_tag: 0,
        quorum_bits: 0,
        timeout_ms: 0,
    };
    let mut jw = JournalWriter::create(&path, SyncPolicy::Round, &genesis).unwrap();

    // the per-round record set the serve loop appends, pre-built so the
    // armed window measures only the writer (records with heap-backed
    // fields are reused by reference; Dispatch/DownlinkLost are inline)
    let open = Record::RoundOpen { rng_state: [1, 2, 3, 4], alive: vec![true, true] };
    let close = Record::RoundClose {
        active_cohort: 4,
        mux_workers: 2,
        worker_drops: 0,
        worker_rejoins: 0,
        journal_bytes: 0,
        global_digest: 0xD1_6E57,
        shard_digests: vec![7, 11],
    };
    // a bulky envelope standing in for a compressed TrainResult uplink
    let env = Message::Join {
        token: vec![0xAB; 2048],
        config_digest: 0xE7,
        requested_worker: 0,
        build: "alloc-probe".into(),
    }
    .to_envelope();

    let round = |jw: &mut JournalWriter, t: u64| {
        jw.append(t, &open).unwrap();
        for slot in 0..4u32 {
            jw.append(t, &Record::Dispatch { slot, client: slot, worker: slot % 2, down_seq: t })
                .unwrap();
        }
        for _ in 0..4 {
            jw.append_uplink(t, false, &env).unwrap();
        }
        jw.append(t, &Record::DownlinkLost { client: 3 }).unwrap();
        jw.append(t, &close).unwrap();
        jw.commit_round().unwrap();
    };

    // warm up: grow the scratch buffer to steady-state capacity
    for t in 0..5 {
        round(&mut jw, t);
    }

    arm();
    for t in 5..8 {
        round(&mut jw, t);
    }
    let (allocs, reallocs) = disarm();
    assert!(jw.round_bytes() > 0, "the armed rounds must have appended bytes");
    drop(jw);
    let _ = std::fs::remove_file(&path);
    assert_eq!(
        (allocs, reallocs),
        (0, 0),
        "steady-state journal append rounds allocated: {allocs} allocs, {reallocs} reallocs"
    );
}
