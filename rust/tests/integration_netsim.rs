//! Integration: netsim replay of a real training log — the Figure 3
//! measurement path. A federated run produces per-round byte counts and
//! compute times; the simulator turns them into comm/compute wall-clock
//! under each of the paper's bandwidth scenarios.

use ecolora::fed::{EcoConfig, FedConfig, FedRunner};
use ecolora::metrics::RunLog;
use ecolora::netsim::{NetSim, RoundPlan, PAPER_SCENARIOS};

fn have_artifacts() -> bool {
    ecolora::runtime::pjrt_available()
        && std::path::Path::new("artifacts/tiny.manifest.json").exists()
}

/// Replay a run log through a bandwidth scenario (mirrors
/// `reports::replay_network`, duplicated here to keep the test independent).
fn replay(log: &RunLog, n_t: usize, scenario: ecolora::netsim::Scenario) -> (f64, f64) {
    let mut sim = NetSim::homogeneous(n_t, scenario.link());
    let mut comm = 0.0;
    let mut compute = 0.0;
    for r in &log.rounds {
        let plan = RoundPlan {
            dl_bytes: (r.down.bytes as usize) / n_t.max(1),
            compute_s: r.compute_s,
            ul_bytes: (r.up.bytes as usize) / n_t.max(1),
        };
        let clients: Vec<usize> = (0..n_t).collect();
        let t = sim.run_round(&clients, &vec![plan; n_t]);
        comm += t.comm_s;
        compute += t.compute_s;
    }
    (comm, compute)
}

#[test]
fn ecolora_comm_time_beats_dense_in_every_scenario() {
    if !have_artifacts() {
        return;
    }
    let run = |eco: Option<EcoConfig>| {
        let mut cfg = FedConfig::test_profile("tiny");
        cfg.lr = 2.0;
        cfg.rounds = 3;
        cfg.eco = eco;
        FedRunner::new(cfg).unwrap().run().unwrap().log
    };
    let dense = run(None);
    let eco = run(Some(EcoConfig::default()));

    for sc in PAPER_SCENARIOS {
        let (dense_comm, _) = replay(&dense, 4, sc);
        let (eco_comm, _) = replay(&eco, 4, sc);
        assert!(
            eco_comm < dense_comm,
            "{}: eco {eco_comm:.2}s vs dense {dense_comm:.2}s",
            sc.name
        );
    }
}

#[test]
fn comm_share_grows_as_bandwidth_shrinks() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = FedConfig::test_profile("tiny");
    cfg.lr = 2.0;
    cfg.rounds = 2;
    let log = FedRunner::new(cfg).unwrap().run().unwrap().log;

    let mut shares = vec![];
    for sc in PAPER_SCENARIOS {
        let (comm, compute) = replay(&log, 4, sc);
        shares.push(comm / (comm + compute));
    }
    // scenarios are ordered slowest -> fastest: comm share must decrease
    for w in shares.windows(2) {
        assert!(w[0] > w[1], "shares {shares:?}");
    }
}
