//! The three federated LoRA fine-tuning methods EcoLoRA is applied to
//! (paper §4.1 Baselines). EcoLoRA itself is a wrapper — `FedConfig.eco`
//! switches the communication layer; the `Method` here fixes what is
//! trained and how the server aggregates.

use crate::model::Schema;

/// Base federated fine-tuning method.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// FedIT (Zhang et al. 2024): FedAvg over full LoRA modules.
    FedIt,
    /// FLoRA (Wang et al. 2024): stacking aggregation — client modules are
    /// merged into the base each round and clients restart from a fresh
    /// LoRA init; the server re-distributes the stacked modules, so the
    /// downlink carries N_t × module parameters.
    FLoRa,
    /// FFA-LoRA (Sun et al. 2024): A frozen at a shared random init, only
    /// B is trained and communicated (half the parameters).
    FfaLora,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::FedIt => "FedIT",
            Method::FLoRa => "FLoRA",
            Method::FfaLora => "FFA-LoRA",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "fedit" => Some(Method::FedIt),
            "flora" => Some(Method::FLoRa),
            "ffa" | "ffa-lora" | "ffalora" => Some(Method::FfaLora),
            _ => None,
        }
    }

    /// Parameters one client UPLOADS per round WITHOUT EcoLoRA.
    pub fn dense_upload_params(self, schema: &Schema) -> usize {
        match self {
            Method::FedIt | Method::FLoRa => schema.lora_total,
            // A never changes after the shared init — only B travels.
            Method::FfaLora => schema.lora_total / 2,
        }
    }

    /// Parameters one client DOWNLOADS per round WITHOUT EcoLoRA.
    /// (`n_t` = sampled clients, for FLoRA's stacked re-distribution.)
    pub fn dense_download_params(self, schema: &Schema, n_t: usize) -> usize {
        match self {
            Method::FedIt => schema.lora_total,
            Method::FLoRa => n_t * schema.lora_total,
            Method::FfaLora => schema.lora_total / 2,
        }
    }

    /// Does the client restart from a fresh LoRA each round?
    pub fn restarts_lora(self) -> bool {
        matches!(self, Method::FLoRa)
    }

    /// Gradient mask: which LoRA entries train.
    pub fn grad_mask(self, schema: &Schema) -> Vec<f32> {
        match self {
            Method::FfaLora => schema.mask_b_only(),
            _ => schema.mask_all(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LoraKind, ModelConfig, Schema, TensorSpec};

    fn schema() -> Schema {
        Schema {
            preset: "t".into(),
            init_std: 0.02,
            config: ModelConfig {
                vocab: 16, d_model: 4, n_layers: 1, n_heads: 1, d_ff: 8,
                seq_len: 8, rank: 2, lora_alpha: 4.0, lora_scale: 2.0,
                batch: 2, eval_batch: 4,
            },
            base_total: 4,
            lora_total: 16,
            base_tensors: vec![TensorSpec {
                name: "w".into(), shape: vec![4], offset: 0, size: 4,
                init: "normal".into(), kind: None, layer: -1,
            }],
            lora_tensors: vec![
                TensorSpec { name: "a".into(), shape: vec![4, 2], offset: 0, size: 8,
                             init: "normal".into(), kind: Some(LoraKind::A), layer: 0 },
                TensorSpec { name: "b".into(), shape: vec![2, 4], offset: 8, size: 8,
                             init: "zeros".into(), kind: Some(LoraKind::B), layer: 0 },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn comm_accounting_per_method() {
        let s = schema();
        assert_eq!(Method::FedIt.dense_upload_params(&s), 16);
        assert_eq!(Method::FfaLora.dense_upload_params(&s), 8);
        assert_eq!(Method::FLoRa.dense_download_params(&s, 10), 160);
        assert_eq!(Method::FedIt.dense_download_params(&s, 10), 16);
        assert_eq!(Method::FfaLora.dense_download_params(&s, 10), 8);
    }

    #[test]
    fn masks_match_method() {
        let s = schema();
        assert_eq!(Method::FedIt.grad_mask(&s).iter().sum::<f32>(), 16.0);
        assert_eq!(Method::FfaLora.grad_mask(&s).iter().sum::<f32>(), 8.0);
        assert!(Method::FLoRa.restarts_lora());
        assert!(!Method::FedIt.restarts_lora());
    }

    #[test]
    fn parse_names() {
        assert_eq!(Method::parse("FedIT"), Some(Method::FedIt));
        assert_eq!(Method::parse("ffa-lora"), Some(Method::FfaLora));
        assert_eq!(Method::parse("flora"), Some(Method::FLoRa));
        assert_eq!(Method::parse("zzz"), None);
    }
}
