//! Downlink compression state (paper §3.4 applies sparsification "for both
//! uploading and downloading").
//!
//! The server keeps, per client, (a) a reference copy of the global model
//! as that client last reconstructed it and (b) an error-feedback
//! compressor. Broadcasting to client i sends the sparsified, Golomb-coded
//! delta `global − ref_i`; both sides then advance `ref_i` by the decoded
//! delta, so server and client stay bit-identical without ever sending the
//! dense vector. Clients idle for many rounds simply get a denser delta
//! (their residual-corrected gap is larger).

use std::sync::Arc;

use anyhow::Result;

use crate::compress::{wire, Compressed, Compressor, Encoding, KindIndex, SparsMode};
use crate::model::LoraKind;
use crate::util::simd;

/// Per-client downlink channel.
struct Channel {
    /// Global model as the client last reconstructed it.
    reference: Vec<f32>,
    comp: Compressor,
}

/// The exact client-bound bytes of one broadcast — what the cluster
/// transport ships. The monolithic runner ignores this and uses
/// `Broadcast::reconstructed` directly.
#[derive(Debug, Clone)]
pub enum DownWire {
    /// Golomb/fixed sparse delta message over the full vector range.
    Sparse(Vec<u8>),
    /// Dense f16 bits of the full-vector delta (`SparsMode::Off`).
    DenseF16(Vec<u8>),
}

/// What one broadcast produced.
pub struct Broadcast {
    /// The client's reconstruction of the global model.
    pub reconstructed: Vec<f32>,
    /// Transmitted parameter count.
    pub params: usize,
    /// Exact wire bytes.
    pub bytes: usize,
    /// The client-bound message itself (present iff `want_wire` was set —
    /// the monolithic runner skips materializing it).
    pub wire: Option<DownWire>,
}

/// Apply one sparse downlink message to `reference`, reusing the
/// caller's decoder + `SparseVec` scratch (the participant hot path:
/// allocation-free once warm). Returns the transmitted parameter count.
pub fn apply_sparse_down(
    bytes: &[u8],
    reference: &mut [f32],
    kidx: &KindIndex,
    dec: &mut wire::Decoder,
    sv: &mut wire::SparseVec,
) -> Result<usize> {
    dec.decode_into(bytes, &(0..reference.len()), kidx, sv)?;
    sv.add_to(reference);
    Ok(sv.len())
}

/// Apply one dense-f16 downlink delta to `reference` (allocation-free).
pub fn apply_dense_f16(bytes: &[u8], reference: &mut [f32]) -> Result<usize> {
    anyhow::ensure!(
        bytes.len() == 2 * reference.len(),
        "downlink dense f16 payload: {} bytes for {} params",
        bytes.len(),
        reference.len()
    );
    simd::f16le_add_to_f32(bytes, reference);
    Ok(reference.len())
}

/// Client-side mirror of [`DownlinkState::broadcast`]: advance the local
/// `reference` copy by the decoded delta. Server and client apply the SAME
/// quantized values, so the two references stay bit-identical. Returns the
/// transmitted parameter count.
pub fn apply_down_wire(
    msg: &DownWire,
    reference: &mut [f32],
    kidx: &KindIndex,
) -> Result<usize> {
    match msg {
        DownWire::Sparse(bytes) => {
            let mut dec = wire::Decoder::new();
            let mut sv = wire::SparseVec::default();
            apply_sparse_down(bytes, reference, kidx, &mut dec, &mut sv)
        }
        DownWire::DenseF16(bytes) => apply_dense_f16(bytes, reference),
    }
}

pub struct DownlinkState {
    channels: Vec<Option<Channel>>,
    kinds: Arc<Vec<LoraKind>>,
    kidx: Arc<KindIndex>,
    mode: SparsMode,
    encoding: Encoding,
    init: Vec<f32>,
    /// Broadcast scratch (channels are served serially): the dense delta
    /// `global − ref_i` and the compression output, reused every call.
    delta: Vec<f32>,
    out: Compressed,
}

impl DownlinkState {
    /// `init` is the LoRA state every client starts from (distributed with
    /// the base model, not counted — paper Appendix A).
    pub fn new(
        n_clients: usize,
        init: Vec<f32>,
        mode: SparsMode,
        encoding: Encoding,
        kinds: Arc<Vec<LoraKind>>,
        kidx: Arc<KindIndex>,
    ) -> Self {
        DownlinkState {
            channels: (0..n_clients).map(|_| None).collect(),
            kinds,
            kidx,
            mode,
            encoding,
            init,
            delta: Vec::new(),
            out: Compressed::default(),
        }
    }

    /// Broadcast `global` to `client`, compressed against its reference.
    /// `l0`/`l_prev` drive the adaptive schedule (Eq. 4). `want_wire`
    /// materializes the client-bound message (cluster transports); the
    /// in-process runner passes false and reads `reconstructed` directly.
    pub fn broadcast(
        &mut self,
        client: usize,
        global: &[f32],
        l0: f64,
        l_prev: f64,
        want_wire: bool,
    ) -> Result<Broadcast> {
        let ch = self.channels[client].get_or_insert_with(|| Channel {
            reference: self.init.clone(),
            comp: Compressor::new(self.mode, self.encoding, self.kinds.clone(), self.kidx.clone()),
        });
        let n = global.len();
        let delta = &mut self.delta;
        delta.clear();
        delta.reserve(n);
        delta.extend(global.iter().zip(&ch.reference).map(|(g, r)| g - r));
        ch.comp.compress_into(delta, l0, l_prev, &mut self.out);
        let out = &self.out;
        let range = 0..n;
        let (bytes, msg) = match &out.dense {
            // unsparsified downlink: dense f16 of the full vector. The sv
            // values ARE the quantized dense delta, so shipping their f16
            // bits reconstructs exactly what `add_to` applies server-side.
            Some(d) => {
                let msg = want_wire.then(|| {
                    let mut w = Vec::with_capacity(2 * d.len());
                    simd::f32_to_f16le_append(d, &mut w);
                    DownWire::DenseF16(w)
                });
                (crate::compress::dense_bytes(d.len()), msg)
            }
            None => {
                // the sparse message is built anyway for exact byte counts
                let mut enc = Vec::new();
                ch.comp.encode_range_into(out, &range, &mut enc)?;
                (enc.len(), want_wire.then(|| DownWire::Sparse(enc)))
            }
        };
        out.sv.add_to(&mut ch.reference);
        Ok(Broadcast {
            reconstructed: ch.reference.clone(),
            params: out.sv.len(),
            bytes,
            wire: msg,
        })
    }

    /// The client's current reference (test hook / reconnection).
    pub fn reference(&self, client: usize) -> Option<&[f32]> {
        self.channels[client].as_ref().map(|c| c.reference.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::AdaptiveSparsifier;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>) {
        let kinds: Vec<LoraKind> = (0..n)
            .map(|i| if (i / 16) % 2 == 0 { LoraKind::A } else { LoraKind::B })
            .collect();
        let kidx = KindIndex::new(&kinds);
        (Arc::new(kinds), Arc::new(kidx))
    }

    #[test]
    fn repeated_broadcasts_converge_to_global() {
        let n = 512;
        let (kinds, kidx) = setup(n);
        let mut dl = DownlinkState::new(
            2,
            vec![0.0; n],
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds,
            kidx,
        );
        let mut rng = Rng::new(0);
        let global: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        // broadcasting the SAME global repeatedly: error feedback must make
        // the reference converge to it (up to f16 precision)
        let mut err = f64::INFINITY;
        for _ in 0..6 {
            let b = dl.broadcast(0, &global, 3.0, 3.0, false).unwrap();
            let e: f64 = b
                .reconstructed
                .iter()
                .zip(&global)
                .map(|(r, g)| ((r - g) as f64).abs())
                .sum();
            assert!(e <= err + 1e-9);
            err = e;
        }
        assert!(err / (n as f64) < 1e-3, "mean err {}", err / n as f64);
    }

    #[test]
    fn sparse_downlink_cheaper_than_dense_for_incremental_updates() {
        let n = 4096;
        let (kinds, kidx) = setup(n);
        let mut dl = DownlinkState::new(
            1,
            vec![0.0; n],
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds.clone(),
            kidx.clone(),
        );
        let mut rng = Rng::new(1);
        let mut global: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        dl.broadcast(0, &global, 3.0, 3.0, false).unwrap();
        // small incremental change late in training -> few params, few bytes
        for v in global.iter_mut().take(100) {
            *v += 0.5;
        }
        let b = dl.broadcast(0, &global, 3.0, 0.5, false).unwrap();
        assert!(b.bytes < crate::compress::dense_bytes(n), "sparse {} bytes", b.bytes);
        assert!(b.params < n);
    }

    #[test]
    fn off_mode_counts_dense_bytes() {
        let n = 128;
        let (kinds, kidx) = setup(n);
        let mut dl =
            DownlinkState::new(1, vec![0.0; n], SparsMode::Off, Encoding::Golomb, kinds, kidx);
        let global = vec![1.0f32; n];
        let b = dl.broadcast(0, &global, 3.0, 3.0, false).unwrap();
        assert_eq!(b.bytes, crate::compress::dense_bytes(n));
        assert_eq!(b.params, n);
    }

    #[test]
    fn client_side_apply_matches_server_reconstruction() {
        // the cluster participant replays the wire message; its reference
        // must track the server's reconstruction bit-for-bit
        for mode in [SparsMode::Adaptive(AdaptiveSparsifier::default()), SparsMode::Off] {
            let n = 256;
            let (kinds, kidx) = setup(n);
            let mut dl =
                DownlinkState::new(1, vec![0.0; n], mode, Encoding::Golomb, kinds, kidx.clone());
            let mut reference = vec![0.0f32; n];
            let mut rng = Rng::new(3);
            let mut global: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            for round in 0..4u32 {
                let b = dl.broadcast(0, &global, 3.0, 2.0, true).unwrap();
                let msg = b.wire.as_ref().expect("want_wire returns the message");
                let params = apply_down_wire(msg, &mut reference, &kidx).unwrap();
                assert_eq!(params, b.params, "{mode:?} round {round}");
                for (r, s) in reference.iter().zip(&b.reconstructed) {
                    assert_eq!(r.to_bits(), s.to_bits(), "{mode:?} round {round}");
                }
                for v in global.iter_mut().take(30) {
                    *v += 0.1 * (round + 1) as f32;
                }
            }
        }
    }

    #[test]
    fn channels_are_independent_per_client() {
        let n = 64;
        let (kinds, kidx) = setup(n);
        let mut dl = DownlinkState::new(
            2,
            vec![0.0; n],
            SparsMode::Fixed(0.5),
            Encoding::Golomb,
            kinds,
            kidx,
        );
        let g1 = vec![1.0f32; n];
        dl.broadcast(0, &g1, 3.0, 3.0, false).unwrap();
        assert!(dl.reference(0).is_some());
        assert!(dl.reference(1).is_none());
    }
}
