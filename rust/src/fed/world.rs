//! Shared deterministic world construction for the monolithic `FedRunner`
//! and the cluster coordinator/participant processes.
//!
//! The cluster ships only wire payloads — never host state — so every
//! peer must materialize an IDENTICAL world (model session, synthetic
//! corpus, partition, preference pairs, LoRA init) from the `FedConfig`
//! alone. `Rng::fork` advances the root stream, which makes the fork
//! ORDER below part of the protocol: reordering any call breaks bitwise
//! parity between the monolithic and cluster paths (and across cluster
//! peers). `tests/integration_cluster.rs` enforces the parity.
//!
//! Fork schedule (root = `Rng::new(cfg.seed)`):
//!   1 → session base init, 2 → corpus, 3 → partition,
//!   9 → preference pairs (DPO only), 4 → LoRA init,
//!   then (coordinator/monolith only) 5 → eval set, 6 → DPO eval set,
//!   then per round t: 1000+t → sampling, 2000+t → FLoRA restart init,
//!   (3000|4000)+t·131+ci → per-client batch stream.
//!
//! Streams for timeout-driven re-dispatch ([`resample_rng`]) deliberately
//! do NOT come from the root stream: whether a slot times out depends on
//! wall-clock events, and advancing the root on one would shift every
//! later fork — breaking the bitwise parity between a quorum run with no
//! timeouts and the synchronous path.
//!
//! The sharded aggregation plane (`cluster::{router, shard}`) consumes NO
//! randomness at all: shard geometry is a pure function of (n_s, shards),
//! so the shard count can never perturb any stream above — `--shards N`
//! parity depends on it.

#![warn(missing_docs)]

use std::sync::Arc;

use anyhow::Result;

use crate::compress::{Compressor, KindIndex};
use crate::data::{self, corpus, preference, ClientData, Dataset};
use crate::model::{LoraKind, Schema};
use crate::util::rng::Rng;
use crate::xla::PjRtBuffer;

use super::session::Session;
use super::FedConfig;

/// One logical client's persistent local state (owned by the monolithic
/// runner, or by whichever cluster participant hosts the client).
pub struct ClientState {
    /// Last local LoRA vector (staleness mixing input, Eq. 3).
    pub lora: Vec<f32>,
    /// Round the client last participated in (τ).
    pub tau: u64,
    /// Uplink compressor with error-feedback residual (EcoLoRA only).
    pub comp: Option<Compressor>,
    /// Local data view with epoch-shuffled batching.
    pub data: ClientData,
    /// Preference pairs assigned to this client (DPO only).
    pub pref_indices: Vec<usize>,
    /// FedAvg weight n_i (≥ 1 even for empty clients).
    pub n_samples: usize,
}

/// Everything deterministically derivable from a `FedConfig`.
pub struct World {
    /// Model session (PJRT engine + compiled artifacts + frozen base).
    pub session: Session,
    /// Synthetic training corpus.
    pub ds: Dataset,
    /// Corpus shape parameters (vocab, sequence length, …).
    pub ccfg: corpus::CorpusCfg,
    /// Preference pairs (DPO only; empty otherwise).
    pub pairs: Vec<preference::PrefPair>,
    /// Per-client sample-index partition.
    pub parts: Vec<Vec<usize>>,
    /// Per-parameter LoRA matrix family (A or B).
    pub kinds: Arc<Vec<LoraKind>>,
    /// Kind-wise index over the flat LoRA vector (wire codec input).
    pub kidx: Arc<KindIndex>,
    /// Initial LoRA vector every client starts from.
    pub lora_init: Vec<f32>,
    /// Root RNG, positioned just after the setup forks (see module docs).
    pub rng: Rng,
}

/// The session-free kernel of a [`World`]: everything deterministically
/// derivable from a `FedConfig` WITHOUT touching PJRT. The massive-scale
/// mux plane builds exactly one of these per host and shares it (via
/// `Arc`) across 10⁴–10⁶ lazily-materialized client states; paths that
/// need compiled compute layer a [`Session`] on top with
/// [`Session::from_seed`].
///
/// `WorldSeed::build` consumes the root RNG stream in EXACTLY the order
/// `World::build` always has (fork 1 → base init, 2 → corpus, 3 →
/// partition, 9 → pairs, 4 → LoRA init), so a seed-built world is
/// bitwise-identical to a session-built one.
pub struct WorldSeed {
    /// Model parameter schema (manifest-loaded, or [`Schema::synthetic`]).
    pub schema: Arc<Schema>,
    /// Host copy of the frozen base weights (random init, or the
    /// checkpoint overlay when `cfg.base_checkpoint` is set).
    pub base_host: Vec<f32>,
    /// Synthetic training corpus.
    pub ds: Dataset,
    /// Corpus shape parameters (vocab, sequence length, …).
    pub ccfg: corpus::CorpusCfg,
    /// Preference pairs (DPO only; empty otherwise).
    pub pairs: Vec<preference::PrefPair>,
    /// Per-client sample-index partition.
    pub parts: Vec<Vec<usize>>,
    /// Per-parameter LoRA matrix family (A or B).
    pub kinds: Arc<Vec<LoraKind>>,
    /// Kind-wise index over the flat LoRA vector (wire codec input).
    pub kidx: Arc<KindIndex>,
    /// Initial LoRA vector every client starts from.
    pub lora_init: Vec<f32>,
    /// Root RNG, positioned just after the setup forks (see module docs).
    pub rng: Rng,
}

impl WorldSeed {
    /// Build the session-free world kernel. The fork order here is
    /// load-bearing — see module docs before touching it.
    pub fn build(cfg: &FedConfig) -> Result<WorldSeed> {
        let mut rng = Rng::new(cfg.seed);
        // fork(1) historically fed `Session::new`, which drew the base
        // init from it before the checkpoint overlay — replicated here
        // byte-for-byte so the stream position is unchanged.
        let schema = if cfg.preset == "synthetic" {
            Schema::synthetic()
        } else {
            Schema::load(&cfg.artifacts_dir, &cfg.preset)?
        };
        let mut base_host = schema.init_base(&mut rng.fork(1));
        if let Some(ckpt) = &cfg.base_checkpoint {
            let bytes = std::fs::read(ckpt)?;
            anyhow::ensure!(bytes.len() == 4 * schema.base_total, "checkpoint size");
            base_host = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
        }
        let mcfg = &schema.config;
        let ccfg = corpus::CorpusCfg::new(mcfg.vocab, mcfg.seq_len, 8);
        let ds = corpus::generate(&mut rng.fork(2), cfg.n_samples, ccfg);
        let parts = data::partition_dataset(&ds, cfg.partition, cfg.n_clients, &mut rng.fork(3));

        let pairs = if cfg.dpo {
            preference::generate_pairs(&mut rng.fork(9), cfg.n_samples, &ccfg)
        } else {
            vec![]
        };

        let kinds = Arc::new(schema.kind_map());
        let kidx = Arc::new(KindIndex::new(&kinds));
        let lora_init = schema.init_lora(&mut rng.fork(4));

        Ok(WorldSeed {
            schema: Arc::new(schema),
            base_host,
            ds,
            ccfg,
            pairs,
            parts,
            kinds,
            kidx,
            lora_init,
            rng,
        })
    }

    /// Fresh state for client `ci` — identical whether built eagerly (the
    /// monolithic runner) or lazily on first task (cluster participants
    /// and mux lanes). Pure: consumes no shared randomness, so the order
    /// clients first appear in cannot perturb any stream.
    pub fn client_state(&self, cfg: &FedConfig, ci: usize) -> ClientState {
        client_state_from(&self.parts, self.pairs.len(), &self.lora_init,
                          &self.kinds, &self.kidx, cfg, ci)
    }

    /// FedAvg weights n_i for every client (sampling + aggregation).
    pub fn client_weights(&self) -> Vec<f64> {
        self.parts.iter().map(|p| p.len().max(1) as f64).collect()
    }
}

/// Shared body of `WorldSeed::client_state` / `World::client_state` — one
/// implementation so the eager, lazy-thread, and mux-lane paths cannot
/// drift.
fn client_state_from(
    parts: &[Vec<usize>],
    n_pairs: usize,
    lora_init: &[f32],
    kinds: &Arc<Vec<LoraKind>>,
    kidx: &Arc<KindIndex>,
    cfg: &FedConfig,
    ci: usize,
) -> ClientState {
    let indices = parts[ci].clone();
    let n_samples = indices.len().max(1);
    let pref_indices: Vec<usize> = if cfg.dpo {
        (0..n_pairs).filter(|p| p % cfg.n_clients == ci).collect()
    } else {
        vec![]
    };
    ClientState {
        lora: lora_init.to_vec(),
        tau: 0,
        comp: cfg
            .eco
            .map(|e| Compressor::new(e.spars, e.encoding, kinds.clone(), kidx.clone())),
        data: ClientData::new(indices),
        pref_indices,
        n_samples,
    }
}

impl World {
    /// Build the world. The fork order is load-bearing — see module docs
    /// (the stream consumption lives in [`WorldSeed::build`] now; this
    /// merely layers the PJRT session on top).
    pub fn build(cfg: &FedConfig) -> Result<World> {
        let seed = WorldSeed::build(cfg)?;
        let engine = Arc::new(crate::runtime::Engine::new(&cfg.artifacts_dir)?);
        let session = Session::from_seed(engine, &seed)?;
        let WorldSeed { ds, ccfg, pairs, parts, kinds, kidx, lora_init, rng, .. } = seed;
        Ok(World { session, ds, ccfg, pairs, parts, kinds, kidx, lora_init, rng })
    }

    /// Fresh state for client `ci` — identical whether built eagerly (the
    /// monolithic runner) or lazily on first task (cluster participants).
    pub fn client_state(&self, cfg: &FedConfig, ci: usize) -> ClientState {
        client_state_from(&self.parts, self.pairs.len(), &self.lora_init,
                          &self.kinds, &self.kidx, cfg, ci)
    }

    /// FedAvg weights n_i for every client (sampling + aggregation).
    pub fn client_weights(&self) -> Vec<f64> {
        self.parts.iter().map(|p| p.len().max(1) as f64).collect()
    }
}

/// One client's local optimization (SGD chain or DPO). Shared verbatim by
/// the monolithic runner and cluster participants so the two paths cannot
/// drift: `rng` is the per-task batch stream, `local` the mixed/restarted
/// starting point. Returns the trained vector and the mean local loss.
pub fn local_train(
    session: &Session,
    cfg: &FedConfig,
    ds: &Dataset,
    pairs: &[preference::PrefPair],
    client: &mut ClientState,
    mut local: Vec<f32>,
    rng: &mut Rng,
    mask: &PjRtBuffer,
) -> Result<(Vec<f32>, f64)> {
    let mean_loss = if cfg.dpo {
        let b = session.schema.config.batch;
        let seq = session.schema.config.seq_len + 1;
        let mut loss_sum = 0.0f64;
        for _ in 0..cfg.local_steps {
            let mut chosen = Vec::with_capacity(b * seq);
            let mut rejected = Vec::with_capacity(b * seq);
            for _ in 0..b {
                let pi = if client.pref_indices.is_empty() {
                    rng.below(pairs.len().max(1))
                } else {
                    client.pref_indices[rng.below(client.pref_indices.len())]
                };
                let p = &pairs[pi];
                chosen.extend_from_slice(&p.chosen);
                rejected.extend_from_slice(&p.rejected);
            }
            let (next, loss, _m) =
                session.dpo_step(&local, &chosen, &rejected, cfg.lr, cfg.dpo_beta, mask)?;
            local = next;
            loss_sum += loss as f64;
        }
        loss_sum / cfg.local_steps.max(1) as f64
    } else {
        let batch_size = session.schema.config.batch;
        let data = &mut client.data;
        let (next, mean_loss) = session.train_chain(
            local,
            cfg.local_steps,
            cfg.lr,
            mask,
            || data.next_batch(ds, batch_size, rng),
        )?;
        local = next;
        mean_loss
    };
    Ok((local, mean_loss))
}

/// Salt for a client's per-round batch stream (shared by both paths).
pub fn batch_salt(dpo: bool, t: u64, ci: usize) -> u64 {
    if dpo {
        4000 + t * 131 + ci as u64
    } else {
        3000 + t * 131 + ci as u64
    }
}

/// Deterministic stream for re-dispatching a timed-out slot: a pure
/// function of (experiment seed, round, slot, re-dispatch attempt) that
/// never touches the root stream (see the module docs on why). The
/// coordinator draws the replacement client AND the replacement task's
/// batch stream from it, so a re-dispatch is fully reproducible given
/// which slot timed out on which attempt.
pub fn resample_rng(seed: u64, t: u64, slot: u32, attempt: u32) -> Rng {
    let salt = 0xD15D_A7C4_5EED_0000u64
        ^ t.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((slot as u64) << 20)
        ^ attempt as u64;
    Rng::new(seed ^ salt)
}
