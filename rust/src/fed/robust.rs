//! Byzantine-robust aggregation (ROADMAP "Adversarial & private
//! scenarios"). [`RobustAggregator`] wraps [`SegmentAggregator`] with a
//! pluggable per-round statistic selected by [`Aggregator`]:
//!
//! - `mean` — today's Eq. 2 sample-weighted average. Every call
//!   delegates straight to the inner [`SegmentAggregator`], so the
//!   floating-point op sequence (and therefore the bits) is identical
//!   to the pre-robust path.
//! - `norm-clip {c}` — each contribution's explicit values are L2-norm
//!   clipped to `c` before entering the mean. A contribution whose norm
//!   is ≤ `c` (always, when `c = inf`) is delegated UNTOUCHED, which is
//!   what keeps `norm-clip:inf` bitwise-identical to `mean`.
//! - `trimmed-mean {beta}` — coordinate-wise: of the `m` contributions
//!   covering a segment, drop the `t = min(floor(beta·m), (m-1)/2)`
//!   lowest and highest values at every coordinate and take the
//!   sample-weighted mean of the rest. When `t = 0` the held
//!   contributions are replayed through the inner aggregator in arrival
//!   order, reproducing the mean bit-for-bit.
//! - `median` — coordinate-wise unweighted median. Weights are client
//!   sample counts, which a Byzantine client can inflate at will, so a
//!   robust order statistic must not honor them.
//!
//! ## Sparse-coordinate semantics: absent = 0
//!
//! Top-k uplinks omit coordinates the client deemed ≈ 0, and Eq. 2
//! already averages those implicit zeros (standard sparse-FedAvg
//! semantics, see [`SegmentAggregator::add_sparse`]). The robust
//! statistics keep that convention: a coordinate absent from a
//! contribution VOTES ZERO rather than abstaining. Abstention would (a)
//! break `trimmed-mean{beta=0} ≡ mean`, and (b) let a single attacker
//! own any coordinate no honest top-k selected. The cost is that
//! top-k sparsification drags the trimmed statistics toward zero — the
//! compression×robustness interaction this plane exists to measure.
//!
//! ## Determinism
//!
//! Trimmed-mean and median are order statistics: per coordinate the
//! `(value, weight)` pairs are sorted by `f32::total_cmp` (then weight,
//! so equal values tie-break identically), making the result invariant
//! to slot arrival order — pinned by the permutation propcheck below.
//! The retained-contribution modes copy each decoded contribution onto
//! the heap, deliberately opting out of the zero-allocation discipline
//! of the mean hot path (documented in ARCHITECTURE.md).

use std::ops::Range;

use crate::compress::{wire, KindIndex, SparseVec};
use crate::fed::server::SegmentAggregator;

/// Robust-statistic selector, part of the run-defining config (covered
/// by `FedConfig::digest`, so a resumed journal or a joining
/// worker/shard with a different `--aggregator` is rejected at
/// handshake).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Aggregator {
    /// Eq. 2 sample-weighted mean — the existing path, bitwise-preserved.
    Mean,
    /// Coordinate-wise trimmed mean dropping a `beta` fraction of the
    /// contributions at each extreme. `0 ≤ beta < 0.5`.
    TrimmedMean { beta: f64 },
    /// Coordinate-wise unweighted median.
    Median,
    /// Per-contribution L2 norm clip to `c` (`c = inf` disables, `c > 0`).
    NormClip { c: f64 },
}

impl Aggregator {
    pub const DEFAULT_TRIM_BETA: f64 = 0.2;
    pub const DEFAULT_CLIP_C: f64 = 1.0;

    /// Parse a CLI spec: `mean`, `median`, `trimmed-mean[:BETA]`,
    /// `norm-clip[:C]` (`C` accepts `inf`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (head, param) = match s.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (s, None),
        };
        let agg = match head {
            "mean" => {
                anyhow::ensure!(param.is_none(), "mean takes no parameter");
                Aggregator::Mean
            }
            "median" => {
                anyhow::ensure!(param.is_none(), "median takes no parameter");
                Aggregator::Median
            }
            "trimmed-mean" => {
                let beta = match param {
                    Some(p) => p.parse::<f64>().map_err(|e| anyhow::anyhow!("bad beta {p:?}: {e}"))?,
                    None => Self::DEFAULT_TRIM_BETA,
                };
                anyhow::ensure!((0.0..0.5).contains(&beta), "trim beta must be in [0, 0.5), got {beta}");
                Aggregator::TrimmedMean { beta }
            }
            "norm-clip" => {
                let c = match param {
                    Some("inf") => f64::INFINITY,
                    Some(p) => p.parse::<f64>().map_err(|e| anyhow::anyhow!("bad clip {p:?}: {e}"))?,
                    None => Self::DEFAULT_CLIP_C,
                };
                anyhow::ensure!(c > 0.0, "clip threshold must be > 0, got {c}");
                Aggregator::NormClip { c }
            }
            other => anyhow::bail!("unknown aggregator {other:?} (mean|trimmed-mean[:beta]|median|norm-clip[:c])"),
        };
        Ok(agg)
    }

    /// Stable label for CSV/logs (round-trips through [`Aggregator::parse`]).
    pub fn name(&self) -> String {
        match self {
            Aggregator::Mean => "mean".into(),
            Aggregator::TrimmedMean { beta } => format!("trimmed-mean:{beta}"),
            Aggregator::Median => "median".into(),
            Aggregator::NormClip { c } => {
                if c.is_infinite() {
                    "norm-clip:inf".into()
                } else {
                    format!("norm-clip:{c}")
                }
            }
        }
    }

    /// `(tag, param_bits)` for config digesting.
    pub fn digest_parts(&self) -> (u8, u64) {
        match self {
            Aggregator::Mean => (0, 0),
            Aggregator::TrimmedMean { beta } => (1, beta.to_bits()),
            Aggregator::Median => (2, 0),
            Aggregator::NormClip { c } => (3, c.to_bits()),
        }
    }
}

impl Default for Aggregator {
    fn default() -> Self {
        Aggregator::Mean
    }
}

/// Per-round robustness counters surfaced in the CSV
/// (`clients_trimmed`, `clip_applied` columns) and summed across shards
/// through `ShardReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RobustStats {
    /// Contributions dropped by trimming, summed over segments (2·t per
    /// segment with a non-zero trim budget).
    pub trimmed: u64,
    /// Contributions whose values were rescaled by the norm clip.
    pub clipped: u64,
}

impl RobustStats {
    pub fn merge(&mut self, o: &RobustStats) {
        self.trimmed += o.trimmed;
        self.clipped += o.clipped;
    }
}

/// One retained contribution (trimmed-mean/median only; mean and
/// norm-clip stream straight into the inner accumulator).
struct Held {
    seg: usize,
    w: f64,
    vals: HeldVals,
}

enum HeldVals {
    /// Sorted global sparse indices + values (the wire decoder emits
    /// ascending indices; [`RobustAggregator::add_sparse`] asserts it).
    Sparse { idx: Vec<u32>, vals: Vec<f32> },
    /// Dense values spanning the segment range (baseline uploads).
    Dense(Vec<f32>),
}

/// [`SegmentAggregator`] wrapper applying the configured robust
/// statistic. Mirrors the inner API (`add_sparse` / `add_dense` /
/// `add_wire` / `owns` / `range` / `finish` / `covered`) so
/// `cluster/shard.rs` swaps it in unchanged; `finish` additionally
/// returns the round's [`RobustStats`].
pub struct RobustAggregator {
    inner: SegmentAggregator,
    kind: Aggregator,
    held: Vec<Held>,
    /// Per owned segment: retained-contribution count (coverage for the
    /// retained modes, where the inner accumulator stays empty).
    held_per_seg: Vec<u32>,
    clipped: u64,
    /// Scratch for norm-clip rescaling.
    clip_scratch: Vec<f32>,
}

impl RobustAggregator {
    pub fn new(kind: Aggregator, total: usize, n_s: usize) -> Self {
        Self::for_segments(kind, total, n_s, 0, n_s)
    }

    pub fn for_segments(kind: Aggregator, total: usize, n_s: usize, seg_lo: usize, seg_hi: usize) -> Self {
        RobustAggregator {
            inner: SegmentAggregator::for_segments(total, n_s, seg_lo, seg_hi),
            kind,
            held: Vec::new(),
            held_per_seg: vec![0; seg_hi - seg_lo],
            clipped: 0,
            clip_scratch: Vec::new(),
        }
    }

    pub fn kind(&self) -> Aggregator {
        self.kind
    }

    fn retains(&self) -> bool {
        matches!(self.kind, Aggregator::TrimmedMean { .. } | Aggregator::Median)
    }

    pub fn n_segments(&self) -> usize {
        self.inner.n_segments()
    }

    pub fn seg0(&self) -> usize {
        self.inner.seg0()
    }

    pub fn base(&self) -> usize {
        self.inner.base()
    }

    pub fn owns(&self, seg: usize) -> bool {
        self.inner.owns(seg)
    }

    pub fn range(&self, seg: usize) -> &Range<usize> {
        self.inner.range(seg)
    }

    pub fn add_sparse(&mut self, seg: usize, sv: &SparseVec, n_i: f64) {
        match self.kind {
            Aggregator::Mean => self.inner.add_sparse(seg, sv, n_i),
            Aggregator::NormClip { c } => {
                let norm = l2_norm(&sv.vals);
                if norm > c {
                    let scale = c / norm;
                    self.clip_scratch.clear();
                    self.clip_scratch.extend(sv.vals.iter().map(|&v| (v as f64 * scale) as f32));
                    let clipped = SparseVec { idx: sv.idx.clone(), vals: std::mem::take(&mut self.clip_scratch) };
                    self.inner.add_sparse(seg, &clipped, n_i);
                    self.clip_scratch = clipped.vals;
                    self.clipped += 1;
                } else {
                    self.inner.add_sparse(seg, sv, n_i);
                }
            }
            Aggregator::TrimmedMean { .. } | Aggregator::Median => {
                let r = self.inner.range(seg);
                let (start, end) = (r.start, r.end);
                let mut prev = None;
                for &i in &sv.idx {
                    let i = i as usize;
                    assert!(i >= start && i < end, "index {i} outside segment {seg}");
                    assert!(prev.map_or(true, |p| p < i), "sparse indices must ascend");
                    prev = Some(i);
                }
                self.held_per_seg[seg - self.inner.seg0()] += 1;
                self.held.push(Held {
                    seg,
                    w: n_i,
                    vals: HeldVals::Sparse { idx: sv.idx.clone(), vals: sv.vals.clone() },
                });
            }
        }
    }

    pub fn add_dense(&mut self, seg: usize, values: &[f32], n_i: f64) {
        match self.kind {
            Aggregator::Mean => self.inner.add_dense(seg, values, n_i),
            Aggregator::NormClip { c } => {
                let norm = l2_norm(values);
                if norm > c {
                    let scale = c / norm;
                    self.clip_scratch.clear();
                    self.clip_scratch.extend(values.iter().map(|&v| (v as f64 * scale) as f32));
                    let scratch = std::mem::take(&mut self.clip_scratch);
                    self.inner.add_dense(seg, &scratch, n_i);
                    self.clip_scratch = scratch;
                    self.clipped += 1;
                } else {
                    self.inner.add_dense(seg, values, n_i);
                }
            }
            Aggregator::TrimmedMean { .. } | Aggregator::Median => {
                assert_eq!(values.len(), self.inner.range(seg).len());
                self.held_per_seg[seg - self.inner.seg0()] += 1;
                self.held.push(Held { seg, w: n_i, vals: HeldVals::Dense(values.to_vec()) });
            }
        }
    }

    /// Decode one uplink wire message and fold it in — the robust twin
    /// of [`SegmentAggregator::add_wire`]. Returns the transmitted
    /// parameter count.
    pub fn add_wire(&mut self, seg: usize, bytes: &[u8], kidx: &KindIndex, n_i: f64) -> anyhow::Result<usize> {
        anyhow::ensure!(self.inner.owns(seg), "segment {seg} not owned by this aggregator");
        if let Aggregator::Mean = self.kind {
            return self.inner.add_wire(seg, bytes, kidx, n_i);
        }
        let range = self.inner.range(seg).clone();
        let decoded = wire::decode(bytes, &range, kidx)?;
        let params = decoded.len();
        self.add_sparse(seg, &decoded, n_i);
        Ok(params)
    }

    pub fn covered(&self) -> Vec<bool> {
        if self.retains() {
            self.held_per_seg.iter().map(|&n| n > 0).collect()
        } else {
            self.inner.covered()
        }
    }

    /// Weighted/robust delta over the owned span plus the round's
    /// robustness counters. Mean and norm-clip delegate to the inner
    /// accumulator; trimmed-mean/median compute order statistics per
    /// coordinate, except that a segment with a zero trim budget
    /// replays its held contributions through the inner accumulator so
    /// `trimmed-mean{beta=0}` stays bitwise-identical to `mean`.
    pub fn finish(self) -> (Vec<f32>, RobustStats) {
        let mut stats = RobustStats { trimmed: 0, clipped: self.clipped };
        if !self.retains() {
            return (self.inner.finish(), stats);
        }

        let (seg0, base) = (self.inner.seg0(), self.inner.base());
        let n_owned = self.inner.n_segments();
        // Group held contributions by segment, preserving arrival order.
        let mut by_seg: Vec<Vec<usize>> = vec![Vec::new(); n_owned];
        for (h, held) in self.held.iter().enumerate() {
            by_seg[held.seg - seg0].push(h);
        }

        let mut inner = self.inner;
        // (flat global index, robust value) computed manually, patched
        // over the replay output below.
        let mut patches: Vec<(usize, f32)> = Vec::new();
        let mut col: Vec<(f32, f64)> = Vec::new();

        for (s, members) in by_seg.iter().enumerate() {
            let m = members.len();
            if m == 0 {
                continue;
            }
            let seg = seg0 + s;
            let t = match self.kind {
                Aggregator::TrimmedMean { beta } => {
                    ((beta * m as f64).floor() as usize).min((m - 1) / 2)
                }
                Aggregator::Median => 0,
                _ => unreachable!(),
            };
            let replay = matches!(self.kind, Aggregator::TrimmedMean { .. }) && t == 0;
            if replay {
                for &h in members {
                    let held = &self.held[h];
                    match &held.vals {
                        HeldVals::Sparse { idx, vals } => {
                            let sv = SparseVec { idx: idx.clone(), vals: vals.clone() };
                            inner.add_sparse(seg, &sv, held.w);
                        }
                        HeldVals::Dense(v) => inner.add_dense(seg, v, held.w),
                    }
                }
                continue;
            }
            if t > 0 {
                stats.trimmed += 2 * t as u64;
            }
            let r = inner.range(seg).clone();
            let mut cursors = vec![0usize; m];
            for i in r.clone() {
                col.clear();
                for (k, &h) in members.iter().enumerate() {
                    let held = &self.held[h];
                    let v = match &held.vals {
                        HeldVals::Dense(d) => d[i - r.start],
                        HeldVals::Sparse { idx, vals } => {
                            let cur = &mut cursors[k];
                            while *cur < idx.len() && (idx[*cur] as usize) < i {
                                *cur += 1;
                            }
                            if *cur < idx.len() && idx[*cur] as usize == i {
                                vals[*cur]
                            } else {
                                0.0
                            }
                        }
                    };
                    col.push((v, held.w));
                }
                // total_cmp then weight: deterministic regardless of
                // arrival order even with tied values.
                col.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
                let out = match self.kind {
                    Aggregator::TrimmedMean { .. } => {
                        let kept = &col[t..m - t];
                        let (mut num, mut den) = (0.0f64, 0.0f64);
                        for &(v, w) in kept {
                            num += w * v as f64;
                            den += w;
                        }
                        if den > 0.0 { (num / den) as f32 } else { 0.0 }
                    }
                    Aggregator::Median => {
                        let mid = m / 2;
                        if m % 2 == 1 {
                            col[mid].0
                        } else {
                            ((col[mid - 1].0 as f64 + col[mid].0 as f64) / 2.0) as f32
                        }
                    }
                    _ => unreachable!(),
                };
                patches.push((i, out));
            }
        }

        let mut delta = inner.finish();
        for (i, v) in patches {
            delta[i - base] = v;
        }
        (delta, stats)
    }
}

fn l2_norm(vals: &[f32]) -> f64 {
    vals.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(idx: Vec<u32>, vals: Vec<f32>) -> SparseVec {
        SparseVec { idx, vals }
    }

    /// Deterministic pseudo-random sparse contributions over `total`
    /// params in `n_s` segments (plain LCG — no external deps).
    fn gen_contributions(total: usize, n_s: usize, n_clients: usize, seed: u64) -> Vec<(usize, SparseVec, f64)> {
        let mut state = seed;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let ranges = crate::model::segment_ranges(total, n_s);
        let mut out = Vec::new();
        for c in 0..n_clients {
            let seg = c % n_s;
            let r = ranges[seg].clone();
            let mut idx: Vec<u32> = Vec::new();
            for i in r.clone() {
                if next() % 3 == 0 {
                    idx.push(i as u32);
                }
            }
            if idx.is_empty() {
                idx.push(r.start as u32);
            }
            let vals: Vec<f32> = idx.iter().map(|_| (next() % 2000) as f32 / 500.0 - 2.0).collect();
            let w = 1.0 + (next() % 7) as f64;
            out.push((seg, sv(idx, vals), w));
        }
        out
    }

    #[test]
    fn parse_roundtrip_and_validation() {
        assert_eq!(Aggregator::parse("mean").unwrap(), Aggregator::Mean);
        assert_eq!(Aggregator::parse("median").unwrap(), Aggregator::Median);
        assert_eq!(
            Aggregator::parse("trimmed-mean:0.25").unwrap(),
            Aggregator::TrimmedMean { beta: 0.25 }
        );
        assert_eq!(
            Aggregator::parse("trimmed-mean").unwrap(),
            Aggregator::TrimmedMean { beta: Aggregator::DEFAULT_TRIM_BETA }
        );
        assert_eq!(Aggregator::parse("norm-clip:2.5").unwrap(), Aggregator::NormClip { c: 2.5 });
        assert_eq!(
            Aggregator::parse("norm-clip:inf").unwrap(),
            Aggregator::NormClip { c: f64::INFINITY }
        );
        assert!(Aggregator::parse("trimmed-mean:0.5").is_err());
        assert!(Aggregator::parse("trimmed-mean:-0.1").is_err());
        assert!(Aggregator::parse("norm-clip:0").is_err());
        assert!(Aggregator::parse("mean:1").is_err());
        assert!(Aggregator::parse("krum").is_err());
        for spec in ["mean", "median", "trimmed-mean:0.25", "norm-clip:2.5", "norm-clip:inf"] {
            assert_eq!(Aggregator::parse(spec).unwrap().name(), spec);
        }
    }

    #[test]
    fn digest_parts_distinguish_kinds_and_params() {
        let a = Aggregator::TrimmedMean { beta: 0.1 }.digest_parts();
        let b = Aggregator::TrimmedMean { beta: 0.2 }.digest_parts();
        let c = Aggregator::Median.digest_parts();
        assert_ne!(a, b);
        assert_ne!(a.0, c.0);
    }

    fn run_kind(kind: Aggregator, contributions: &[(usize, SparseVec, f64)], total: usize, n_s: usize) -> (Vec<f32>, RobustStats) {
        let mut agg = RobustAggregator::new(kind, total, n_s);
        for (seg, v, w) in contributions {
            agg.add_sparse(*seg, v, *w);
        }
        agg.finish()
    }

    #[test]
    fn mean_is_bitwise_identical_to_segment_aggregator() {
        let (total, n_s) = (37, 4);
        let contributions = gen_contributions(total, n_s, 16, 11);
        let mut plain = SegmentAggregator::new(total, n_s);
        for (seg, v, w) in &contributions {
            plain.add_sparse(*seg, v, *w);
        }
        let want = plain.finish();
        let (got, stats) = run_kind(Aggregator::Mean, &contributions, total, n_s);
        assert_eq!(stats, RobustStats::default());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "diverged at {i}");
        }
    }

    #[test]
    fn mean_matches_segment_aggregator_under_shard_splits() {
        // the robust mean through 1, 2 and 4 shard slices must equal
        // the whole-space SegmentAggregator bit-for-bit
        let (total, n_s) = (53, 4);
        let contributions = gen_contributions(total, n_s, 20, 7);
        let mut plain = SegmentAggregator::new(total, n_s);
        for (seg, v, w) in &contributions {
            plain.add_sparse(*seg, v, *w);
        }
        let want = plain.finish();
        for shards in [1usize, 2, 4] {
            let per = n_s / shards;
            let mut got = vec![0.0f32; total];
            for s in 0..shards {
                let (lo, hi) = (s * per, (s + 1) * per);
                let mut agg = RobustAggregator::for_segments(Aggregator::Mean, total, n_s, lo, hi);
                for (seg, v, w) in &contributions {
                    if agg.owns(*seg) {
                        agg.add_sparse(*seg, v, *w);
                    }
                }
                let base = agg.base();
                let (part, _) = agg.finish();
                got[base..base + part.len()].copy_from_slice(&part);
            }
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "shards={shards} diverged at {i}");
            }
        }
    }

    #[test]
    fn trim_beta_zero_and_clip_inf_are_bitwise_mean() {
        let (total, n_s) = (41, 3);
        let contributions = gen_contributions(total, n_s, 15, 3);
        let (want, _) = run_kind(Aggregator::Mean, &contributions, total, n_s);
        for kind in [Aggregator::TrimmedMean { beta: 0.0 }, Aggregator::NormClip { c: f64::INFINITY }] {
            let (got, stats) = run_kind(kind, &contributions, total, n_s);
            assert_eq!(stats, RobustStats::default(), "{kind:?} touched data");
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?} diverged at {i}");
            }
        }
    }

    #[test]
    fn trimmed_and_median_are_permutation_invariant() {
        let (total, n_s) = (29, 2);
        let contributions = gen_contributions(total, n_s, 12, 19);
        // a handful of deterministic permutations of arrival order
        let perms: Vec<Vec<usize>> = vec![
            (0..contributions.len()).collect(),
            (0..contributions.len()).rev().collect(),
            (0..contributions.len()).map(|i| (i * 5) % contributions.len()).collect(),
        ];
        for kind in [Aggregator::TrimmedMean { beta: 0.3 }, Aggregator::Median] {
            let mut outs: Vec<Vec<f32>> = Vec::new();
            for p in &perms {
                let reordered: Vec<_> = p.iter().map(|&i| contributions[i].clone()).collect();
                outs.push(run_kind(kind, &reordered, total, n_s).0);
            }
            for o in &outs[1..] {
                for (i, (a, b)) in outs[0].iter().zip(o).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kind:?} order-dependent at {i}");
                }
            }
        }
    }

    #[test]
    fn trimmed_mean_drops_an_outlier() {
        // 5 honest clients say ~1.0, one attacker says 1000; beta=0.2
        // trims one from each end → attacker gone
        let mut agg = RobustAggregator::new(Aggregator::TrimmedMean { beta: 0.2 }, 4, 1);
        for _ in 0..5 {
            agg.add_sparse(0, &sv(vec![0, 1, 2, 3], vec![1.0; 4]), 1.0);
        }
        agg.add_sparse(0, &sv(vec![0, 1, 2, 3], vec![1000.0; 4]), 1.0);
        let (out, stats) = agg.finish();
        assert_eq!(stats.trimmed, 2);
        assert!(out.iter().all(|&v| v == 1.0), "attacker survived: {out:?}");
    }

    #[test]
    fn median_ignores_weights_and_outliers() {
        let mut agg = RobustAggregator::new(Aggregator::Median, 2, 1);
        agg.add_sparse(0, &sv(vec![0, 1], vec![1.0, 1.0]), 1.0);
        agg.add_sparse(0, &sv(vec![0, 1], vec![1.0, 1.0]), 1.0);
        // attacker with a huge claimed sample count
        agg.add_sparse(0, &sv(vec![0, 1], vec![-50.0, -50.0]), 1e9);
        let (out, _) = agg.finish();
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn absent_coordinates_vote_zero() {
        // 3 contributions, only one mentions coordinate 1 → its median
        // column is [0, 0, 9] → 0
        let mut agg = RobustAggregator::new(Aggregator::Median, 2, 1);
        agg.add_sparse(0, &sv(vec![0], vec![4.0]), 1.0);
        agg.add_sparse(0, &sv(vec![0], vec![5.0]), 1.0);
        agg.add_sparse(0, &sv(vec![0, 1], vec![6.0, 9.0]), 1.0);
        let (out, _) = agg.finish();
        assert_eq!(out, vec![5.0, 0.0]);
    }

    #[test]
    fn norm_clip_scales_hot_contributions_and_counts_them() {
        let mut agg = RobustAggregator::new(Aggregator::NormClip { c: 1.0 }, 2, 1);
        agg.add_sparse(0, &sv(vec![0, 1], vec![3.0, 4.0]), 1.0); // norm 5 → scaled by 0.2
        agg.add_sparse(0, &sv(vec![0, 1], vec![0.3, 0.4]), 1.0); // norm 0.5 → untouched
        let (out, stats) = agg.finish();
        assert_eq!(stats.clipped, 1);
        assert!((out[0] - 0.45).abs() < 1e-6 && (out[1] - 0.6).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn dense_and_sparse_mix_in_retained_modes() {
        let mut agg = RobustAggregator::new(Aggregator::Median, 3, 1);
        agg.add_dense(0, &[1.0, 2.0, 3.0], 2.0);
        agg.add_sparse(0, &sv(vec![1], vec![8.0]), 1.0);
        agg.add_dense(0, &[1.0, 4.0, 3.0], 1.0);
        let (out, _) = agg.finish();
        assert_eq!(out, vec![1.0, 4.0, 3.0]);
    }

    #[test]
    fn covered_tracks_retained_contributions() {
        let mut agg = RobustAggregator::new(Aggregator::Median, 8, 2);
        agg.add_sparse(1, &sv(vec![4], vec![1.0]), 1.0);
        assert_eq!(agg.covered(), vec![false, true]);
        let (out, _) = agg.finish();
        assert_eq!(&out[..4], &[0.0; 4]);
    }
}
