//! Server-side aggregation (paper §3.3, Eq. 2): segments with the same id
//! are combined by a sample-count-weighted average and the global model is
//! reassembled from the aggregated segments.
//!
//! An aggregator no longer has to own the whole segment space: the
//! sharded aggregation plane (`cluster::shard`) builds one aggregator per
//! shard over a contiguous slice `[seg_lo, seg_hi)` of the segments via
//! [`SegmentAggregator::for_segments`]. Segment ids and sparse indices
//! stay GLOBAL everywhere — only the accumulator storage is offset — so
//! the per-index floating-point reduction of a sharded round is the same
//! sequence of operations as the unsharded one, which is what keeps
//! `--shards N` bitwise-identical to `--shards 1`.

use std::ops::Range;

use crate::compress::{wire, KindIndex, SparseVec};
use crate::model::segment_ranges;

/// Weighted per-segment aggregator over client UPDATES (deltas from the
/// round-start global). Works for both sparse (EcoLoRA) and dense
/// (baseline) uploads; baselines use `n_s = 1`.
pub struct SegmentAggregator {
    /// GLOBAL index ranges of the owned segments (contiguous slice).
    ranges: Vec<Range<usize>>,
    /// Global id of the first owned segment.
    seg0: usize,
    /// First owned flat index (0 when owning everything or nothing).
    base: usize,
    acc: Vec<f64>,
    seg_weight: Vec<f64>,
}

impl SegmentAggregator {
    /// Aggregator owning the WHOLE segment space (the monolithic runner
    /// and `--shards 1`).
    pub fn new(total: usize, n_s: usize) -> Self {
        Self::for_segments(total, n_s, 0, n_s)
    }

    /// Aggregator owning the contiguous global segments `[seg_lo, seg_hi)`
    /// of a `total`-parameter vector split into `n_s` segments. Segment
    /// ids passed to `add_*`/`range` stay global; `seg_lo == seg_hi`
    /// builds an empty aggregator that owns nothing.
    pub fn for_segments(total: usize, n_s: usize, seg_lo: usize, seg_hi: usize) -> Self {
        assert!(seg_lo <= seg_hi && seg_hi <= n_s, "shard [{seg_lo},{seg_hi}) outside 0..{n_s}");
        let all = segment_ranges(total, n_s);
        let ranges: Vec<Range<usize>> = all[seg_lo..seg_hi].to_vec();
        let base = ranges.first().map_or(0, |r| r.start);
        let span = ranges.last().map_or(0, |r| r.end) - base;
        SegmentAggregator {
            ranges,
            seg0: seg_lo,
            base,
            acc: vec![0.0; span],
            seg_weight: vec![0.0; seg_hi - seg_lo],
        }
    }

    /// Owned segment count (the full `n_s` for a whole-space aggregator).
    pub fn n_segments(&self) -> usize {
        self.ranges.len()
    }

    /// Global id of the first owned segment (0 for a whole-space one).
    pub fn seg0(&self) -> usize {
        self.seg0
    }

    /// First flat index this aggregator's [`SegmentAggregator::finish`]
    /// delta refers to (0 for a whole-space aggregator).
    pub fn base(&self) -> usize {
        self.base
    }

    /// True when this aggregator owns global segment `seg`.
    pub fn owns(&self, seg: usize) -> bool {
        seg >= self.seg0 && seg < self.seg0 + self.ranges.len()
    }

    /// GLOBAL flat-index range of owned global segment `seg`.
    pub fn range(&self, seg: usize) -> &Range<usize> {
        assert!(self.owns(seg), "segment {seg} not owned by this aggregator");
        &self.ranges[seg - self.seg0]
    }

    /// Add a sparse segment contribution with weight `n_i`. Indices must
    /// lie inside the segment's range; zeros elsewhere count toward the
    /// average (standard sparse FedAvg semantics).
    pub fn add_sparse(&mut self, seg: usize, sv: &SparseVec, n_i: f64) {
        let r = self.range(seg);
        let (start, end) = (r.start, r.end);
        for (&i, &v) in sv.idx.iter().zip(&sv.vals) {
            let i = i as usize;
            assert!(i >= start && i < end, "index {i} outside segment {seg}");
            self.acc[i - self.base] += n_i * v as f64;
        }
        self.seg_weight[seg - self.seg0] += n_i;
    }

    /// Decode one uplink wire message for `seg` and fold it in with weight
    /// `n_i` — the server side of the EcoLoRA uplink, shared by the
    /// monolithic runner and the sharded cluster plane. Returns the
    /// transmitted parameter count (comm accounting).
    pub fn add_wire(
        &mut self,
        seg: usize,
        bytes: &[u8],
        kidx: &KindIndex,
        n_i: f64,
    ) -> anyhow::Result<usize> {
        anyhow::ensure!(self.owns(seg), "segment {seg} not owned by this aggregator");
        let range = self.range(seg).clone();
        let decoded = wire::decode(bytes, &range, kidx)?;
        let params = decoded.len();
        self.add_sparse(seg, &decoded, n_i);
        Ok(params)
    }

    /// Add a dense segment contribution (`values` spans the segment range).
    pub fn add_dense(&mut self, seg: usize, values: &[f32], n_i: f64) {
        let r = self.range(seg).clone();
        assert_eq!(values.len(), r.len());
        for (a, &v) in self.acc[r.start - self.base..r.end - self.base].iter_mut().zip(values) {
            *a += n_i * v as f64;
        }
        self.seg_weight[seg - self.seg0] += n_i;
    }

    /// Finish: weighted-average delta over the OWNED index span (index 0
    /// of the result is flat index [`SegmentAggregator::base`]; the full
    /// vector for a whole-space aggregator). Segments nobody uploaded stay
    /// zero — cannot happen when the round-robin coverage invariant holds,
    /// but quorum rounds can close before a segment's only uploader
    /// reports.
    pub fn finish(self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.acc.len()];
        for (s, r) in self.ranges.iter().enumerate() {
            let w = self.seg_weight[s];
            if w <= 0.0 {
                continue;
            }
            for i in r.clone() {
                out[i - self.base] = (self.acc[i - self.base] / w) as f32;
            }
        }
        out
    }

    /// Per owned segment (in global-id order from `seg0`): did it receive
    /// at least one upload?
    pub fn covered(&self) -> Vec<bool> {
        self.seg_weight.iter().map(|&w| w > 0.0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_matches_eq2() {
        // two clients upload the same segment with weights 1 and 3
        let mut agg = SegmentAggregator::new(8, 2);
        agg.add_dense(0, &[1.0, 1.0, 1.0, 1.0], 1.0);
        agg.add_dense(0, &[5.0, 5.0, 5.0, 5.0], 3.0);
        agg.add_dense(1, &[2.0, 2.0, 2.0, 2.0], 2.0);
        let out = agg.finish();
        // (1*1 + 3*5)/4 = 4
        assert_eq!(&out[..4], &[4.0, 4.0, 4.0, 4.0]);
        assert_eq!(&out[4..], &[2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn sparse_contributions_average_against_zeros() {
        let mut agg = SegmentAggregator::new(4, 1);
        let sv = SparseVec { idx: vec![1], vals: vec![4.0] };
        agg.add_sparse(0, &sv, 1.0);
        agg.add_dense(0, &[0.0, 0.0, 0.0, 8.0], 1.0);
        let out = agg.finish();
        assert_eq!(out, vec![0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn uncovered_segment_yields_zero_delta() {
        let mut agg = SegmentAggregator::new(6, 3);
        agg.add_dense(1, &[3.0, 3.0], 1.0);
        assert_eq!(agg.covered(), vec![false, true, false]);
        let out = agg.finish();
        assert_eq!(out, vec![0.0, 0.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "outside segment")]
    fn sparse_indices_outside_segment_panic() {
        let mut agg = SegmentAggregator::new(8, 2);
        let sv = SparseVec { idx: vec![6], vals: vec![1.0] };
        agg.add_sparse(0, &sv, 1.0);
    }

    #[test]
    fn single_segment_is_plain_fedavg() {
        let mut agg = SegmentAggregator::new(3, 1);
        agg.add_dense(0, &[1.0, 2.0, 3.0], 2.0);
        agg.add_dense(0, &[3.0, 2.0, 1.0], 2.0);
        assert_eq!(agg.finish(), vec![2.0, 2.0, 2.0]);
    }

    // ---- offset shards ------------------------------------------------------

    #[test]
    fn shard_slice_uses_global_ids_and_offset_storage() {
        // 10 params in 4 segments: 3,3,2,2 → shard owns segments [1, 3)
        let mut shard = SegmentAggregator::for_segments(10, 4, 1, 3);
        assert_eq!(shard.n_segments(), 2);
        assert_eq!(shard.seg0(), 1);
        assert_eq!(shard.base(), 3);
        assert!(!shard.owns(0) && shard.owns(1) && shard.owns(2) && !shard.owns(3));
        assert_eq!(shard.range(1), &(3..6));
        assert_eq!(shard.range(2), &(6..8));
        shard.add_dense(1, &[1.0, 2.0, 3.0], 2.0);
        shard.add_sparse(2, &SparseVec { idx: vec![7], vals: vec![4.0] }, 1.0);
        assert_eq!(shard.covered(), vec![true, true]);
        let out = shard.finish();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 0.0, 4.0]);
    }

    #[test]
    fn shard_slice_matches_whole_space_bitwise() {
        // the same contributions through a whole-space aggregator and
        // through two shard slices must produce identical bits
        let total = 13;
        let n_s = 3;
        let contributions: Vec<(usize, Vec<f32>, f64)> = vec![
            (0, vec![0.5, -1.0, 2.0, 0.25, 1.0], 3.0),
            (1, vec![1.5, 0.0, -0.125, 0.75], 2.0),
            (0, vec![-0.25, 0.5, 0.5, 1.0, -2.0], 1.0),
            (2, vec![2.0, 2.0, 2.0, -1.0], 5.0),
        ];
        let mut whole = SegmentAggregator::new(total, n_s);
        for (seg, v, w) in &contributions {
            whole.add_dense(*seg, v, *w);
        }
        let want = whole.finish();

        let mut lo = SegmentAggregator::for_segments(total, n_s, 0, 1);
        let mut hi = SegmentAggregator::for_segments(total, n_s, 1, 3);
        for (seg, v, w) in &contributions {
            if lo.owns(*seg) {
                lo.add_dense(*seg, v, *w);
            } else {
                hi.add_dense(*seg, v, *w);
            }
        }
        let (lo_base, hi_base) = (lo.base(), hi.base());
        let mut got = vec![0.0f32; total];
        for (base, part) in [(lo_base, lo.finish()), (hi_base, hi.finish())] {
            got[base..base + part.len()].copy_from_slice(&part);
        }
        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "diverged at {i}");
        }
    }

    #[test]
    fn empty_shard_owns_nothing() {
        let agg = SegmentAggregator::for_segments(10, 4, 2, 2);
        assert_eq!(agg.n_segments(), 0);
        assert_eq!(agg.base(), 0);
        assert!(!agg.owns(2));
        assert!(agg.covered().is_empty());
        assert!(agg.finish().is_empty());
    }

    #[test]
    fn partial_coverage_round_reports_uncovered_segments() {
        // a quorum round that closed before segment 2's only uploader
        // reported: covered() exposes the gap, finish() leaves it zero
        let mut agg = SegmentAggregator::new(9, 3);
        agg.add_dense(0, &[1.0, 1.0, 1.0], 1.0);
        agg.add_dense(1, &[2.0, 2.0, 2.0], 1.0);
        assert_eq!(agg.covered(), vec![true, true, false]);
        let out = agg.finish();
        assert_eq!(&out[6..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "not owned")]
    fn foreign_segment_rejected() {
        let mut shard = SegmentAggregator::for_segments(10, 4, 1, 3);
        shard.add_dense(0, &[0.0, 0.0, 0.0], 1.0);
    }
}
