//! Server-side aggregation (paper §3.3, Eq. 2): segments with the same id
//! are combined by a sample-count-weighted average and the global model is
//! reassembled from the aggregated segments.

use std::ops::Range;

use crate::compress::{wire, KindIndex, SparseVec};
use crate::model::segment_ranges;

/// Weighted per-segment aggregator over client UPDATES (deltas from the
/// round-start global). Works for both sparse (EcoLoRA) and dense
/// (baseline) uploads; baselines use `n_s = 1`.
pub struct SegmentAggregator {
    ranges: Vec<Range<usize>>,
    acc: Vec<f64>,
    seg_weight: Vec<f64>,
}

impl SegmentAggregator {
    pub fn new(total: usize, n_s: usize) -> Self {
        SegmentAggregator {
            ranges: segment_ranges(total, n_s),
            acc: vec![0.0; total],
            seg_weight: vec![0.0; n_s],
        }
    }

    pub fn n_segments(&self) -> usize {
        self.ranges.len()
    }

    pub fn range(&self, seg: usize) -> &Range<usize> {
        &self.ranges[seg]
    }

    /// Add a sparse segment contribution with weight `n_i`. Indices must
    /// lie inside the segment's range; zeros elsewhere count toward the
    /// average (standard sparse FedAvg semantics).
    pub fn add_sparse(&mut self, seg: usize, sv: &SparseVec, n_i: f64) {
        let r = &self.ranges[seg];
        for (&i, &v) in sv.idx.iter().zip(&sv.vals) {
            let i = i as usize;
            assert!(i >= r.start && i < r.end, "index {i} outside segment {seg}");
            self.acc[i] += n_i * v as f64;
        }
        self.seg_weight[seg] += n_i;
    }

    /// Decode one uplink wire message for `seg` and fold it in with weight
    /// `n_i` — the server side of the EcoLoRA uplink, shared by the
    /// monolithic runner and the cluster coordinator. Returns the
    /// transmitted parameter count (comm accounting).
    pub fn add_wire(
        &mut self,
        seg: usize,
        bytes: &[u8],
        kidx: &KindIndex,
        n_i: f64,
    ) -> anyhow::Result<usize> {
        let range = self.ranges[seg].clone();
        let decoded = wire::decode(bytes, &range, kidx)?;
        let params = decoded.len();
        self.add_sparse(seg, &decoded, n_i);
        Ok(params)
    }

    /// Add a dense segment contribution (`values` spans the segment range).
    pub fn add_dense(&mut self, seg: usize, values: &[f32], n_i: f64) {
        let r = self.ranges[seg].clone();
        assert_eq!(values.len(), r.len());
        for (a, &v) in self.acc[r].iter_mut().zip(values) {
            *a += n_i * v as f64;
        }
        self.seg_weight[seg] += n_i;
    }

    /// Finish: weighted-average delta (zero for segments nobody uploaded —
    /// cannot happen when the round-robin coverage invariant holds).
    pub fn finish(self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.acc.len()];
        for (seg, r) in self.ranges.iter().enumerate() {
            let w = self.seg_weight[seg];
            if w <= 0.0 {
                continue;
            }
            for i in r.clone() {
                out[i] = (self.acc[i] / w) as f32;
            }
        }
        out
    }

    /// Segments that received at least one upload.
    pub fn covered(&self) -> Vec<bool> {
        self.seg_weight.iter().map(|&w| w > 0.0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_matches_eq2() {
        // two clients upload the same segment with weights 1 and 3
        let mut agg = SegmentAggregator::new(8, 2);
        agg.add_dense(0, &[1.0, 1.0, 1.0, 1.0], 1.0);
        agg.add_dense(0, &[5.0, 5.0, 5.0, 5.0], 3.0);
        agg.add_dense(1, &[2.0, 2.0, 2.0, 2.0], 2.0);
        let out = agg.finish();
        // (1*1 + 3*5)/4 = 4
        assert_eq!(&out[..4], &[4.0, 4.0, 4.0, 4.0]);
        assert_eq!(&out[4..], &[2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn sparse_contributions_average_against_zeros() {
        let mut agg = SegmentAggregator::new(4, 1);
        let sv = SparseVec { idx: vec![1], vals: vec![4.0] };
        agg.add_sparse(0, &sv, 1.0);
        agg.add_dense(0, &[0.0, 0.0, 0.0, 8.0], 1.0);
        let out = agg.finish();
        assert_eq!(out, vec![0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn uncovered_segment_yields_zero_delta() {
        let mut agg = SegmentAggregator::new(6, 3);
        agg.add_dense(1, &[3.0, 3.0], 1.0);
        assert_eq!(agg.covered(), vec![false, true, false]);
        let out = agg.finish();
        assert_eq!(out, vec![0.0, 0.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "outside segment")]
    fn sparse_indices_outside_segment_panic() {
        let mut agg = SegmentAggregator::new(8, 2);
        let sv = SparseVec { idx: vec![6], vals: vec![1.0] };
        agg.add_sparse(0, &sv, 1.0);
    }

    #[test]
    fn single_segment_is_plain_fedavg() {
        let mut agg = SegmentAggregator::new(3, 1);
        agg.add_dense(0, &[1.0, 2.0, 3.0], 2.0);
        agg.add_dense(0, &[3.0, 2.0, 1.0], 2.0);
        assert_eq!(agg.finish(), vec![2.0, 2.0, 2.0]);
    }
}
