//! Federated fine-tuning coordinator — the paper's system contribution.
//!
//! `FedRunner` drives the full protocol per round (DESIGN.md §Training
//! protocol): client sampling → downlink broadcast (dense or EcoLoRA
//! sparse) → staleness mixing (Eq. 3) → local SGD/DPO through the compiled
//! artifacts → uplink (dense, or EcoLoRA round-robin segment + adaptive
//! top-k + error feedback + Golomb wire) → per-segment weighted
//! aggregation (Eq. 2) → telemetry.
//!
//! `FedRunner` is the monolithic, single-threaded reference path. The
//! message-passing deployment of the same protocol lives in
//! `crate::cluster` (coordinator/participant over pluggable transports);
//! both paths share their deterministic setup and local-training code via
//! [`world`], and `tests/integration_cluster.rs` proves they agree
//! bitwise.

pub mod downlink;
pub mod robust;
pub mod round_robin;
pub mod sampling;
pub mod server;
pub mod session;
pub mod staleness;
pub mod world;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::baselines::Method;
use crate::compress::{dense_bytes, Encoding, KindIndex, SparsMode};
use crate::xla;
use crate::data::{corpus, preference, Dataset, PartitionKind};
use crate::eval::{DpoEvaluator, McEvaluator};
use crate::metrics::{sparsity_snapshot, RoundRecord, RunLog};
use crate::model::LoraKind;
use crate::util::rng::Rng;

use downlink::DownlinkState;
use server::SegmentAggregator;
use session::Session;
use world::{ClientState, World};

/// EcoLoRA communication configuration (`FedConfig.eco == None` = plain
/// baseline communication).
#[derive(Debug, Clone, Copy)]
pub struct EcoConfig {
    /// Round-robin segments N_s (1 disables RR — the Table 3 ablation).
    pub n_s: usize,
    /// Staleness decay β (Eq. 3).
    pub beta: f64,
    /// Uplink (and sparse-downlink) sparsification mode.
    pub spars: SparsMode,
    /// Position encoding (Golomb vs fixed — the Table 3 ablation).
    pub encoding: Encoding,
    /// Sparsify the downlink broadcast too (§3.4).
    pub downlink_sparse: bool,
}

impl Default for EcoConfig {
    fn default() -> Self {
        EcoConfig {
            n_s: 5,
            beta: 0.7,
            spars: SparsMode::Adaptive(Default::default()),
            encoding: Encoding::Golomb,
            downlink_sparse: true,
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct FedConfig {
    pub preset: String,
    pub artifacts_dir: PathBuf,
    pub method: Method,
    pub eco: Option<EcoConfig>,
    pub n_clients: usize,
    pub clients_per_round: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub n_samples: usize,
    pub partition: PartitionKind,
    pub eval_items: usize,
    pub eval_every: usize,
    /// Stop once eval accuracy reaches this (Tables 3/4 protocol).
    pub target_acc: Option<f64>,
    /// Value-alignment mode: federated DPO on preference pairs (Table 2).
    pub dpo: bool,
    pub dpo_beta: f32,
    /// Client sampling strategy (paper: uniform).
    pub sampling: sampling::Sampling,
    /// Robust aggregation statistic (default: the Eq. 2 mean). Non-mean
    /// aggregators run only on the cluster plane; the monolithic
    /// [`FedRunner`] rejects them (see [`FedRunner::new`]).
    pub aggregator: robust::Aggregator,
    /// Pretrained base checkpoint (created by `ecolora pretrain`).
    pub base_checkpoint: Option<PathBuf>,
    pub verbose: bool,
}

impl FedConfig {
    /// Paper-shaped defaults scaled to this testbed (Appendix A: 100
    /// clients, 10 per round, 40 rounds, Dirichlet α = 0.5).
    pub fn paper_default(preset: &str) -> Self {
        FedConfig {
            preset: preset.to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            method: Method::FedIt,
            eco: None,
            n_clients: 100,
            clients_per_round: 10,
            rounds: 40,
            local_steps: 5,
            lr: 0.5,
            seed: 42,
            n_samples: 4000,
            partition: PartitionKind::DirichletLabels { alpha: 0.5 },
            eval_items: 100,
            eval_every: 5,
            target_acc: None,
            dpo: false,
            dpo_beta: 0.5,
            sampling: sampling::Sampling::Uniform,
            aggregator: robust::Aggregator::Mean,
            base_checkpoint: None,
            verbose: false,
        }
    }

    /// Small fast profile for tests and benches.
    pub fn test_profile(preset: &str) -> Self {
        FedConfig {
            n_clients: 12,
            clients_per_round: 4,
            rounds: 4,
            local_steps: 2,
            n_samples: 240,
            eval_items: 24,
            eval_every: 2,
            ..Self::paper_default(preset)
        }
    }

    /// Artifact-free scale profile for the `--preset synthetic` client
    /// plane (10⁴–10⁶ simulated clients behind the mux). Evaluation is
    /// off — the synthetic schema has no compiled model — and EcoLoRA is
    /// on so the sparse compressor, wire codecs, and sharded aggregation
    /// carry real traffic at population scale.
    pub fn synthetic_profile(clients: usize) -> Self {
        let clients = clients.max(1);
        FedConfig {
            n_clients: clients,
            clients_per_round: clients.min(64),
            rounds: 2,
            local_steps: 1,
            n_samples: 256,
            eval_items: 0,
            eval_every: 0,
            target_acc: None,
            dpo: false,
            eco: Some(EcoConfig::default()),
            ..Self::paper_default("synthetic")
        }
    }

    /// Run label shared by the monolithic and cluster paths.
    pub fn run_label(&self) -> String {
        format!(
            "{}{}-{}",
            self.method.name(),
            if self.eco.is_some() { "+EcoLoRA" } else { "" },
            self.preset
        )
    }

    /// 64-bit fingerprint of every configuration field that must agree
    /// between the processes of a multi-host deployment for the federated
    /// run to be well-defined (the `serve`/`worker` handshake hard-rejects
    /// a `Join` whose digest differs — see `cluster::handshake` and
    /// docs/PROTOCOL.md §Handshake).
    ///
    /// Host-local fields — `artifacts_dir`, `base_checkpoint` paths,
    /// `verbose` — are deliberately excluded: the paths may differ per
    /// host as long as they hold the same bytes (`World::build` is a pure
    /// function of the remaining fields plus the checkpoint contents).
    /// FNV-1a over a canonical little-endian field serialization; not
    /// cryptographic — it catches operator mistakes, not adversaries
    /// (the auth token handles those).
    pub fn digest(&self) -> u64 {
        let mut h = ConfigHasher::new();
        h.str(&self.preset);
        h.str(self.method.name());
        match &self.eco {
            None => h.u8(0),
            Some(e) => {
                h.u8(1);
                h.u64(e.n_s as u64);
                h.f64(e.beta);
                match &e.spars {
                    SparsMode::Off => h.u8(0),
                    SparsMode::Fixed(k) => {
                        h.u8(1);
                        h.f64(*k);
                    }
                    SparsMode::Adaptive(a) => {
                        h.u8(2);
                        for s in [&a.a, &a.b] {
                            h.f64(s.k_min);
                            h.f64(s.k_max);
                            h.f64(s.gamma);
                        }
                    }
                }
                h.u8(match e.encoding {
                    Encoding::Golomb => 0,
                    Encoding::Fixed => 1,
                });
                h.u8(e.downlink_sparse as u8);
            }
        }
        h.u64(self.n_clients as u64);
        h.u64(self.clients_per_round as u64);
        h.u64(self.rounds as u64);
        h.u64(self.local_steps as u64);
        h.u64(self.lr.to_bits() as u64);
        h.u64(self.seed);
        h.u64(self.n_samples as u64);
        match &self.partition {
            PartitionKind::DirichletLabels { alpha } => {
                h.u8(0);
                h.f64(*alpha);
            }
            PartitionKind::DirichletClusters { alpha, k } => {
                h.u8(1);
                h.f64(*alpha);
                h.u64(*k as u64);
            }
            PartitionKind::TaskDomain => h.u8(2),
            PartitionKind::Iid => h.u8(3),
        }
        h.u64(self.eval_items as u64);
        h.u64(self.eval_every as u64);
        match self.target_acc {
            None => h.u8(0),
            Some(t) => {
                h.u8(1);
                h.f64(t);
            }
        }
        h.u8(self.dpo as u8);
        h.u64(self.dpo_beta.to_bits() as u64);
        h.u8(match self.sampling {
            sampling::Sampling::Uniform => 0,
            sampling::Sampling::WeightedBySamples => 1,
            sampling::Sampling::RoundRobinCohorts => 2,
        });
        let (agg_tag, agg_bits) = self.aggregator.digest_parts();
        h.u8(agg_tag);
        h.u64(agg_bits);
        h.finish()
    }
}

/// FNV-1a-64 accumulator over a canonical field serialization (see
/// [`FedConfig::digest`]). Every field write is length-delimited or
/// fixed-width, so distinct configurations cannot collide by
/// concatenation ambiguity.
struct ConfigHasher {
    h: u64,
}

impl ConfigHasher {
    fn new() -> ConfigHasher {
        ConfigHasher { h: 0xCBF2_9CE4_8422_2325 }
    }

    fn byte(&mut self, x: u8) {
        self.h ^= x as u64;
        self.h = self.h.wrapping_mul(0x0000_0100_0000_01B3);
    }

    fn u8(&mut self, x: u8) {
        self.byte(x);
    }

    fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.byte(b);
        }
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        for b in s.as_bytes() {
            self.byte(*b);
        }
    }

    fn finish(&self) -> u64 {
        self.h
    }
}

/// Outcome of a full federated run.
pub struct FedOutcome {
    pub log: RunLog,
    pub final_lora: Vec<f32>,
    pub final_acc: f64,
    pub final_margin: Option<f64>,
    pub reached_target_at: Option<usize>,
}

/// The monolithic coordinator.
pub struct FedRunner {
    pub cfg: FedConfig,
    pub session: Session,
    pub ds: Dataset,
    pairs: Vec<preference::PrefPair>,
    clients: Vec<ClientState>,
    global: Vec<f32>,
    kinds: Arc<Vec<LoraKind>>,
    kidx: Arc<KindIndex>,
    dl: Option<DownlinkState>,
    evaluator: McEvaluator,
    dpo_eval: Option<DpoEvaluator>,
    rng: Rng,
    l0: Option<f64>,
    l_prev: f64,
    lora_init: Vec<f32>,
}

impl FedRunner {
    pub fn new(cfg: FedConfig) -> Result<FedRunner> {
        anyhow::ensure!(
            cfg.aggregator == robust::Aggregator::Mean,
            "the monolithic runner only supports --aggregator mean; \
             robust aggregation runs on the cluster plane (cluster::run / ecolora serve)"
        );
        let mut world = World::build(&cfg)?;
        let clients: Vec<ClientState> =
            (0..cfg.n_clients).map(|i| world.client_state(&cfg, i)).collect();

        let dl = cfg.eco.filter(|e| e.downlink_sparse).map(|e| {
            DownlinkState::new(
                cfg.n_clients,
                world.lora_init.clone(),
                e.spars,
                e.encoding,
                world.kinds.clone(),
                world.kidx.clone(),
            )
        });

        let evaluator = McEvaluator::new(
            corpus::make_eval_set(&mut world.rng.fork(5), cfg.eval_items, &world.ccfg),
            world.ccfg.seq_tokens,
        );
        let dpo_eval = cfg.dpo.then(|| {
            DpoEvaluator::new(preference::generate_pairs(&mut world.rng.fork(6), 64, &world.ccfg))
        });

        Ok(FedRunner {
            global: world.lora_init.clone(),
            lora_init: world.lora_init,
            cfg,
            session: world.session,
            ds: world.ds,
            pairs: world.pairs,
            clients,
            kinds: world.kinds,
            kidx: world.kidx,
            dl,
            evaluator,
            dpo_eval,
            rng: world.rng,
            l0: None,
            l_prev: f64::NAN,
        })
    }

    pub fn schema(&self) -> &crate::model::Schema {
        &self.session.schema
    }

    pub fn global_lora(&self) -> &[f32] {
        &self.global
    }

    /// Run the configured number of rounds (early-stopping on target_acc).
    pub fn run(&mut self) -> Result<FedOutcome> {
        let label = self.cfg.run_label();
        let mut log = RunLog::new(label.clone());
        let mask = self.session.upload_mask(&self.cfg.method.grad_mask(&self.session.schema))?;
        let mut reached: Option<usize> = None;

        for t in 0..self.cfg.rounds {
            let rec = self.round(t as u64, &mask)?;
            let acc = rec.eval_acc;
            if self.cfg.verbose {
                eprintln!(
                    "[{label}] round {t}: loss {:.4} acc {} upM {:.3} downM {:.3} k=({:.2},{:.2})",
                    rec.global_loss,
                    acc.map_or("-".into(), |a| format!("{a:.3}")),
                    rec.up.params_m(),
                    rec.down.params_m(),
                    rec.k_a,
                    rec.k_b,
                );
            }
            log.push(rec);
            if let (Some(target), Some(a)) = (self.cfg.target_acc, acc) {
                if a >= target {
                    reached = Some(t);
                    break;
                }
            }
        }

        let final_acc = self.evaluator.accuracy(&self.session, &self.global)?;
        let final_margin = match &self.dpo_eval {
            Some(ev) => Some(ev.mean_margin(&self.session, &self.global, self.cfg.dpo_beta)?),
            None => None,
        };
        Ok(FedOutcome {
            final_lora: self.global.clone(),
            final_acc,
            final_margin,
            reached_target_at: reached,
            log,
        })
    }

    /// One synchronous round.
    fn round(&mut self, t: u64, mask: &xla::PjRtBuffer) -> Result<RoundRecord> {
        let n_t = self.cfg.clients_per_round.min(self.cfg.n_clients);
        let weights: Vec<f64> = self.clients.iter().map(|c| c.n_samples as f64).collect();
        let sampled = self.cfg.sampling.sample(
            self.cfg.n_clients, n_t, &weights, t, &mut self.rng.fork(1000 + t));
        let n_s = self.cfg.eco.map_or(1, |e| e.n_s.max(1)).min(n_t);
        let lora_total = self.session.schema.lora_total;

        let mut rec = RoundRecord { round: t as usize, ..Default::default() };
        let loss_signal = if self.l0.is_some() {
            (self.l0.unwrap(), self.l_prev)
        } else {
            (1.0, 1.0) // round 0: Eq. 4 sits at k_max
        };

        let mut agg = SegmentAggregator::new(lora_total, n_s);
        let mut flora_modules: Vec<(Vec<f32>, f64)> = Vec::new();
        let mut loss_acc = 0.0f64;
        let mut weight_acc = 0.0f64;
        let mut overhead = 0.0f64;
        let exec_before = self.session.exec_seconds.get();

        // FLoRA: fresh LoRA init shared by this round's cohort.
        let flora_init = self
            .cfg
            .method
            .restarts_lora()
            .then(|| self.session.schema.init_lora(&mut self.rng.fork(2000 + t)));

        for (slot, &ci) in sampled.iter().enumerate() {
            // ---- downlink --------------------------------------------------
            let t0 = Instant::now();
            let start_global: Vec<f32> = if self.cfg.method.restarts_lora() {
                // FLoRA re-distributes the stacked modules (merged into the
                // base) — the downlink stays N_t × module even with EcoLoRA
                // (the paper's Table 1 FLoRA totals remain stack-dominated).
                let p = self.cfg.method.dense_download_params(&self.session.schema, n_t);
                rec.down.add(p, dense_bytes(p));
                self.global.clone()
            } else { match &mut self.dl {
                Some(dl) => {
                    let b = dl.broadcast(ci, &self.global, loss_signal.0, loss_signal.1, false)?;
                    rec.down.add(b.params, b.bytes);
                    b.reconstructed
                }
                None => {
                    let p = self.cfg.method.dense_download_params(&self.session.schema, n_t);
                    rec.down.add(p, dense_bytes(p));
                    self.global.clone()
                }
            } };
            overhead += t0.elapsed().as_secs_f64();

            // ---- local init: FLoRA restart or Eq. 3 mixing ------------------
            let client = &mut self.clients[ci];
            let base_point: Vec<f32> = match &flora_init {
                Some(init) => init.clone(),
                None => start_global.clone(),
            };
            let local = if flora_init.is_some() {
                base_point.clone()
            } else if let Some(eco) = self.cfg.eco {
                let staleness = (t.saturating_sub(client.tau)).max(1);
                let mut mixed = client.lora.clone();
                staleness::mix_into_local(eco.beta, staleness, &start_global, &mut mixed);
                mixed
            } else {
                start_global.clone()
            };

            // ---- local training (code shared with cluster participants) ----
            let mut brng = self.rng.fork(world::batch_salt(self.cfg.dpo, t, ci));
            let (local, mean_loss) = world::local_train(
                &self.session, &self.cfg, &self.ds, &self.pairs, client, local, &mut brng, mask)?;
            loss_acc += mean_loss * client.n_samples as f64;
            weight_acc += client.n_samples as f64;

            // ---- uplink -----------------------------------------------------
            let t1 = Instant::now();
            let mut update = vec![0.0f32; lora_total];
            for i in 0..lora_total {
                update[i] = local[i] - base_point[i];
            }
            match (&mut client.comp, self.cfg.eco) {
                (Some(comp), Some(_eco)) => {
                    let out = comp.compress(&update, loss_signal.0, loss_signal.1);
                    rec.k_a = out.k.0;
                    rec.k_b = out.k.1;
                    let seg = round_robin::segment_for(slot, t as usize, n_s);
                    let range = agg.range(seg).clone();
                    // encodes straight from the binary-searched range
                    // window of out.sv (byte-identical to the historical
                    // restrict-then-encode; comp.encoding == eco.encoding)
                    let bytes = comp.encode_range(&out, &range)?;
                    // the server decodes the exact wire message
                    let params = agg.add_wire(seg, &bytes, &self.kidx, client.n_samples as f64)?;
                    rec.up.add(params, bytes.len());
                }
                _ => {
                    let p = self.cfg.method.dense_upload_params(&self.session.schema);
                    rec.up.add(p, dense_bytes(p));
                    if self.cfg.method.restarts_lora() {
                        // FLoRA dense: each client module merges individually
                        flora_modules.push((local.clone(), client.n_samples as f64));
                    } else {
                        agg.add_dense(0, &update, client.n_samples as f64);
                    }
                }
            }
            overhead += t1.elapsed().as_secs_f64();

            // ---- persist client state --------------------------------------
            client.lora = local;
            client.tau = t;
        }

        // ---- aggregation (Eq. 2) + global advance ---------------------------
        let t2 = Instant::now();
        rec.seg_uncovered = agg.covered().iter().filter(|&&c| !c).count();
        if self.cfg.method.restarts_lora() {
            if self.cfg.eco.is_some() {
                // FLoRA + EcoLoRA: merge the segment-aggregated mean module.
                let delta = agg.finish();
                let mut module = flora_init.clone().unwrap();
                for i in 0..lora_total {
                    module[i] += delta[i];
                }
                self.session.merge_lora(&module, 1.0)?;
            } else {
                // exact stacking: merge every client module with weight w_i
                let w_total: f64 = flora_modules.iter().map(|(_, w)| w).sum();
                for (module, w) in &flora_modules {
                    self.session.merge_lora(module, (*w / w_total.max(1.0)) as f32)?;
                }
            }
            // clients restart next round; global LoRA is the zero-adapter
            self.global = self.lora_init.clone();
        } else {
            let delta = agg.finish();
            for i in 0..lora_total {
                self.global[i] += delta[i];
            }
        }
        overhead += t2.elapsed().as_secs_f64();

        // ---- telemetry -------------------------------------------------------
        let round_loss = loss_acc / weight_acc.max(1.0);
        if self.l0.is_none() {
            self.l0 = Some(round_loss);
        }
        self.l_prev = round_loss;
        rec.global_loss = round_loss;
        rec.overhead_s = overhead;
        rec.cohort = n_t;
        rec.shards = 1; // the monolithic path is a one-shard plane
        rec.aggregator = self.cfg.aggregator.name(); // always "mean" here (see new())
        rec.population = self.cfg.n_clients;
        rec.active_cohort = n_t; // no resampling plane: cohort == dispatched set
        rec.compute_s = (self.session.exec_seconds.get() - exec_before) / n_t.max(1) as f64;
        let snap = sparsity_snapshot(&self.global, &self.kinds);
        rec.gini_a = snap.gini_a;
        rec.gini_b = snap.gini_b;

        let eval_now = self.cfg.target_acc.is_some()
            || (self.cfg.eval_every > 0
                && (t as usize % self.cfg.eval_every == self.cfg.eval_every - 1
                    || t as usize + 1 == self.cfg.rounds));
        if eval_now {
            rec.eval_acc = Some(self.evaluator.accuracy(&self.session, &self.global)?);
        }
        Ok(rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_digest_is_stable_and_ignores_host_local_fields() {
        let cfg = FedConfig::test_profile("tiny");
        let d = cfg.digest();
        assert_eq!(d, cfg.clone().digest(), "digest is a pure function");

        // host-local fields must not perturb the handshake fingerprint
        let mut local = cfg.clone();
        local.artifacts_dir = PathBuf::from("/somewhere/else");
        local.base_checkpoint = Some(PathBuf::from("/elsewhere/ckpt.bin"));
        local.verbose = true;
        assert_eq!(local.digest(), d);
    }

    #[test]
    fn config_digest_detects_run_defining_divergence() {
        let base = FedConfig::test_profile("tiny");
        let d = base.digest();
        let mut variants = Vec::new();

        let mut c = base.clone();
        c.seed += 1;
        variants.push(("seed", c));
        let mut c = base.clone();
        c.rounds += 1;
        variants.push(("rounds", c));
        let mut c = base.clone();
        c.method = Method::FfaLora;
        variants.push(("method", c));
        let mut c = base.clone();
        c.eco = Some(EcoConfig::default());
        variants.push(("eco on", c));
        let mut c = base.clone();
        c.eco = Some(EcoConfig { n_s: 3, ..EcoConfig::default() });
        variants.push(("eco n_s", c));
        let mut c = base.clone();
        c.lr *= 2.0;
        variants.push(("lr", c));
        let mut c = base.clone();
        c.partition = PartitionKind::Iid;
        variants.push(("partition", c));
        let mut c = base.clone();
        c.sampling = sampling::Sampling::RoundRobinCohorts;
        variants.push(("sampling", c));
        let mut c = base.clone();
        c.target_acc = Some(0.9);
        variants.push(("target_acc", c));
        let mut c = base.clone();
        c.aggregator = robust::Aggregator::TrimmedMean { beta: 0.2 };
        variants.push(("aggregator kind", c));
        let mut c = base.clone();
        c.aggregator = robust::Aggregator::TrimmedMean { beta: 0.25 };
        variants.push(("aggregator param", c));

        for (what, v) in variants {
            assert_ne!(v.digest(), d, "digest must change when {what} changes");
        }
    }
}
