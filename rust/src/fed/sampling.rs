//! Client sampling strategies. The paper samples uniformly (Appendix A);
//! related work (§2.3) uses contribution-aware sampling. Both are provided
//! so the sampling axis can be ablated, plus a deterministic cohort rotor
//! for reproducible stress tests.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Uniform without replacement (the paper's setting).
    Uniform,
    /// Probability proportional to local sample count (importance-style).
    WeightedBySamples,
    /// Deterministic rotating cohorts: round t takes clients
    /// [t*n_t, (t+1)*n_t) mod n — worst case for staleness (every client
    /// idles n/n_t − 1 rounds), exercising Eq. 3 hard.
    RoundRobinCohorts,
}

impl Sampling {
    pub fn parse(s: &str) -> Option<Sampling> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Some(Sampling::Uniform),
            "weighted" => Some(Sampling::WeightedBySamples),
            "cohorts" => Some(Sampling::RoundRobinCohorts),
            _ => None,
        }
    }

    /// Sample `n_t` distinct clients for round `t`.
    pub fn sample(
        &self,
        n_clients: usize,
        n_t: usize,
        client_weights: &[f64],
        t: u64,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let n_t = n_t.min(n_clients);
        match self {
            Sampling::Uniform => rng.sample_indices(n_clients, n_t),
            Sampling::WeightedBySamples => {
                // weighted sampling without replacement (successive draws)
                let mut w = client_weights.to_vec();
                w.resize(n_clients, 1.0);
                let mut out = Vec::with_capacity(n_t);
                for _ in 0..n_t {
                    let i = rng.categorical(&w);
                    out.push(i);
                    w[i] = 0.0;
                }
                out
            }
            Sampling::RoundRobinCohorts => {
                let start = (t as usize * n_t) % n_clients;
                (0..n_t).map(|j| (start + j) % n_clients).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn all_strategies_return_distinct_valid_clients() {
        propcheck(100, |rng| {
            let n = rng.below(50) + 2;
            let n_t = rng.below(n) + 1;
            let w: Vec<f64> = (0..n).map(|_| rng.below(100) as f64 + 1.0).collect();
            for s in [Sampling::Uniform, Sampling::WeightedBySamples, Sampling::RoundRobinCohorts]
            {
                let picked = s.sample(n, n_t, &w, rng.below(1000) as u64, rng);
                assert_eq!(picked.len(), n_t);
                let mut u = picked.clone();
                u.sort_unstable();
                u.dedup();
                assert_eq!(u.len(), n_t, "{s:?} returned duplicates");
                assert!(picked.iter().all(|&c| c < n));
            }
        });
    }

    #[test]
    fn weighted_prefers_heavy_clients() {
        let mut rng = Rng::new(0);
        let mut counts = vec![0usize; 4];
        let w = vec![100.0, 1.0, 1.0, 1.0];
        for t in 0..2000 {
            for c in Sampling::WeightedBySamples.sample(4, 1, &w, t, &mut rng) {
                counts[c] += 1;
            }
        }
        assert!(counts[0] > 1500, "{counts:?}");
    }

    #[test]
    fn cohorts_cover_everyone_over_a_cycle() {
        let (n, n_t) = (10, 3);
        let mut seen = vec![false; n];
        let mut rng = Rng::new(1);
        for t in 0..10 {
            for c in Sampling::RoundRobinCohorts.sample(n, n_t, &[], t, &mut rng) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Sampling::parse("uniform"), Some(Sampling::Uniform));
        assert_eq!(Sampling::parse("weighted"), Some(Sampling::WeightedBySamples));
        assert_eq!(Sampling::parse("cohorts"), Some(Sampling::RoundRobinCohorts));
        assert_eq!(Sampling::parse("x"), None);
    }
}
