//! Staleness-weighted model mixing (paper §3.3, Eq. 3).
//!
//!   P̂ᵢ = (1 − e^{−β(t−τ)}) Pᵗ + e^{−β(t−τ)} Pᵢ^τ
//!
//! A client idle since round τ starts local optimization from a blend of
//! the fresh global model and its stale local model; the exponential decay
//! (Chen et al. 2019) shifts weight toward the global model as staleness
//! grows, protecting convergence in cross-device settings.

use crate::util::linalg;

/// Weight on the GLOBAL model for staleness `t − τ` (rounds).
pub fn global_weight(beta: f64, staleness: u64) -> f64 {
    1.0 - (-beta * staleness as f64).exp()
}

/// Mix in place: `local = (1−w_g)·local + w_g·global` per Eq. 3.
pub fn mix_into_local(beta: f64, staleness: u64, global: &[f32], local: &mut [f32]) {
    let w_g = global_weight(beta, staleness) as f32;
    linalg::mix(w_g, global, local);
}

/// Server-side dual of Eq. 3: the FedAvg-weight multiplier for a LATE
/// uplink folded `staleness` rounds after the round it was computed
/// against, `e^{−β·staleness}` (the complement of [`global_weight`]).
///
/// Quorum rounds (`cluster::RoundPolicy::Quorum`) buffer straggler
/// uplinks instead of blocking on them; when the buffer is folded into a
/// later round's Eq. 2 aggregate, this discount shifts weight away from
/// the stale contribution exactly as the client-side mixing shifts weight
/// away from a stale local model.
pub fn stale_discount(beta: f64, staleness: u64) -> f64 {
    (-beta * staleness as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_staleness_keeps_local() {
        // t == τ (client participated this round already): weight on the
        // global model is 0 — pure local.
        assert_eq!(global_weight(0.5, 0), 0.0);
        let global = vec![10.0f32; 4];
        let mut local = vec![1.0f32; 4];
        mix_into_local(0.5, 0, &global, &mut local);
        assert_eq!(local, vec![1.0; 4]);
    }

    #[test]
    fn infinite_staleness_converges_to_global() {
        let w = global_weight(0.5, 1000);
        assert!((w - 1.0).abs() < 1e-12);
        let global = vec![10.0f32; 4];
        let mut local = vec![1.0f32; 4];
        mix_into_local(0.5, 1000, &global, &mut local);
        assert_eq!(local, vec![10.0; 4]);
    }

    #[test]
    fn weight_monotone_in_staleness_and_beta() {
        let mut prev = -1.0;
        for s in 0..10 {
            let w = global_weight(0.7, s);
            assert!(w > prev);
            prev = w;
        }
        assert!(global_weight(2.0, 3) > global_weight(0.5, 3));
    }

    #[test]
    fn stale_discount_complements_global_weight() {
        for s in 0..10 {
            let (w, d) = (global_weight(0.7, s), stale_discount(0.7, s));
            assert!((w + d - 1.0).abs() < 1e-12, "s={s}: {w} + {d} != 1");
        }
        // fresh uplink: full weight; very stale uplink: negligible weight
        assert_eq!(stale_discount(0.7, 0), 1.0);
        assert!(stale_discount(0.7, 100) < 1e-12);
        // monotone decreasing in staleness
        let mut prev = 2.0;
        for s in 0..10 {
            let d = stale_discount(0.5, s);
            assert!(d < prev);
            prev = d;
        }
    }

    #[test]
    fn one_round_staleness_matches_formula() {
        let beta = 0.8;
        let w = global_weight(beta, 1);
        assert!((w - (1.0 - (-beta as f64).exp())).abs() < 1e-12);
        let global = vec![2.0f32];
        let mut local = vec![0.0f32];
        mix_into_local(beta, 1, &global, &mut local);
        assert!((local[0] as f64 - 2.0 * w).abs() < 1e-6);
    }
}
