//! Model session: schema + compiled artifacts + device-resident state.
//!
//! One `Session` per process wraps the PJRT engine, keeps the frozen base
//! weights in a single device buffer shared by every simulated client, and
//! exposes typed step functions (`train_step`, `eval_rows`, `dpo_step`,
//! `pretrain`, `merge_lora`). Token/LoRA transfers are per-call (small);
//! the base is re-uploaded only when FLoRA merges into it.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::xla;
use crate::xla::PjRtBuffer;

use crate::model::Schema;
use crate::runtime::{literal_f32, literal_scalar_f32, Engine, Exec};
use crate::util::rng::Rng;

use super::world::WorldSeed;

pub struct Session {
    /// Shared PJRT engine. `Arc` so many mux-plane sessions in one
    /// process reuse one compiled-executable cache (startup cost
    /// amortizes across same-config clients).
    pub engine: Arc<Engine>,
    pub schema: Schema,
    train: Arc<Exec>,
    eval_: Arc<Exec>,
    pretrain_: Option<Arc<Exec>>,
    merge_: Option<Arc<Exec>>,
    dpo_: Option<Arc<Exec>>,
    /// Frozen base weights, resident on device.
    base_buf: PjRtBuffer,
    /// Host copy of the base (FLoRA merge bookkeeping, checkpointing).
    base_host: Vec<f32>,
    /// Wall-clock spent inside compiled executions (perf accounting).
    pub exec_seconds: std::cell::Cell<f64>,
    pub exec_calls: std::cell::Cell<u64>,
}

impl Session {
    /// Load a preset's artifacts; base starts at random init unless a
    /// pretrained checkpoint is supplied via `load_base`.
    pub fn new(artifacts_dir: &Path, preset: &str, rng: &mut Rng) -> Result<Session> {
        let schema = Schema::load(artifacts_dir, preset)?;
        let engine = Arc::new(Engine::new(artifacts_dir)?);
        let base_host = schema.init_base(rng);
        Session::assemble(engine, schema, base_host)
    }

    /// Layer a session over an already-built [`WorldSeed`], sharing
    /// `engine` (and therefore its compiled-executable cache) with every
    /// other session in the process. Consumes NO randomness — the seed
    /// already drew the base init — so any number of sessions can be
    /// materialized without perturbing the world's streams.
    pub fn from_seed(engine: Arc<Engine>, seed: &WorldSeed) -> Result<Session> {
        Session::assemble(engine, (*seed.schema).clone(), seed.base_host.clone())
    }

    fn assemble(engine: Arc<Engine>, schema: Schema, base_host: Vec<f32>) -> Result<Session> {
        let train = engine.load_tagged(&schema, "train")?;
        let eval_ = engine.load_tagged(&schema, "eval")?;
        let pretrain_ = schema
            .artifacts
            .contains_key("pretrain")
            .then(|| engine.load_tagged(&schema, "pretrain"))
            .transpose()?;
        let merge_ = schema
            .artifacts
            .contains_key("merge")
            .then(|| engine.load_tagged(&schema, "merge"))
            .transpose()?;
        let dpo_ = schema
            .artifacts
            .contains_key("dpo")
            .then(|| engine.load_tagged(&schema, "dpo"))
            .transpose()?;
        let base_buf = engine.upload_f32(&base_host, &[schema.base_total])?;
        Ok(Session {
            engine,
            schema,
            train,
            eval_,
            pretrain_,
            merge_,
            dpo_,
            base_buf,
            base_host,
            exec_seconds: std::cell::Cell::new(0.0),
            exec_calls: std::cell::Cell::new(0),
        })
    }

    fn timed_run(&self, exec: &Exec, args: &[&PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let t0 = std::time::Instant::now();
        let out = exec.run(args)?;
        self.exec_seconds
            .set(self.exec_seconds.get() + t0.elapsed().as_secs_f64());
        self.exec_calls.set(self.exec_calls.get() + 1);
        Ok(out)
    }

    // ---- base management ---------------------------------------------------

    pub fn base_host(&self) -> &[f32] {
        &self.base_host
    }

    /// Replace the base weights (pretrained checkpoint or FLoRA merge).
    pub fn set_base(&mut self, base: Vec<f32>) -> Result<()> {
        anyhow::ensure!(base.len() == self.schema.base_total, "base length");
        self.base_buf = self.engine.upload_f32(&base, &[self.schema.base_total])?;
        self.base_host = base;
        Ok(())
    }

    /// Load a base checkpoint written by `save_base`.
    pub fn load_base(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() == 4 * self.schema.base_total, "checkpoint size");
        let base: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        self.set_base(base)
    }

    pub fn save_base(&self, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(4 * self.base_host.len());
        for v in &self.base_host {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    // ---- step functions ---------------------------------------------------

    /// One local SGD step: returns (new_lora, loss).
    pub fn train_step(
        &self,
        lora: &[f32],
        tokens: &[i32],
        lr: f32,
        grad_mask: &PjRtBuffer,
    ) -> Result<(Vec<f32>, f32)> {
        let s = &self.schema;
        let b = s.config.batch;
        let seq = s.config.seq_len + 1;
        anyhow::ensure!(tokens.len() == b * seq, "token batch shape");
        let lora_buf = self.engine.upload_f32(lora, &[s.lora_total])?;
        let tok_buf = self.engine.upload_i32(tokens, &[b, seq])?;
        let lr_buf = self.engine.upload_scalar_f32(lr)?;
        let outs = self.timed_run(
            &self.train,
            &[&lora_buf, &self.base_buf, &tok_buf, &lr_buf, grad_mask],
        )?;
        anyhow::ensure!(outs.len() == 2, "train_step outputs");
        Ok((literal_f32(&outs[0])?, literal_scalar_f32(&outs[1])?))
    }

    /// Run `steps` local steps over batches provided by `next_batch`,
    /// returning (final lora, mean loss).
    pub fn train_chain<F: FnMut() -> Vec<i32>>(
        &self,
        lora: Vec<f32>,
        steps: usize,
        lr: f32,
        grad_mask: &PjRtBuffer,
        mut next_batch: F,
    ) -> Result<(Vec<f32>, f64)> {
        let mut cur = lora;
        let mut loss_sum = 0.0f64;
        for _ in 0..steps {
            let batch = next_batch();
            let (next, loss) = self.train_step(&cur, &batch, lr, grad_mask)?;
            cur = next;
            loss_sum += loss as f64;
        }
        Ok((cur, loss_sum / steps.max(1) as f64))
    }

    /// Per-row eval losses for `eval_batch` rows of tokens.
    pub fn eval_rows(&self, lora: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let s = &self.schema;
        let be = s.config.eval_batch;
        let seq = s.config.seq_len + 1;
        anyhow::ensure!(tokens.len() == be * seq, "eval batch shape");
        let lora_buf = self.engine.upload_f32(lora, &[s.lora_total])?;
        let tok_buf = self.engine.upload_i32(tokens, &[be, seq])?;
        let outs = self.timed_run(&self.eval_, &[&lora_buf, &self.base_buf, &tok_buf])?;
        literal_f32(&outs[0])
    }

    /// One full-parameter pretraining step on the plain base model.
    pub fn pretrain_step(&mut self, tokens: &[i32], lr: f32) -> Result<f32> {
        let s = &self.schema;
        let b = s.config.batch;
        let seq = s.config.seq_len + 1;
        let pre = self
            .pretrain_
            .clone()
            .ok_or_else(|| anyhow!("preset {} lacks pretrain artifact", s.preset))?;
        let tok_buf = self.engine.upload_i32(tokens, &[b, seq])?;
        let lr_buf = self.engine.upload_scalar_f32(lr)?;
        let outs = self.timed_run(&pre, &[&self.base_buf, &tok_buf, &lr_buf])?;
        anyhow::ensure!(outs.len() == 2, "pretrain outputs");
        let new_base = literal_f32(&outs[0])?;
        let loss = literal_scalar_f32(&outs[1])?;
        // keep base on device for the next step; host copy refreshed too
        self.base_buf = self.engine.upload_f32(&new_base, &[s.base_total])?;
        self.base_host = new_base;
        Ok(loss)
    }

    /// Merge a LoRA module into the base with weight `scale` (FLoRA).
    pub fn merge_lora(&mut self, lora: &[f32], scale: f32) -> Result<()> {
        let s = &self.schema;
        let m = self
            .merge_
            .clone()
            .ok_or_else(|| anyhow!("preset {} lacks merge artifact", s.preset))?;
        let lora_buf = self.engine.upload_f32(lora, &[s.lora_total])?;
        let scale_buf = self.engine.upload_scalar_f32(scale)?;
        let outs = self.timed_run(&m, &[&self.base_buf, &lora_buf, &scale_buf])?;
        let new_base = literal_f32(&outs[0])?;
        self.base_buf = self.engine.upload_f32(&new_base, &[s.base_total])?;
        self.base_host = new_base;
        Ok(())
    }

    /// One federated-DPO step: returns (new_lora, loss, reward margin).
    pub fn dpo_step(
        &self,
        lora: &[f32],
        chosen: &[i32],
        rejected: &[i32],
        lr: f32,
        beta: f32,
        grad_mask: &PjRtBuffer,
    ) -> Result<(Vec<f32>, f32, f32)> {
        let s = &self.schema;
        let b = s.config.batch;
        let seq = s.config.seq_len + 1;
        let dpo = self
            .dpo_
            .clone()
            .ok_or_else(|| anyhow!("preset {} lacks dpo artifact", s.preset))?;
        let lora_buf = self.engine.upload_f32(lora, &[s.lora_total])?;
        let c_buf = self.engine.upload_i32(chosen, &[b, seq])?;
        let r_buf = self.engine.upload_i32(rejected, &[b, seq])?;
        let lr_buf = self.engine.upload_scalar_f32(lr)?;
        let beta_buf = self.engine.upload_scalar_f32(beta)?;
        let outs = self.timed_run(
            &dpo,
            &[&lora_buf, &self.base_buf, &c_buf, &r_buf, &lr_buf, &beta_buf, grad_mask],
        )?;
        anyhow::ensure!(outs.len() == 3, "dpo outputs");
        Ok((
            literal_f32(&outs[0])?,
            literal_scalar_f32(&outs[1])?,
            literal_scalar_f32(&outs[2])?,
        ))
    }

    /// Upload a gradient mask once (reused across every step).
    pub fn upload_mask(&self, mask: &[f32]) -> Result<PjRtBuffer> {
        self.engine.upload_f32(mask, &[self.schema.lora_total])
    }
}
