//! Round-robin segment assignment (paper §3.3).
//!
//! Client at sampled-slot `j` in round `t` uploads segment
//! `(j + t) mod N_s`. Using the slot index (not the global client id)
//! guarantees the paper's coverage requirement — every segment is uploaded
//! by at least one client per round whenever `N_s <= N_t` — which random
//! global-id sampling cannot guarantee.

/// Segment id for sampled-slot `slot` in round `round`.
pub fn segment_for(slot: usize, round: usize, n_s: usize) -> usize {
    (slot + round) % n_s
}

/// Slots (positions in the sampled set) assigned to `segment` this round.
pub fn slots_for_segment(segment: usize, round: usize, n_s: usize, n_t: usize) -> Vec<usize> {
    (0..n_t).filter(|&j| segment_for(j, round, n_s) == segment).collect()
}

/// Verify the coverage invariant for a round configuration.
pub fn covers_all_segments(round: usize, n_s: usize, n_t: usize) -> bool {
    (0..n_s).all(|s| !slots_for_segment(s, round, n_s, n_t).is_empty())
}

/// Per-segment coverage given the slots that actually reported: quorum
/// rounds can close before a segment's only uploader lands, leaving that
/// segment's delta zero for the round (`SegmentAggregator::covered`
/// observes the same thing on the aggregation plane).
pub fn covered_segments(reported_slots: &[usize], round: usize, n_s: usize) -> Vec<bool> {
    let mut covered = vec![false; n_s];
    for &slot in reported_slots {
        covered[segment_for(slot, round, n_s)] = true;
    }
    covered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn matches_paper_worked_example() {
        // §3.3: N_t = 5, N_s = 3, t = 0 — clients 0..4 upload 0,1,2,0,1.
        let segs: Vec<usize> = (0..5).map(|j| segment_for(j, 0, 3)).collect();
        assert_eq!(segs, vec![0, 1, 2, 0, 1]);
        assert_eq!(slots_for_segment(0, 0, 3, 5), vec![0, 3]);
        assert_eq!(slots_for_segment(1, 0, 3, 5), vec![1, 4]);
        assert_eq!(slots_for_segment(2, 0, 3, 5), vec![2]);
    }

    #[test]
    fn full_coverage_whenever_ns_le_nt() {
        propcheck(300, |rng| {
            let n_t = rng.below(32) + 1;
            let n_s = rng.below(n_t) + 1;
            let round = rng.below(1000);
            assert!(covers_all_segments(round, n_s, n_t));
        });
    }

    #[test]
    fn rotation_over_rounds_touches_all_segments_per_slot() {
        // any fixed slot uploads every segment over N_s consecutive rounds
        let n_s = 5;
        for slot in 0..7 {
            let mut seen: Vec<usize> = (0..n_s).map(|t| segment_for(slot, t, n_s)).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n_s).collect::<Vec<_>>());
        }
    }

    #[test]
    fn covered_segments_tracks_reported_slots() {
        // §3.3 worked example: slots 0..4 upload segments 0,1,2,0,1 — if
        // only slots 0 and 4 report, segment 2 is the coverage gap
        assert_eq!(covered_segments(&[0, 4], 0, 3), vec![true, true, false]);
        assert_eq!(covered_segments(&[], 0, 3), vec![false, false, false]);
        assert_eq!(covered_segments(&[0, 1, 2], 0, 3), vec![true, true, true]);
        // a full cohort always covers when n_s <= n_t
        propcheck(100, |rng| {
            let n_t = rng.below(16) + 1;
            let n_s = rng.below(n_t) + 1;
            let round = rng.below(100);
            let all: Vec<usize> = (0..n_t).collect();
            assert!(covered_segments(&all, round, n_s).iter().all(|&c| c));
        });
    }

    #[test]
    fn balanced_assignment_within_round() {
        // with n_t a multiple of n_s, every segment gets n_t/n_s uploaders
        let (n_s, n_t) = (5, 10);
        for round in 0..10 {
            for s in 0..n_s {
                assert_eq!(slots_for_segment(s, round, n_s, n_t).len(), n_t / n_s);
            }
        }
    }
}
