//! Communication accounting + round telemetry.
//!
//! Tracks exactly what the paper reports: uploaded / downloaded parameter
//! counts and wire bytes per round (excluding the initial base-model
//! distribution, per Appendix A), loss curves, eval scores, per-matrix
//! Gini coefficients (Figure 2), and wall-clock timers.

use std::fmt::Write as _;

use crate::util::stats;

/// One communication direction's totals.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommTotals {
    /// Transmitted parameter count (the paper's "Param." columns).
    pub params: u64,
    /// Exact on-the-wire bytes (drives the netsim).
    pub bytes: u64,
}

impl CommTotals {
    pub fn add(&mut self, params: usize, bytes: usize) {
        self.params += params as u64;
        self.bytes += bytes as u64;
    }

    pub fn merge(&mut self, other: &CommTotals) {
        self.params += other.params;
        self.bytes += other.bytes;
    }

    pub fn params_m(&self) -> f64 {
        self.params as f64 / 1e6
    }
}

/// Per-round record (one row of the training log).
#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub global_loss: f64,
    pub eval_acc: Option<f64>,
    pub up: CommTotals,
    pub down: CommTotals,
    pub k_a: f64,
    pub k_b: f64,
    pub gini_a: f64,
    pub gini_b: f64,
    /// L3 coordinator overhead this round (compress/aggregate/encode), s.
    pub overhead_s: f64,
    /// Local training compute per sampled client (mean), s.
    pub compute_s: f64,
    /// Cohort size N_t (slots dispatched this round).
    pub cohort: usize,
    /// Slots still outstanding when the round closed (quorum rounds only;
    /// their uplinks are buffered for the next round's staleness fold).
    pub stragglers: usize,
    /// Buffered late uplinks from earlier rounds folded into this round's
    /// aggregate with the Eq. 3 staleness discount.
    pub late_folds: usize,
    /// Timed-out slots re-dispatched to a replacement client.
    pub resampled: usize,
    /// Results discarded without folding: a slot already filled by a
    /// replacement (or vice versa), or a buffered late uplink that could
    /// not be folded into this round's aggregate.
    pub orphaned: usize,
    /// Seconds from task dispatch until the quorum was reached (equals the
    /// full collect wait under `RoundPolicy::Sync`).
    pub quorum_wait_s: f64,
    /// Aggregation-plane shard count (1 = single aggregator; the
    /// monolithic runner also reports 1).
    pub shards: usize,
    /// Max wall milliseconds any one shard spent decoding + accumulating
    /// this round (the aggregation plane's critical path).
    pub shard_agg_ms_max: f64,
    /// Max router→shard queue backlog observed during collect.
    pub router_queue_max: usize,
    /// Straggler payloads rejected by the late-buffer byte cap
    /// (`cluster::shard::LATE_BUFFER_MAX_BYTES`).
    pub late_evicted: usize,
    /// Round-robin segments that received NO contribution this round —
    /// always 0 under `Sync` (the §3.3 coverage invariant), possibly
    /// positive when a quorum round closes before a segment's only
    /// uploader reports (that segment's delta stays zero for the round).
    pub seg_uncovered: usize,
    /// Worker connections that died during this round (send failure or
    /// reader hangup). Always 0 for in-process and monolithic runs; a
    /// multi-process `serve` run counts each lost `ecolora worker` link.
    pub worker_drops: usize,
    /// Worker connections re-admitted into a previously-dropped slot
    /// during this round (multi-process rejoins; see `cluster::deploy`).
    pub worker_rejoins: usize,
    /// Total simulated client population N (the mux plane decouples this
    /// from per-round cost; the monolithic runner reports `n_clients`).
    pub population: usize,
    /// Tasks successfully dispatched this round (initial cohort plus
    /// resample waves) — the denominator of the O(active cohort) claim.
    pub active_cohort: usize,
    /// Mux compute-pool threads (0 for the threads plane, the monolithic
    /// runner, and multi-process serve coordinators).
    pub mux_workers: usize,
    /// Coordinator scheduling wall-milliseconds this round: sampling,
    /// downlink build, dispatch, resample waves, and round close. Must
    /// stay O(active cohort), not O(population).
    pub sched_ms: f64,
    /// Bytes appended to the durable round journal this round (round
    /// open through the last pre-close record; 0 when `--journal` is
    /// off). Deterministic: a resumed run re-journals the identical
    /// record stream.
    pub journal_bytes: u64,
    /// Wall milliseconds the round-close journal fsync took (0 under
    /// `--journal-sync off` and for replayed rounds).
    pub journal_fsync_ms: f64,
    /// Frame bytes the coordinator sent to remote `ecolora shard`
    /// processes this round (0 when the aggregation plane is in-process).
    pub shard_tx_bytes: u64,
    /// Frame bytes received from remote shard processes this round
    /// (0 when the aggregation plane is in-process).
    pub shard_rx_bytes: u64,
    /// Max milliseconds from a remote shard's round-close send to its
    /// report's arrival (the aggregation tier's network critical path;
    /// 0 in-process).
    pub shard_rtt_ms_max: f64,
    /// Robust-aggregation statistic label (`fed::robust::Aggregator::name`,
    /// e.g. `mean`, `trimmed-mean:0.2`). Both runner paths stamp it every
    /// round; empty only on hand-built test records.
    pub aggregator: String,
    /// Contributions dropped by coordinate-wise trimming this round,
    /// summed over segments (0 under `mean`).
    pub clients_trimmed: u64,
    /// Contributions rescaled by the L2 norm clip this round.
    pub clip_applied: u64,
}

/// The CSV header row `RunLog::to_csv` emits — shared with the e2e
/// suites' `NONDETERMINISTIC_COLS` allowlists so a new column cannot
/// silently join (or silently skip) the bitwise-compared set.
pub const CSV_HEADER: &str = "round,loss,acc,up_params,up_bytes,down_params,down_bytes,k_a,k_b,gini_a,gini_b,overhead_s,compute_s,cohort,stragglers,late_folds,resampled,orphaned,quorum_wait_s,shards,shard_agg_ms_max,router_queue_max,late_evicted,seg_uncovered,worker_drops,worker_rejoins,population,active_cohort,mux_workers,sched_ms,journal_bytes,journal_fsync_ms,shard_tx_bytes,shard_rx_bytes,shard_rtt_ms_max,aggregator,clients_trimmed,clip_applied";

/// Full training telemetry.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub rounds: Vec<RoundRecord>,
    pub label: String,
}

impl RunLog {
    pub fn new(label: impl Into<String>) -> Self {
        RunLog { rounds: vec![], label: label.into() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn total_up(&self) -> CommTotals {
        let mut t = CommTotals::default();
        for r in &self.rounds {
            t.merge(&r.up);
        }
        t
    }

    pub fn total_down(&self) -> CommTotals {
        let mut t = CommTotals::default();
        for r in &self.rounds {
            t.merge(&r.down);
        }
        t
    }

    /// Upload + download parameters (the paper's "Total Param." column).
    pub fn total_params(&self) -> u64 {
        self.total_up().params + self.total_down().params
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.global_loss).unwrap_or(f64::NAN)
    }

    pub fn best_acc(&self) -> Option<f64> {
        self.rounds.iter().filter_map(|r| r.eval_acc).fold(None, |m, a| {
            Some(m.map_or(a, |m: f64| m.max(a)))
        })
    }

    /// First round index at which eval accuracy reached `target`
    /// (Tables 3/4 "communication to reach target accuracy").
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.eval_acc.map_or(false, |a| a >= target))
            .map(|r| r.round)
    }

    /// Fraction of dispatched slots that were still outstanding when
    /// their round closed (the paper-style client dropout rate under
    /// quorum aggregation). 0.0 for synchronous runs.
    pub fn dropout_rate(&self) -> f64 {
        let slots: usize = self.rounds.iter().map(|r| r.cohort).sum();
        let stragglers: usize = self.rounds.iter().map(|r| r.stragglers).sum();
        if slots == 0 {
            0.0
        } else {
            stragglers as f64 / slots as f64
        }
    }

    /// Total straggler slots across the run.
    pub fn total_stragglers(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers).sum()
    }

    /// Total late uplinks folded back in across the run.
    pub fn total_late_folds(&self) -> usize {
        self.rounds.iter().map(|r| r.late_folds).sum()
    }

    /// Total timed-out slots re-dispatched across the run.
    pub fn total_resampled(&self) -> usize {
        self.rounds.iter().map(|r| r.resampled).sum()
    }

    /// Max per-round shard aggregation wall time, ms (0 when unsharded
    /// timing was never recorded).
    pub fn max_shard_agg_ms(&self) -> f64 {
        self.rounds.iter().map(|r| r.shard_agg_ms_max).fold(0.0, f64::max)
    }

    /// Total straggler payloads evicted by the late-buffer byte cap.
    pub fn total_late_evicted(&self) -> usize {
        self.rounds.iter().map(|r| r.late_evicted).sum()
    }

    /// Total worker-connection drops across the run (multi-process
    /// deployments; 0 in-process).
    pub fn total_worker_drops(&self) -> usize {
        self.rounds.iter().map(|r| r.worker_drops).sum()
    }

    /// Total worker rejoins across the run (multi-process deployments).
    pub fn total_worker_rejoins(&self) -> usize {
        self.rounds.iter().map(|r| r.worker_rejoins).sum()
    }

    /// Mean seconds from dispatch to quorum over all rounds.
    pub fn mean_quorum_wait_s(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.rounds.iter().map(|r| r.quorum_wait_s).sum::<f64>() / self.rounds.len() as f64
        }
    }

    /// Cumulative comm totals up to and including `round`.
    pub fn totals_until(&self, round: usize) -> (CommTotals, CommTotals) {
        let mut up = CommTotals::default();
        let mut down = CommTotals::default();
        for r in self.rounds.iter().filter(|r| r.round <= round) {
            up.merge(&r.up);
            down.merge(&r.down);
        }
        (up, down)
    }

    /// CSV export (one row per round).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(CSV_HEADER);
        s.push('\n');
        for r in &self.rounds {
            let _ = writeln!(
                s,
                "{},{:.6},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.6},{:.4},{},{},{},{},{},{:.4},{},{:.4},{},{},{},{},{},{},{},{},{:.4},{},{:.4},{},{},{:.4},{},{},{}",
                r.round,
                r.global_loss,
                r.eval_acc.map_or(String::from(""), |a| format!("{a:.4}")),
                r.up.params,
                r.up.bytes,
                r.down.params,
                r.down.bytes,
                r.k_a,
                r.k_b,
                r.gini_a,
                r.gini_b,
                r.overhead_s,
                r.compute_s,
                r.cohort,
                r.stragglers,
                r.late_folds,
                r.resampled,
                r.orphaned,
                r.quorum_wait_s,
                r.shards,
                r.shard_agg_ms_max,
                r.router_queue_max,
                r.late_evicted,
                r.seg_uncovered,
                r.worker_drops,
                r.worker_rejoins,
                r.population,
                r.active_cohort,
                r.mux_workers,
                r.sched_ms,
                r.journal_bytes,
                r.journal_fsync_ms,
                r.shard_tx_bytes,
                r.shard_rx_bytes,
                r.shard_rtt_ms_max,
                r.aggregator,
                r.clients_trimmed,
                r.clip_applied,
            );
        }
        s
    }
}

/// Per-matrix sparsity snapshot (Figure 2).
#[derive(Debug, Clone, Copy, Default)]
pub struct SparsitySnapshot {
    pub gini_a: f64,
    pub gini_b: f64,
    pub frac_small_a: f64,
    pub frac_small_b: f64,
}

/// Compute the Figure 2 statistics from a flat LoRA vector.
pub fn sparsity_snapshot(
    lora: &[f32],
    kinds: &[crate::model::LoraKind],
) -> SparsitySnapshot {
    let mut a = Vec::new();
    let mut b = Vec::new();
    for (v, k) in lora.iter().zip(kinds) {
        match k {
            crate::model::LoraKind::A => a.push(*v),
            crate::model::LoraKind::B => b.push(*v),
        }
    }
    // "small" threshold: 10% of the family's RMS
    let thr = |v: &[f32]| {
        let rms = (v.iter().map(|x| (x * x) as f64).sum::<f64>() / v.len().max(1) as f64).sqrt();
        (0.1 * rms) as f32
    };
    SparsitySnapshot {
        gini_a: stats::gini(&a),
        gini_b: stats::gini(&b),
        frac_small_a: stats::sparsity(&a, thr(&a)),
        frac_small_b: stats::sparsity(&b, thr(&b)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LoraKind;

    fn record(round: usize, acc: f64, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            global_loss: 3.0 - round as f64 * 0.1,
            eval_acc: Some(acc),
            up: CommTotals { params: up, bytes: up * 2 },
            down: CommTotals { params: 2 * up, bytes: 4 * up },
            ..Default::default()
        }
    }

    #[test]
    fn totals_accumulate() {
        let mut log = RunLog::new("t");
        log.push(record(0, 0.3, 100));
        log.push(record(1, 0.5, 150));
        assert_eq!(log.total_up().params, 250);
        assert_eq!(log.total_down().params, 500);
        assert_eq!(log.total_params(), 750);
    }

    #[test]
    fn rounds_to_accuracy_finds_first_crossing() {
        let mut log = RunLog::new("t");
        log.push(record(0, 0.30, 1));
        log.push(record(1, 0.55, 1));
        log.push(record(2, 0.52, 1));
        assert_eq!(log.rounds_to_accuracy(0.55), Some(1));
        assert_eq!(log.rounds_to_accuracy(0.9), None);
        let (up, _) = log.totals_until(1);
        assert_eq!(up.params, 2);
    }

    #[test]
    fn csv_has_row_per_round() {
        let mut log = RunLog::new("t");
        log.push(record(0, 0.3, 10));
        log.push(record(1, 0.4, 10));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("0,"));
        // every row carries the same number of columns as the header
        let cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }

    #[test]
    fn dropout_accounting_over_quorum_rounds() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord { round: 0, cohort: 4, stragglers: 1, resampled: 1, ..Default::default() });
        log.push(RoundRecord { round: 1, cohort: 4, late_folds: 1, ..Default::default() });
        assert!((log.dropout_rate() - 1.0 / 8.0).abs() < 1e-12);
        assert_eq!(log.total_stragglers(), 1);
        assert_eq!(log.total_late_folds(), 1);
        assert_eq!(log.total_resampled(), 1);
        assert_eq!(RunLog::new("empty").dropout_rate(), 0.0);
    }

    #[test]
    fn shard_columns_round_trip_through_csv() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord {
            round: 0,
            shards: 4,
            shard_agg_ms_max: 12.5,
            router_queue_max: 7,
            late_evicted: 2,
            seg_uncovered: 1,
            worker_drops: 3,
            worker_rejoins: 2,
            ..Default::default()
        });
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in [
            "shards",
            "shard_agg_ms_max",
            "router_queue_max",
            "late_evicted",
            "seg_uncovered",
            "worker_drops",
            "worker_rejoins",
        ] {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",4,12.5000,7,2,1,3,2,0,0,0,0.0000,0,0.0000,0,0,0.0000,,0,0"), "{row}");
        assert_eq!(log.max_shard_agg_ms(), 12.5);
        assert_eq!(log.total_late_evicted(), 2);
        assert_eq!(log.total_worker_drops(), 3);
        assert_eq!(log.total_worker_rejoins(), 2);
    }

    #[test]
    fn client_plane_columns_round_trip_through_csv() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord {
            round: 0,
            population: 100_000,
            active_cohort: 64,
            mux_workers: 8,
            sched_ms: 3.25,
            ..Default::default()
        });
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in ["population", "active_cohort", "mux_workers", "sched_ms"] {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",100000,64,8,3.2500,0,0.0000,0,0,0.0000,,0,0"), "{row}");
    }

    #[test]
    fn journal_columns_round_trip_through_csv() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord {
            round: 0,
            journal_bytes: 4096,
            journal_fsync_ms: 1.5,
            ..Default::default()
        });
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in ["journal_bytes", "journal_fsync_ms"] {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",4096,1.5000,0,0,0.0000,,0,0"), "{row}");
    }

    #[test]
    fn shard_link_columns_round_trip_through_csv() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord {
            round: 0,
            shard_tx_bytes: 8192,
            shard_rx_bytes: 2048,
            shard_rtt_ms_max: 0.75,
            ..Default::default()
        });
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in ["shard_tx_bytes", "shard_rx_bytes", "shard_rtt_ms_max"] {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",8192,2048,0.7500,,0,0"), "{row}");
    }

    #[test]
    fn robust_columns_round_trip_through_csv() {
        let mut log = RunLog::new("t");
        log.push(RoundRecord {
            round: 0,
            aggregator: "trimmed-mean:0.2".into(),
            clients_trimmed: 4,
            clip_applied: 2,
            ..Default::default()
        });
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in ["aggregator", "clients_trimmed", "clip_applied"] {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",trimmed-mean:0.2,4,2"), "{row}");
    }

    #[test]
    fn csv_header_constant_matches_emitted_header() {
        let log = RunLog::new("t");
        assert_eq!(log.to_csv().lines().next().unwrap(), CSV_HEADER);
        // the struct and the header must agree on column count: a field
        // added to RoundRecord without a column (or vice versa) should
        // fail here, not silently diverge in the e2e bitwise compare
        assert_eq!(CSV_HEADER.split(',').count(), 38);
    }

    #[test]
    fn snapshot_detects_sparser_b() {
        let mut rng = crate::util::rng::Rng::new(0);
        let n = 2000;
        let kinds: Vec<LoraKind> = (0..n)
            .map(|i| if i < n / 2 { LoraKind::A } else { LoraKind::B })
            .collect();
        let lora: Vec<f32> = (0..n)
            .map(|i| {
                if i < n / 2 {
                    rng.normal() as f32 // dense A
                } else if rng.below(10) == 0 {
                    5.0 * rng.normal() as f32 // sparse spiky B
                } else {
                    0.01 * rng.normal() as f32
                }
            })
            .collect();
        let s = sparsity_snapshot(&lora, &kinds);
        assert!(s.gini_b > s.gini_a, "giniA={} giniB={}", s.gini_a, s.gini_b);
        assert!(s.frac_small_b > s.frac_small_a);
    }
}
