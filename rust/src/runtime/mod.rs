//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute_b`). Python produced the artifacts at build time;
//! this module is the ONLY place the request path touches the compiled
//! compute. Frozen base weights are uploaded once per process and shared by
//! every simulated client as a single device buffer.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::xla;
use crate::xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::Schema;
use crate::util::lock_unpoisoned;

/// Whether this build links the real PJRT runtime (the `pjrt` feature).
/// When false, `Engine::new` fails cleanly and artifact-backed tests skip.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Process-wide PJRT engine (CPU client + compiled executable cache).
pub struct Engine {
    client: PjRtClient,
    artifacts_dir: PathBuf,
    cache: std::sync::Mutex<BTreeMap<String, std::sync::Arc<Exec>>>,
    /// Cumulative XLA compile time (reported in perf logs).
    pub compile_seconds: std::sync::Mutex<f64>,
}

/// One compiled entry point.
pub struct Exec {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Engine {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Engine> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.into(),
            cache: Default::default(),
            compile_seconds: std::sync::Mutex::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<std::sync::Arc<Exec>> {
        if let Some(e) = lock_unpoisoned(&self.cache).get(file) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {file}: {e:?}"))?;
        *lock_unpoisoned(&self.compile_seconds) += t0.elapsed().as_secs_f64();
        let exec = std::sync::Arc::new(Exec { exe, name: file.to_string() });
        lock_unpoisoned(&self.cache).insert(file.to_string(), exec.clone());
        Ok(exec)
    }

    /// Load the artifact for `tag` ("train" / "eval" / ...) of a preset.
    pub fn load_tagged(&self, schema: &Schema, tag: &str) -> Result<std::sync::Arc<Exec>> {
        let art = schema
            .artifacts
            .get(tag)
            .ok_or_else(|| anyhow!("preset {} has no `{tag}` artifact", schema.preset))?;
        self.load(&art.file)
    }

    // ---- host <-> device transfers ---------------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    pub fn upload_scalar_f32(&self, x: f32) -> Result<PjRtBuffer> {
        self.upload_f32(&[x], &[])
    }
}

/// Host-side copy of one executable output.
pub fn literal_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}

pub fn literal_scalar_f32(lit: &Literal) -> Result<f32> {
    let v = literal_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

impl Exec {
    /// Execute on device buffers, returning the flattened output leaves as
    /// host literals. Handles both PJRT output conventions (one tuple
    /// buffer vs per-leaf buffers).
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let replica = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output replica", self.name))?;
        let mut literals = Vec::new();
        for buf in &replica {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("{}: output fetch: {e:?}", self.name))?;
            // return_tuple=True artifacts produce a single tuple literal.
            match lit.primitive_type() {
                Ok(xla::PrimitiveType::Tuple) => {
                    let mut l = lit;
                    literals.extend(
                        l.decompose_tuple()
                            .map_err(|e| anyhow!("{}: tuple decompose: {e:?}", self.name))?,
                    );
                }
                _ => literals.push(lit),
            }
        }
        Ok(literals)
    }

    /// Execute and keep outputs on device (for feedback loops where an
    /// output becomes the next call's input, e.g. pretraining).
    pub fn run_buffers(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        outs.into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output replica", self.name))
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end by rust/tests/integration_runtime.rs (needs
    // artifacts); unit-level coverage here is limited to error paths.
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        if let Ok(engine) = Engine::new("artifacts") {
            match engine.load("nope.hlo.txt") {
                Ok(_) => panic!("expected error"),
                Err(err) => {
                    let msg = format!("{err:#}");
                    assert!(msg.contains("nope.hlo.txt"), "{msg}");
                }
            }
        }
    }
}
