//! Data layer: synthetic corpus, non-IID partitioners, TF-IDF + KMeans
//! synthetic categories, preference pairs, and client-side batching.

pub mod corpus;
pub mod kmeans;
pub mod partition;
pub mod preference;
pub mod tfidf;

pub use corpus::{CorpusCfg, Dataset, McItem, Sample};

use crate::util::rng::Rng;

/// How clients are carved from the corpus (paper Appendix A).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    /// Dirichlet(α) over the corpus's true category labels (Dolly-style).
    DirichletLabels { alpha: f64 },
    /// Dirichlet(α) over TF-IDF + KMeans synthetic categories
    /// (Alpaca-style; the true labels are ignored).
    DirichletClusters { alpha: f64, k: usize },
    /// One task domain per client (Table 6).
    TaskDomain,
    /// IID control.
    Iid,
}

/// Build the per-client sample-index partition.
pub fn partition_dataset(
    ds: &Dataset,
    kind: PartitionKind,
    n_clients: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let labels: Vec<usize> = ds.samples.iter().map(|s| s.category).collect();
    match kind {
        PartitionKind::DirichletLabels { alpha } => {
            partition::dirichlet(&labels, n_clients, alpha, rng)
        }
        PartitionKind::DirichletClusters { alpha, k } => {
            let docs: Vec<Vec<i32>> = ds.samples.iter().map(|s| s.tokens.clone()).collect();
            let tf = tfidf::tfidf(&docs, ds.cfg.vocab, corpus::CONTENT0);
            let km = kmeans::kmeans(&tf.vectors, k, 25, rng);
            partition::dirichlet(&km.assignment, n_clients, alpha, rng)
        }
        PartitionKind::TaskDomain => partition::task_domain(&labels, n_clients, rng),
        PartitionKind::Iid => partition::iid(ds.samples.len(), n_clients, rng),
    }
}

/// One client's local data view with epoch-shuffled batch iteration.
#[derive(Debug, Clone)]
pub struct ClientData {
    pub indices: Vec<usize>,
    cursor: usize,
    order: Vec<usize>,
}

impl ClientData {
    pub fn new(indices: Vec<usize>) -> Self {
        let order = (0..indices.len()).collect();
        ClientData { indices, cursor: 0, order }
    }

    pub fn n_samples(&self) -> usize {
        self.indices.len()
    }

    /// Next batch of `batch` rows, flattened [batch * seq_tokens] i32,
    /// cycling with reshuffle at epoch boundaries. Short clients repeat
    /// samples (standard practice; keeps batch shapes static for XLA).
    pub fn next_batch(&mut self, ds: &Dataset, batch: usize, rng: &mut Rng) -> Vec<i32> {
        let seq = ds.cfg.seq_tokens;
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            if self.indices.is_empty() {
                // degenerate client: PAD-only rows contribute zero loss
                out.extend(std::iter::repeat(corpus::PAD).take(seq));
                continue;
            }
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                rng.shuffle(&mut self.order);
            }
            let s = self.indices[self.order[self.cursor]];
            self.cursor += 1;
            out.extend_from_slice(&ds.samples[s].tokens);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        let cfg = CorpusCfg::new(256, 48, 8);
        corpus::generate(&mut Rng::new(0), 400, cfg)
    }

    #[test]
    fn cluster_partition_covers_dataset() {
        let ds = dataset();
        let mut rng = Rng::new(1);
        let p = partition_dataset(
            &ds,
            PartitionKind::DirichletClusters { alpha: 0.5, k: 8 },
            20,
            &mut rng,
        );
        let total: usize = p.iter().map(|c| c.len()).sum();
        assert_eq!(total, ds.samples.len());
    }

    #[test]
    fn batches_have_static_shape_and_cycle() {
        let ds = dataset();
        let mut rng = Rng::new(2);
        let mut cd = ClientData::new(vec![0, 1, 2]);
        let seq = ds.cfg.seq_tokens;
        for _ in 0..5 {
            let b = cd.next_batch(&ds, 8, &mut rng);
            assert_eq!(b.len(), 8 * seq);
        }
    }

    #[test]
    fn empty_client_yields_pad_batches() {
        let ds = dataset();
        let mut rng = Rng::new(3);
        let mut cd = ClientData::new(vec![]);
        let b = cd.next_batch(&ds, 4, &mut rng);
        assert!(b.iter().all(|&t| t == corpus::PAD));
    }

    #[test]
    fn task_domain_partition_routes_by_category() {
        let ds = dataset();
        let mut rng = Rng::new(4);
        let p = partition_dataset(&ds, PartitionKind::TaskDomain, 16, &mut rng);
        for (c, client) in p.iter().enumerate() {
            for &s in client {
                assert_eq!(ds.samples[s].category, c % 8);
            }
        }
    }
}
