//! Non-IID client partitioners (paper Appendix A):
//!
//! * `dirichlet` — Dirichlet(α)-weighted allocation over category labels
//!   (α = 0.5 in the paper); the Dolly-style split.
//! * `task_domain` — each client draws from a single category (the
//!   Table 6 / Appendix C extreme-heterogeneity split).
//! * `iid` — uniform shuffle baseline.
//!
//! All partitioners return per-client sample-index lists; every sample is
//! assigned to exactly one client.

use crate::util::rng::Rng;

/// Dirichlet non-IID split: for each category, the category's samples are
/// distributed across clients with proportions ~ Dirichlet(alpha).
pub fn dirichlet(labels: &[usize], n_clients: usize, alpha: f64, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(n_clients > 0);
    let n_categories = labels.iter().max().map_or(0, |m| m + 1);
    let mut clients: Vec<Vec<usize>> = vec![vec![]; n_clients];
    for cat in 0..n_categories {
        let members: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] == cat).collect();
        if members.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, n_clients);
        // multinomial assignment by per-sample categorical draw keeps the
        // expected proportions while assigning every sample exactly once
        for &s in &members {
            clients[rng.categorical(&props)].push(s);
        }
    }
    clients
}

/// Task-domain split: client i draws only from category i mod n_categories.
pub fn task_domain(labels: &[usize], n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let n_categories = labels.iter().max().map_or(0, |m| m + 1).max(1);
    let mut per_cat: Vec<Vec<usize>> = vec![vec![]; n_categories];
    for (i, &l) in labels.iter().enumerate() {
        per_cat[l].push(i);
    }
    let mut clients: Vec<Vec<usize>> = vec![vec![]; n_clients];
    // clients of the same category split that category's samples evenly
    for (cat, members) in per_cat.iter_mut().enumerate() {
        rng.shuffle(members);
        let owners: Vec<usize> =
            (0..n_clients).filter(|c| c % n_categories == cat).collect();
        if owners.is_empty() {
            continue;
        }
        for (j, &s) in members.iter().enumerate() {
            clients[owners[j % owners.len()]].push(s);
        }
    }
    clients
}

/// IID split: shuffle, deal round-robin.
pub fn iid(n_samples: usize, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let mut clients: Vec<Vec<usize>> = vec![vec![]; n_clients];
    for (j, s) in idx.into_iter().enumerate() {
        clients[j % n_clients].push(s);
    }
    clients
}

/// Heterogeneity diagnostic: mean over clients of the max category share
/// (1.0 = every client single-category, 1/C = perfectly mixed).
pub fn label_skew(partition: &[Vec<usize>], labels: &[usize]) -> f64 {
    let n_categories = labels.iter().max().map_or(0, |m| m + 1).max(1);
    let mut total = 0.0;
    let mut counted = 0usize;
    for client in partition {
        if client.is_empty() {
            continue;
        }
        let mut counts = vec![0usize; n_categories];
        for &s in client {
            counts[labels[s]] += 1;
        }
        total += *counts.iter().max().unwrap() as f64 / client.len() as f64;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, c: usize, rng: &mut Rng) -> Vec<usize> {
        (0..n).map(|_| rng.below(c)).collect()
    }

    fn assert_exact_cover(partition: &[Vec<usize>], n: usize) {
        let mut seen = vec![false; n];
        for client in partition {
            for &s in client {
                assert!(!seen[s], "sample {s} assigned twice");
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "every sample assigned");
    }

    #[test]
    fn dirichlet_covers_every_sample() {
        let mut rng = Rng::new(0);
        let l = labels(5_000, 8, &mut rng);
        let p = dirichlet(&l, 100, 0.5, &mut rng);
        assert_eq!(p.len(), 100);
        assert_exact_cover(&p, l.len());
    }

    #[test]
    fn dirichlet_low_alpha_is_more_skewed_than_high_alpha() {
        let mut rng = Rng::new(1);
        let l = labels(20_000, 8, &mut rng);
        let skew_low = label_skew(&dirichlet(&l, 50, 0.1, &mut rng), &l);
        let skew_high = label_skew(&dirichlet(&l, 50, 100.0, &mut rng), &l);
        assert!(
            skew_low > skew_high + 0.1,
            "alpha=0.1 skew {skew_low:.3} vs alpha=100 skew {skew_high:.3}"
        );
    }

    #[test]
    fn task_domain_clients_are_single_category() {
        let mut rng = Rng::new(2);
        let l = labels(4_000, 8, &mut rng);
        let p = task_domain(&l, 100, &mut rng);
        assert_exact_cover(&p, l.len());
        assert!((label_skew(&p, &l) - 1.0).abs() < 1e-12);
        for (c, client) in p.iter().enumerate() {
            for &s in client {
                assert_eq!(l[s], c % 8);
            }
        }
    }

    #[test]
    fn iid_is_balanced_and_mixed() {
        let mut rng = Rng::new(3);
        let l = labels(8_000, 8, &mut rng);
        let p = iid(l.len(), 100, &mut rng);
        assert_exact_cover(&p, l.len());
        let sizes: Vec<usize> = p.iter().map(|c| c.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        assert!(label_skew(&p, &l) < 0.35); // ~1/8 + noise
    }

    #[test]
    fn empty_and_degenerate_cases() {
        let mut rng = Rng::new(4);
        let p = dirichlet(&[], 10, 0.5, &mut rng);
        assert!(p.iter().all(|c| c.is_empty()));
        let p = iid(5, 10, &mut rng);
        assert_eq!(p.iter().map(|c| c.len()).sum::<usize>(), 5);
    }
}
