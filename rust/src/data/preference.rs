//! Preference pairs for the federated-DPO value-alignment task (paper
//! §4.2, Table 2) — the UltraFeedback stand-in.
//!
//! Each pair shares a prompt; the chosen response is the task-grammar
//! answer ("highest-scored response"), the rejected one is a corrupted
//! answer ("randomly designated dispreferred response", following the
//! paper's Zephyr-style construction).

use super::corpus::{assemble, task_answer, CorpusCfg, CONTENT0};
use crate::util::rng::Rng;

/// One tokenized preference pair (rows are full padded sequences).
#[derive(Debug, Clone)]
pub struct PrefPair {
    pub chosen: Vec<i32>,
    pub rejected: Vec<i32>,
    pub category: usize,
}

/// Generate `n` preference pairs across categories.
pub fn generate_pairs(rng: &mut Rng, n: usize, cfg: &CorpusCfg) -> Vec<PrefPair> {
    (0..n)
        .map(|_| {
            let cat = rng.below(cfg.n_categories);
            let m = cfg.span();
            let (boff, size) = cfg.band(cat);
            let base = CONTENT0 + boff;
            let prompt: Vec<i32> =
                (0..m).map(|_| base + rng.below(size as usize) as i32).collect();
            let good = task_answer(cat, &prompt, cfg);
            // corrupt: random in-band tokens over half the answer
            let mut bad = good.clone();
            for _ in 0..(m / 2).max(1) {
                let i = rng.below(m);
                bad[i] = base + rng.below(size as usize) as i32;
            }
            if bad == good {
                bad[0] = base + ((bad[0] - base + 1).rem_euclid(size));
            }
            PrefPair {
                chosen: assemble(&prompt, &good, cfg),
                rejected: assemble(&prompt, &bad, cfg),
                category: cat,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{BOS, SEP};

    #[test]
    fn pairs_share_prompt_and_differ_in_answer() {
        let cfg = CorpusCfg::new(256, 48, 8);
        let mut rng = Rng::new(0);
        let pairs = generate_pairs(&mut rng, 40, &cfg);
        assert_eq!(pairs.len(), 40);
        for p in &pairs {
            assert_eq!(p.chosen.len(), cfg.seq_tokens);
            assert_eq!(p.rejected.len(), cfg.seq_tokens);
            assert_ne!(p.chosen, p.rejected);
            // shared prefix through SEP
            let sep_pos = p.chosen.iter().position(|&t| t == SEP).unwrap();
            assert_eq!(p.chosen[..=sep_pos], p.rejected[..=sep_pos]);
            assert_eq!(p.chosen[0], BOS);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = CorpusCfg::new(256, 48, 4);
        let a = generate_pairs(&mut Rng::new(7), 10, &cfg);
        let b = generate_pairs(&mut Rng::new(7), 10, &cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.chosen, y.chosen);
            assert_eq!(x.rejected, y.rejected);
        }
    }
}
