//! KMeans clustering with k-means++ seeding (Lloyd's algorithm) — used to
//! derive synthetic categories from TF-IDF vectors (paper Appendix A).

use crate::util::linalg::dist_sq;
use crate::util::rng::Rng;

/// Clustering result.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub centroids: Vec<Vec<f32>>,
    pub assignment: Vec<usize>,
    pub inertia: f64,
    pub iterations: usize,
}

/// Run KMeans. `points` must be non-empty rows of equal dimension.
pub fn kmeans(points: &[Vec<f32>], k: usize, max_iters: usize, rng: &mut Rng) -> KMeans {
    assert!(!points.is_empty() && k >= 1);
    let k = k.min(points.len());
    let dim = points[0].len();

    // -- k-means++ seeding ---------------------------------------------------
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(points[rng.below(points.len())].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| dist_sq(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.below(points.len())
        } else {
            rng.categorical(&d2)
        };
        centroids.push(points[next].clone());
        let c = centroids.last().unwrap();
        for (d, p) in d2.iter_mut().zip(points) {
            *d = d.min(dist_sq(p, c));
        }
    }

    // -- Lloyd iterations ----------------------------------------------------
    let mut assignment = vec![0usize; points.len()];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // assign
        let mut new_inertia = 0.0;
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let (mut best, mut bd) = (0usize, f64::INFINITY);
            for (c, cent) in centroids.iter().enumerate() {
                let d = dist_sq(p, cent);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
            new_inertia += bd;
        }
        inertia = new_inertia;
        if !changed && it > 0 {
            break;
        }
        // update
        let mut sums = vec![vec![0.0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (p, &a) in points.iter().zip(&assignment) {
            counts[a] += 1;
            for (s, &x) in sums[a].iter_mut().zip(p) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the farthest point
                let far = points
                    .iter()
                    .enumerate()
                    .max_by(|(_, a), (_, b)| {
                        dist_sq(a, &centroids[assignment[0]])
                            .partial_cmp(&dist_sq(b, &centroids[assignment[0]]))
                            .unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                centroids[c] = points[far].clone();
            } else {
                for (dst, s) in centroids[c].iter_mut().zip(&sums[c]) {
                    *dst = (*s / counts[c] as f64) as f32;
                }
            }
        }
    }
    KMeans { centroids, assignment, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, per: usize, centers: &[[f32; 2]], spread: f32) -> Vec<Vec<f32>> {
        let mut pts = vec![];
        for c in centers {
            for _ in 0..per {
                pts.push(vec![
                    c[0] + spread * rng.normal() as f32,
                    c[1] + spread * rng.normal() as f32,
                ]);
            }
        }
        pts
    }

    #[test]
    fn separates_well_separated_blobs() {
        let mut rng = Rng::new(0);
        let pts = blobs(&mut rng, 50, &[[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]], 0.3);
        let km = kmeans(&pts, 3, 50, &mut rng);
        // each blob maps to exactly one cluster
        for b in 0..3 {
            let assigns: Vec<usize> = (b * 50..(b + 1) * 50).map(|i| km.assignment[i]).collect();
            assert!(assigns.iter().all(|&a| a == assigns[0]), "blob {b} split");
        }
        // and clusters are distinct
        assert_ne!(km.assignment[0], km.assignment[50]);
        assert_ne!(km.assignment[50], km.assignment[100]);
        assert!(km.inertia < 100.0);
    }

    #[test]
    fn inertia_nonincreasing_in_k() {
        let mut rng = Rng::new(1);
        let pts = blobs(&mut rng, 40, &[[0.0, 0.0], [5.0, 5.0]], 1.0);
        let mut last = f64::INFINITY;
        for k in [1, 2, 4, 8] {
            let km = kmeans(&pts, k, 50, &mut Rng::new(7));
            assert!(km.inertia <= last + 1e-6, "k={k}");
            last = km.inertia;
        }
    }

    #[test]
    fn k_clamped_to_n_points() {
        let mut rng = Rng::new(2);
        let pts = vec![vec![0.0f32, 0.0], vec![1.0, 1.0]];
        let km = kmeans(&pts, 10, 10, &mut rng);
        assert_eq!(km.centroids.len(), 2);
        assert!(km.assignment.iter().all(|&a| a < 2));
    }

    #[test]
    fn deterministic_under_seed() {
        let mut r1 = Rng::new(9);
        let pts = blobs(&mut r1, 30, &[[0.0, 0.0], [8.0, 8.0]], 0.5);
        let a = kmeans(&pts, 2, 50, &mut Rng::new(5));
        let b = kmeans(&pts, 2, 50, &mut Rng::new(5));
        assert_eq!(a.assignment, b.assignment);
    }
}
