//! TF-IDF vectorizer over token sequences — the Alpaca-style synthetic-
//! category pipeline (paper Appendix A): samples without labels are
//! embedded as TF-IDF vectors and clustered with KMeans; the clusters act
//! as categories for the Dirichlet split.

/// TF-IDF matrix: one L2-normalized row per document.
#[derive(Debug, Clone)]
pub struct TfIdf {
    pub vectors: Vec<Vec<f32>>,
    pub vocab: usize,
}

/// Build TF-IDF over token-id documents, ignoring ids < `min_token`
/// (reserved/control tokens act like stop words).
pub fn tfidf(docs: &[Vec<i32>], vocab: usize, min_token: i32) -> TfIdf {
    let n = docs.len();
    let mut df = vec![0u32; vocab];
    let mut counts: Vec<Vec<(usize, f32)>> = Vec::with_capacity(n);

    for doc in docs {
        let mut c = std::collections::BTreeMap::new();
        for &t in doc {
            if t >= min_token && (t as usize) < vocab {
                *c.entry(t as usize).or_insert(0.0f32) += 1.0;
            }
        }
        for &tok in c.keys() {
            df[tok] += 1;
        }
        counts.push(c.into_iter().collect());
    }

    let idf: Vec<f32> = df
        .iter()
        .map(|&d| ((1.0 + n as f32) / (1.0 + d as f32)).ln() + 1.0)
        .collect();

    let vectors = counts
        .into_iter()
        .map(|c| {
            let mut v = vec![0.0f32; vocab];
            let total: f32 = c.iter().map(|(_, x)| x).sum();
            for (tok, cnt) in c {
                v[tok] = (cnt / total.max(1.0)) * idf[tok];
            }
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                for x in &mut v {
                    *x /= norm;
                }
            }
            v
        })
        .collect();

    TfIdf { vectors, vocab }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::dot;

    #[test]
    fn rows_are_unit_norm() {
        let docs = vec![vec![4, 5, 6, 4], vec![7, 8], vec![4, 4, 4]];
        let t = tfidf(&docs, 16, 4);
        for v in &t.vectors {
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn similar_docs_have_higher_cosine() {
        let a = vec![4, 5, 6, 7];
        let b = vec![4, 5, 6, 8]; // shares 3 tokens with a
        let c = vec![10, 11, 12, 13]; // disjoint
        let t = tfidf(&[a, b, c], 16, 4);
        let sim_ab = dot(&t.vectors[0], &t.vectors[1]);
        let sim_ac = dot(&t.vectors[0], &t.vectors[2]);
        assert!(sim_ab > sim_ac + 0.3, "{sim_ab} vs {sim_ac}");
    }

    #[test]
    fn control_tokens_ignored() {
        let docs = vec![vec![0, 1, 2, 3, 4], vec![4]];
        let t = tfidf(&docs, 16, 4);
        // both docs reduce to {4}: identical vectors
        assert_eq!(t.vectors[0], t.vectors[1]);
    }

    #[test]
    fn rare_tokens_weigh_more_than_common() {
        // token 4 in every doc, token 9 in one
        let docs = vec![vec![4, 9], vec![4, 5], vec![4, 6], vec![4, 7]];
        let t = tfidf(&docs, 16, 4);
        assert!(t.vectors[0][9] > t.vectors[0][4]);
    }
}
