//! Synthetic task-category corpus — the stand-in for Dolly / Alpaca-GPT4
//! (see DESIGN.md §Substitutions).
//!
//! Every sample is `BOS prompt… SEP answer… EOS PAD…` where the answer is a
//! deterministic function of the prompt chosen by the sample's task
//! category. Eight task grammars give the corpus the category structure
//! the paper's non-IID splits rely on (Dolly category labels, Alpaca
//! TF-IDF+KMeans synthetic categories, Table 6 task domains), and make
//! fine-tuning measurably learnable: a model that has learned a category
//! maps prompts to answers with low loss, which the multiple-choice eval
//! (ARC proxy) detects.

use crate::util::rng::Rng;

/// Reserved token ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const EOS: i32 = 3;
/// First content token id.
pub const CONTENT0: i32 = 4;

/// The eight task grammars (category id = index).
pub const N_TASKS: usize = 8;
pub const TASK_NAMES: [&str; N_TASKS] = [
    "copy", "reverse", "successor", "sort", "repeat-last", "running-sum",
    "first-token", "swap-pairs",
];

/// Corpus shape parameters, derived from a model preset.
#[derive(Debug, Clone, Copy)]
pub struct CorpusCfg {
    pub vocab: usize,
    /// tokens per sequence INCLUDING the shifted target (model takes S+1).
    pub seq_tokens: usize,
    pub n_categories: usize,
}

impl CorpusCfg {
    pub fn new(vocab: usize, seq_len: usize, n_categories: usize) -> Self {
        assert!(vocab > CONTENT0 as usize + 8, "vocab too small for content");
        assert!(n_categories >= 1 && n_categories <= N_TASKS);
        CorpusCfg { vocab, seq_tokens: seq_len + 1, n_categories }
    }

    /// Prompt/answer length: fill `BOS p.. SEP a.. EOS` into seq_tokens.
    pub fn span(&self) -> usize {
        (self.seq_tokens - 3) / 2
    }

    fn content_range(&self) -> i32 {
        (self.vocab as i32) - CONTENT0
    }

    /// Each category draws from its own token band (offset, size) within
    /// the content range. Disjoint bands keep per-category entropy low —
    /// the analogue of domain-specific vocabulary in Dolly categories —
    /// which both makes fine-tuning learnable at this model scale and
    /// gives TF-IDF + KMeans real cluster structure to recover.
    pub fn band(&self, cat: usize) -> (i32, i32) {
        let range = self.content_range();
        let size = (range / self.n_categories as i32).min(16).max(2);
        let offset = (cat as i32) * size % (range - size + 1).max(1);
        (offset, size)
    }
}

/// One tokenized sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub category: usize,
}

/// A corpus with category labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    pub n_categories: usize,
    pub cfg: CorpusCfg,
}

/// Deterministic answer for `prompt` under task `cat`. Arithmetic wraps
/// inside the category's token band so answers stay in-distribution.
pub fn task_answer(cat: usize, prompt: &[i32], cfg: &CorpusCfg) -> Vec<i32> {
    let m = prompt.len();
    let (boff, range) = cfg.band(cat % cfg.n_categories);
    let base = CONTENT0 + boff;
    let shift = |t: i32, d: i32| base + ((t - base) + d).rem_euclid(range);
    match cat % N_TASKS {
        0 => prompt.to_vec(),
        1 => prompt.iter().rev().copied().collect(),
        2 => prompt.iter().map(|&t| shift(t, 1)).collect(),
        3 => {
            let mut v = prompt.to_vec();
            v.sort_unstable();
            v
        }
        4 => vec![prompt[m - 1]; m],
        5 => {
            let mut acc = 0i32;
            prompt
                .iter()
                .map(|&t| {
                    acc = (acc + (t - base)).rem_euclid(range);
                    base + acc
                })
                .collect()
        }
        6 => vec![prompt[0]; m],
        _ => {
            let mut v = prompt.to_vec();
            for i in (0..m - 1).step_by(2) {
                v.swap(i, i + 1);
            }
            v
        }
    }
}

/// Assemble a padded token row from prompt + answer.
pub fn assemble(prompt: &[i32], answer: &[i32], cfg: &CorpusCfg) -> Vec<i32> {
    let mut t = Vec::with_capacity(cfg.seq_tokens);
    t.push(BOS);
    t.extend_from_slice(prompt);
    t.push(SEP);
    t.extend_from_slice(answer);
    t.push(EOS);
    assert!(t.len() <= cfg.seq_tokens, "sample overflows sequence");
    t.resize(cfg.seq_tokens, PAD);
    t
}

/// Prompt drawn from the category's token band.
fn random_prompt(rng: &mut Rng, cat: usize, cfg: &CorpusCfg) -> Vec<i32> {
    let m = cfg.span();
    let (boff, size) = cfg.band(cat % cfg.n_categories);
    (0..m)
        .map(|_| CONTENT0 + boff + rng.below(size as usize) as i32)
        .collect()
}

/// Generate one sample of category `cat`.
pub fn gen_sample(rng: &mut Rng, cat: usize, cfg: &CorpusCfg) -> Sample {
    let prompt = random_prompt(rng, cat, cfg);
    let answer = task_answer(cat, &prompt, cfg);
    Sample { tokens: assemble(&prompt, &answer, cfg), category: cat }
}

/// Generate a labelled corpus with roughly uniform category frequencies
/// (the Dolly stand-in; Alpaca-style runs ignore the labels and recover
/// categories via TF-IDF + KMeans).
pub fn generate(rng: &mut Rng, n_samples: usize, cfg: CorpusCfg) -> Dataset {
    let samples = (0..n_samples)
        .map(|_| {
            let cat = rng.below(cfg.n_categories);
            gen_sample(rng, cat, &cfg)
        })
        .collect();
    Dataset { samples, n_categories: cfg.n_categories, cfg }
}

/// A 4-way multiple-choice item (ARC proxy): row 0..3 are full sequences
/// sharing the prompt; exactly one has the true answer.
#[derive(Debug, Clone)]
pub struct McItem {
    pub rows: Vec<Vec<i32>>,
    pub correct: usize,
    pub category: usize,
}

pub const MC_CHOICES: usize = 4;

/// Corrupt an answer into a plausible distractor (same length, in-band).
fn corrupt(rng: &mut Rng, cat: usize, answer: &[i32], cfg: &CorpusCfg) -> Vec<i32> {
    let mut a = answer.to_vec();
    let (boff, size) = cfg.band(cat % cfg.n_categories);
    match rng.below(3) {
        0 => {
            // perturb a few tokens within the category band
            for _ in 0..(a.len() / 3).max(1) {
                let i = rng.below(a.len());
                a[i] = CONTENT0 + boff + rng.below(size as usize) as i32;
            }
        }
        1 => a.reverse(),
        _ => {
            let n = a.len();
            let by = 1.max(rng.below(n.max(2))).min(n);
            a.rotate_left(by);
        }
    }
    a
}

/// Build a held-out MC eval set for the given categories.
pub fn make_eval_set(rng: &mut Rng, n_items: usize, cfg: &CorpusCfg) -> Vec<McItem> {
    (0..n_items)
        .map(|_| {
            let cat = rng.below(cfg.n_categories);
            let prompt = random_prompt(rng, cat, cfg);
            let answer = task_answer(cat, &prompt, cfg);
            let correct = rng.below(MC_CHOICES);
            let (boff, size) = cfg.band(cat);
            let rows = (0..MC_CHOICES)
                .map(|c| {
                    if c == correct {
                        assemble(&prompt, &answer, cfg)
                    } else {
                        let mut d = corrupt(rng, cat, &answer, cfg);
                        // ensure the distractor differs (stay in band)
                        if d == answer {
                            let base = CONTENT0 + boff;
                            d[0] = base + ((d[0] - base + 1).rem_euclid(size));
                        }
                        assemble(&prompt, &d, cfg)
                    }
                })
                .collect();
            McItem { rows, correct, category: cat }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusCfg {
        CorpusCfg::new(256, 48, 8)
    }

    #[test]
    fn samples_are_well_formed() {
        let cfg = cfg();
        let mut rng = Rng::new(0);
        let ds = generate(&mut rng, 200, cfg);
        assert_eq!(ds.samples.len(), 200);
        for s in &ds.samples {
            assert_eq!(s.tokens.len(), cfg.seq_tokens);
            assert_eq!(s.tokens[0], BOS);
            assert!(s.tokens.contains(&SEP));
            assert!(s.tokens.contains(&EOS));
            assert!(s.tokens.iter().all(|&t| (t as usize) < cfg.vocab));
            assert!(s.category < 8);
        }
        // all categories appear
        let mut seen = [false; N_TASKS];
        for s in &ds.samples {
            seen[s.category] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn answers_are_deterministic_functions() {
        let cfg = cfg();
        let prompt = vec![10, 7, 22, 5];
        assert_eq!(task_answer(0, &prompt, &cfg), prompt);
        assert_eq!(task_answer(1, &prompt, &cfg), vec![5, 22, 7, 10]);
        assert_eq!(task_answer(3, &prompt, &cfg), vec![5, 7, 10, 22]);
        assert_eq!(task_answer(4, &prompt, &cfg), vec![5, 5, 5, 5]);
        assert_eq!(task_answer(6, &prompt, &cfg), vec![10, 10, 10, 10]);
        assert_eq!(task_answer(7, &prompt, &cfg), vec![7, 10, 5, 22]);
        // successor shifts within the category band
        let (boff, _) = cfg.band(2);
        let base = CONTENT0 + boff;
        assert_eq!(task_answer(2, &[base], &cfg), vec![base + 1]);
    }

    #[test]
    fn successor_wraps_in_band() {
        let cfg = cfg();
        let (boff, size) = cfg.band(2);
        let top = CONTENT0 + boff + size - 1;
        let ans = task_answer(2, &[top], &cfg);
        assert_eq!(ans, vec![CONTENT0 + boff]);
    }

    #[test]
    fn bands_are_disjoint_and_in_range() {
        let cfg = cfg();
        for c in 0..cfg.n_categories {
            let (off, size) = cfg.band(c);
            assert!(size >= 2);
            assert!(CONTENT0 + off + size <= cfg.vocab as i32);
            for c2 in 0..c {
                let (off2, size2) = cfg.band(c2);
                assert!(off >= off2 + size2 || off2 >= off + size, "bands overlap");
            }
        }
        // samples stay inside their band
        let mut rng = Rng::new(11);
        for cat in 0..8 {
            let s = gen_sample(&mut rng, cat, &cfg);
            let (off, size) = cfg.band(cat);
            for &t in &s.tokens {
                if t >= CONTENT0 {
                    assert!(t >= CONTENT0 + off && t < CONTENT0 + off + size);
                }
            }
        }
    }

    #[test]
    fn mc_items_have_unique_correct_row() {
        let cfg = cfg();
        let mut rng = Rng::new(3);
        let items = make_eval_set(&mut rng, 50, &cfg);
        for it in &items {
            assert_eq!(it.rows.len(), MC_CHOICES);
            assert!(it.correct < MC_CHOICES);
            let correct_row = &it.rows[it.correct];
            for (c, row) in it.rows.iter().enumerate() {
                assert_eq!(row.len(), cfg.seq_tokens);
                if c != it.correct {
                    assert_ne!(row, correct_row, "distractor equals answer");
                }
            }
        }
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let cfg = cfg();
        let a = generate(&mut Rng::new(42), 20, cfg);
        let b = generate(&mut Rng::new(42), 20, cfg);
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
