//! Error-feedback residual accumulation (paper §3.4, Eqs. 5–6).
//!
//!   Ĉ = SC_k(U + R)          — compress the update plus carried residue
//!   R' = (U + R) − Ĉ         — keep what was not transmitted
//!
//! Every endpoint that sparsifies (each client's uplink, and the server's
//! downlink broadcast) owns one `Residual` the size of the LoRA vector, so
//! large updates go out immediately and small ones accumulate until they
//! matter. (Eq. 6 in the paper is written R^{t+1} = R^t + P^{t+1} − P̂^{t+1},
//! the same quantity since P̂ was selected from P + R.)

/// Per-endpoint residual state.
#[derive(Debug, Clone)]
pub struct Residual {
    pub r: Vec<f32>,
}

impl Residual {
    pub fn new(len: usize) -> Self {
        Residual { r: vec![0.0; len] }
    }

    /// Add the carried residue into `update` in place (U + R), returning a
    /// scratch reference the caller sparsifies. After selecting the kept
    /// set, call `commit`.
    pub fn add_into(&self, update: &mut [f32]) {
        assert_eq!(update.len(), self.r.len());
        for (u, r) in update.iter_mut().zip(&self.r) {
            *u += *r;
        }
    }

    /// Commit: `combined` is U + R; `kept_idx`/`kept_vals` is what was
    /// transmitted (possibly quantized). The new residue is
    /// combined − transmitted.
    pub fn commit(&mut self, combined: &[f32], kept_idx: &[u32], kept_vals: &[f32]) {
        assert_eq!(combined.len(), self.r.len());
        assert_eq!(kept_idx.len(), kept_vals.len());
        self.r.copy_from_slice(combined);
        for (&i, &v) in kept_idx.iter().zip(kept_vals) {
            self.r[i as usize] -= v;
        }
    }

    /// Total |residue| mass (diagnostics: must stay bounded in training).
    pub fn l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }

    pub fn reset(&mut self) {
        self.r.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::sparsify;
    use crate::util::propcheck::propcheck;

    #[test]
    fn conservation_transmitted_plus_residual_equals_total() {
        // Over T rounds: sum(transmitted) + final residual == sum(updates)
        // exactly (no quantization) — the error-feedback invariant.
        propcheck(100, |rng| {
            let n = rng.below(500) + 10;
            let keep = rng.below(n) + 1;
            let rounds = rng.below(12) + 1;
            let mut res = Residual::new(n);
            let mut sum_updates = vec![0.0f64; n];
            let mut sum_tx = vec![0.0f64; n];
            for _ in 0..rounds {
                let update: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                for (s, u) in sum_updates.iter_mut().zip(&update) {
                    *s += *u as f64;
                }
                let mut combined = update.clone();
                res.add_into(&mut combined);
                let (idx, vals) = sparsify(&combined, keep);
                res.commit(&combined, &idx, &vals);
                for (&i, &v) in idx.iter().zip(&vals) {
                    sum_tx[i as usize] += v as f64;
                }
            }
            for i in 0..n {
                let recon = sum_tx[i] + res.r[i] as f64;
                assert!(
                    (recon - sum_updates[i]).abs() < 1e-3,
                    "i={i}: {} vs {}",
                    recon,
                    sum_updates[i]
                );
            }
        });
    }

    #[test]
    fn keep_all_leaves_zero_residual() {
        let mut res = Residual::new(4);
        let mut u = vec![1.0f32, -2.0, 3.0, 0.5];
        res.add_into(&mut u);
        let (idx, vals) = sparsify(&u, 4);
        res.commit(&u, &idx, &vals);
        assert!(res.r.iter().all(|&x| x == 0.0));
        assert_eq!(res.l1(), 0.0);
    }

    #[test]
    fn untransmitted_mass_carries_forward() {
        let mut res = Residual::new(3);
        let mut u = vec![10.0f32, 0.1, 0.2];
        res.add_into(&mut u);
        let (idx, vals) = sparsify(&u, 1);
        res.commit(&u, &idx, &vals);
        assert_eq!(idx, vec![0]);
        assert_eq!(res.r, vec![0.0, 0.1, 0.2]);

        // next round the small entries accumulate and eventually win
        let mut u2 = vec![0.0f32, 0.15, 0.05];
        res.add_into(&mut u2);
        assert!((u2[1] - 0.25).abs() < 1e-6);
        let (idx2, _) = sparsify(&u2, 1);
        assert_eq!(idx2, vec![1]);
    }

    #[test]
    fn quantized_commit_keeps_quantization_error() {
        let mut res = Residual::new(2);
        let combined = vec![1.0f32, 0.0];
        // transmit a quantized version of entry 0
        res.commit(&combined, &[0], &[0.875]);
        assert!((res.r[0] - 0.125).abs() < 1e-6);
    }
}
