//! Pooled buffer arenas for the uplink hot path (§Perf,
//! docs/ARCHITECTURE.md §Codec hot path).
//!
//! Uplink payloads have an awkward ownership shape for scratch reuse:
//! the encoded `Vec<u8>` leaves the compressor, travels through a
//! `TrainResult`, may be copied into the exactly-once result cache, and
//! is finally consumed by a transport send — so a plain `&mut Vec<u8>`
//! scratch cannot cover it. [`PayloadArena`] closes that gap with a
//! recycle pool: every payload is *taken* from the arena (warm capacity,
//! presized from a high-water mark), and every site that retires a
//! payload (post-send, cache prune, error path) *recycles* it back.
//! After warm-up the cycle is allocation-free, which the gated
//! `alloc_discipline` suite proves with a counting global allocator.
//!
//! [`SparsePool`] is the same idea for the shard aggregators' decoded
//! `SparseVec`s (one live per in-flight uplink, returned on merge).

use super::SparseVec;

/// Default maximum number of pooled payload buffers kept for reuse.
pub const DEFAULT_POOL_CAP: usize = 32;

/// Recycle pool of uplink payload buffers with a high-water mark.
///
/// `take()` hands out a cleared buffer presized to `watermark + 25% + 64`
/// so steady-state encodes never grow it; `recycle()` returns a retired
/// buffer (and teaches the arena its length). The pool is bounded so a
/// burst of in-flight payloads cannot pin memory forever.
#[derive(Debug)]
pub struct PayloadArena {
    pool: Vec<Vec<u8>>,
    watermark: usize,
    cap: usize,
}

impl Default for PayloadArena {
    fn default() -> Self {
        PayloadArena::new(DEFAULT_POOL_CAP)
    }
}

impl PayloadArena {
    /// Arena keeping at most `cap` retired buffers for reuse.
    pub fn new(cap: usize) -> Self {
        PayloadArena { pool: Vec::new(), watermark: 0, cap }
    }

    /// A cleared buffer ready for one payload: pooled when available,
    /// fresh otherwise, presized to the high-water mark plus headroom
    /// (the encoded length breathes a few bytes round-to-round as the
    /// kept set rotates — 25% + 64 covers it without regrowth).
    pub fn take(&mut self) -> Vec<u8> {
        let mut b = self.pool.pop().unwrap_or_default();
        b.clear();
        let target = self.watermark + self.watermark / 4 + 64;
        if b.capacity() < target {
            b.reserve(target - b.len());
        }
        b
    }

    /// Teach the arena an observed payload length without returning a
    /// buffer (used when the buffer itself must keep flowing downstream).
    pub fn note(&mut self, len: usize) {
        self.watermark = self.watermark.max(len);
    }

    /// Return a retired payload buffer to the pool (dropped if the pool
    /// is full); its length feeds the high-water mark first.
    pub fn recycle(&mut self, b: Vec<u8>) {
        self.watermark = self.watermark.max(b.len());
        if self.pool.len() < self.cap {
            self.pool.push(b);
        }
    }

    /// Largest payload length seen so far.
    pub fn watermark(&self) -> usize {
        self.watermark
    }

    /// Number of buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

/// Bounded recycle pool of decoded [`SparseVec`]s (shard aggregators:
/// one live per in-flight uplink, recycled on merge or decode error).
#[derive(Debug)]
pub struct SparsePool {
    pool: Vec<SparseVec>,
    cap: usize,
}

impl SparsePool {
    /// Pool keeping at most `cap` retired vectors for reuse.
    pub fn new(cap: usize) -> Self {
        SparsePool { pool: Vec::new(), cap }
    }

    /// A cleared `SparseVec`: pooled (warm capacity) when available.
    pub fn take(&mut self) -> SparseVec {
        self.pool.pop().unwrap_or_default()
    }

    /// Return a retired vector to the pool (cleared here, capacity kept;
    /// dropped if the pool is full).
    pub fn recycle(&mut self, mut sv: SparseVec) {
        sv.clear();
        if self.pool.len() < self.cap {
            self.pool.push(sv);
        }
    }

    /// Number of vectors currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_grows_and_presizes_take() {
        let mut a = PayloadArena::new(4);
        let mut b = a.take();
        assert_eq!(b.len(), 0);
        b.extend_from_slice(&[0u8; 1000]);
        a.recycle(b);
        assert_eq!(a.watermark(), 1000);
        // a fresh take must be presized past the watermark + headroom
        let b2 = a.take();
        assert!(b2.is_empty());
        assert!(b2.capacity() >= 1000 + 250 + 64, "cap={}", b2.capacity());
        // note() teaches the watermark without a buffer
        a.note(5000);
        assert_eq!(a.watermark(), 5000);
        assert!(a.take().capacity() >= 5000);
    }

    #[test]
    fn pool_capacity_is_bounded() {
        let mut a = PayloadArena::new(2);
        for _ in 0..5 {
            a.recycle(vec![0u8; 10]);
        }
        assert_eq!(a.pooled(), 2);
        // takes drain the pool, then fall back to fresh buffers
        let (x, y, z) = (a.take(), a.take(), a.take());
        assert_eq!(a.pooled(), 0);
        assert!(x.is_empty() && y.is_empty() && z.is_empty());
    }

    #[test]
    fn recycled_buffers_are_reused_warm() {
        let mut a = PayloadArena::new(4);
        let mut b = a.take();
        b.extend_from_slice(&[7u8; 512]);
        let ptr = b.as_ptr();
        a.recycle(b);
        let b2 = a.take();
        // same backing allocation, cleared for the next payload
        assert_eq!(b2.as_ptr(), ptr);
        assert!(b2.is_empty());
    }

    #[test]
    fn sparse_pool_recycles_cleared_with_capacity() {
        let mut p = SparsePool::new(2);
        let mut sv = p.take();
        sv.idx.extend(0..100u32);
        sv.vals.extend((0..100).map(|i| i as f32));
        p.recycle(sv);
        assert_eq!(p.pooled(), 1);
        let sv2 = p.take();
        assert!(sv2.is_empty());
        assert!(sv2.idx.capacity() >= 100 && sv2.vals.capacity() >= 100);
        // bounded: extra recycles beyond cap are dropped
        p.recycle(SparseVec::default());
        p.recycle(SparseVec::default());
        p.recycle(SparseVec::default());
        assert_eq!(p.pooled(), 2);
    }
}
