//! Adaptive sparsification schedule (paper §3.4, Eq. 4).
//!
//! `k_t = k_min + (k_max − k_min) · exp(−γ (L₀ − L_{t−1}))`
//!
//! As the global loss drops below its initial value, the kept fraction
//! decays from k_max toward k_min. Matrices A and B get *different*
//! (k_min, γ): B is intrinsically sparser and sparsifies faster (larger γ,
//! smaller k_min) — the matrix-adaptive half of the scheme.

use crate::model::LoraKind;

/// Schedule parameters for one matrix family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KSchedule {
    pub k_min: f64,
    pub k_max: f64,
    pub gamma: f64,
}

impl KSchedule {
    /// Eq. 4. `l0` = initial global loss, `l_prev` = last round's loss.
    pub fn k(&self, l0: f64, l_prev: f64) -> f64 {
        let drop = (l0 - l_prev).max(0.0); // loss above L0 => no extra sparsity
        let k = self.k_min + (self.k_max - self.k_min) * (-self.gamma * drop).exp();
        k.clamp(self.k_min.min(self.k_max), self.k_max.max(self.k_min))
    }
}

/// Paper defaults (Appendix A): k_max = 0.95, k_min^A = 0.6, k_min^B = 0.5,
/// with γ_B > γ_A to track B's faster sparsification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveSparsifier {
    pub a: KSchedule,
    pub b: KSchedule,
}

impl Default for AdaptiveSparsifier {
    fn default() -> Self {
        AdaptiveSparsifier {
            a: KSchedule { k_min: 0.6, k_max: 0.95, gamma: 1.0 },
            b: KSchedule { k_min: 0.5, k_max: 0.95, gamma: 2.0 },
        }
    }
}

impl AdaptiveSparsifier {
    pub fn with_k_mins(k_min_a: f64, k_min_b: f64) -> Self {
        AdaptiveSparsifier {
            a: KSchedule { k_min: k_min_a, ..Self::default().a },
            b: KSchedule { k_min: k_min_b, ..Self::default().b },
        }
    }

    /// Fixed-ratio variant (Table 3 "w/ Fixed Sparsification" and the
    /// Table 5 top-k baseline): k constant for both matrices.
    pub fn fixed(k: f64) -> Self {
        AdaptiveSparsifier {
            a: KSchedule { k_min: k, k_max: k, gamma: 0.0 },
            b: KSchedule { k_min: k, k_max: k, gamma: 0.0 },
        }
    }

    pub fn schedule(&self, kind: LoraKind) -> &KSchedule {
        match kind {
            LoraKind::A => &self.a,
            LoraKind::B => &self.b,
        }
    }

    /// Current keep fractions (k_A, k_B) given the loss signal.
    pub fn k_pair(&self, l0: f64, l_prev: f64) -> (f64, f64) {
        (self.a.k(l0, l_prev), self.b.k(l0, l_prev))
    }

    /// Average keep fraction over a vector with `n_a` A-entries and `n_b`
    /// B-entries (used to pick the Golomb parameter and for accounting).
    pub fn effective_k(&self, l0: f64, l_prev: f64, n_a: usize, n_b: usize) -> f64 {
        let (ka, kb) = self.k_pair(l0, l_prev);
        let n = (n_a + n_b).max(1);
        (ka * n_a as f64 + kb * n_b as f64) / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_starts_at_kmax_and_decays_to_kmin() {
        let s = KSchedule { k_min: 0.5, k_max: 0.95, gamma: 2.0 };
        assert!((s.k(3.0, 3.0) - 0.95).abs() < 1e-12); // no progress yet
        assert!(s.k(3.0, 2.0) < 0.95);
        assert!((s.k(3.0, -50.0) - 0.5).abs() < 1e-6); // huge progress
    }

    #[test]
    fn loss_increase_does_not_raise_k_above_kmax() {
        let s = KSchedule { k_min: 0.5, k_max: 0.95, gamma: 2.0 };
        assert!((s.k(3.0, 10.0) - 0.95).abs() < 1e-12);
    }

    #[test]
    fn k_is_monotone_in_loss_drop() {
        let s = KSchedule { k_min: 0.3, k_max: 0.9, gamma: 1.5 };
        let mut prev = f64::INFINITY;
        for i in 0..20 {
            let l = 3.0 - 0.15 * i as f64;
            let k = s.k(3.0, l);
            assert!(k <= prev + 1e-12);
            prev = k;
        }
    }

    #[test]
    fn b_sparser_than_a_once_training_progresses() {
        let sp = AdaptiveSparsifier::default();
        let (ka, kb) = sp.k_pair(3.0, 1.0);
        assert!(kb < ka, "kA={ka} kB={kb}");
    }

    #[test]
    fn fixed_variant_is_constant() {
        let sp = AdaptiveSparsifier::fixed(0.7);
        for l in [3.0, 2.0, 0.5] {
            let (ka, kb) = sp.k_pair(3.0, l);
            assert!((ka - 0.7).abs() < 1e-12 && (kb - 0.7).abs() < 1e-12);
        }
    }

    #[test]
    fn effective_k_weighted_average() {
        let sp = AdaptiveSparsifier::with_k_mins(0.6, 0.2);
        let k = sp.effective_k(3.0, -100.0, 100, 300); // fully decayed
        assert!((k - (0.6 * 100.0 + 0.2 * 300.0) / 400.0).abs() < 1e-6);
    }
}
