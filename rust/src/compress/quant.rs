//! Quantization baselines from the paper's related work (§2.3): signSGD
//! (Bernstein et al. 2018), ternary compression (Xu et al. 2020), and
//! uniform b-bit stochastic quantization. EcoLoRA argues sparsification
//! beats quantization for federated LoRA; these implementations let the
//! comparison be run rather than asserted (bench: hotpath + table5-style
//! sweeps).

use crate::util::rng::Rng;

/// signSGD: 1 bit per entry plus one shared scale (the mean |x|).
#[derive(Debug, Clone)]
pub struct SignCompressed {
    pub signs: Vec<u8>, // bit-packed, MSB-first
    pub scale: f32,
    pub len: usize,
}

pub fn sign_compress(x: &[f32]) -> SignCompressed {
    let scale = if x.is_empty() {
        0.0
    } else {
        x.iter().map(|v| v.abs()).sum::<f32>() / x.len() as f32
    };
    let mut signs = vec![0u8; (x.len() + 7) / 8];
    for (i, v) in x.iter().enumerate() {
        if *v < 0.0 {
            signs[i / 8] |= 1 << (7 - i % 8);
        }
    }
    SignCompressed { signs, scale, len: x.len() }
}

pub fn sign_decompress(c: &SignCompressed) -> Vec<f32> {
    (0..c.len)
        .map(|i| {
            if c.signs[i / 8] >> (7 - i % 8) & 1 == 1 {
                -c.scale
            } else {
                c.scale
            }
        })
        .collect()
}

/// Wire bytes for signSGD (1 bit/entry + f32 scale).
pub fn sign_bytes(len: usize) -> usize {
    (len + 7) / 8 + 4
}

/// Ternary {-s, 0, +s}: entries below `threshold_frac * max|x|` send 0.
/// 2 bits per entry + scale.
#[derive(Debug, Clone)]
pub struct TernaryCompressed {
    pub codes: Vec<u8>, // 2-bit codes packed 4/byte: 0=zero, 1=+s, 2=-s
    pub scale: f32,
    pub len: usize,
}

pub fn ternary_compress(x: &[f32], threshold_frac: f32) -> TernaryCompressed {
    let maxabs = crate::util::simd::max_abs(x);
    let thr = threshold_frac * maxabs;
    // scale = mean |x| over the kept entries (unbiased-ish reconstruction)
    let kept: Vec<f32> = x.iter().filter(|v| v.abs() > thr).map(|v| v.abs()).collect();
    let scale = if kept.is_empty() {
        0.0
    } else {
        kept.iter().sum::<f32>() / kept.len() as f32
    };
    let mut codes = vec![0u8; (x.len() + 3) / 4];
    for (i, v) in x.iter().enumerate() {
        let code: u8 = if v.abs() <= thr {
            0
        } else if *v > 0.0 {
            1
        } else {
            2
        };
        codes[i / 4] |= code << (6 - 2 * (i % 4));
    }
    TernaryCompressed { codes, scale, len: x.len() }
}

pub fn ternary_decompress(c: &TernaryCompressed) -> Vec<f32> {
    (0..c.len)
        .map(|i| match c.codes[i / 4] >> (6 - 2 * (i % 4)) & 3 {
            1 => c.scale,
            2 => -c.scale,
            _ => 0.0,
        })
        .collect()
}

pub fn ternary_bytes(len: usize) -> usize {
    (len + 3) / 4 + 4
}

/// Uniform b-bit stochastic quantization in [-max|x|, max|x|].
pub fn uniform_quantize(x: &[f32], bits: u32, rng: &mut Rng) -> (Vec<u32>, f32) {
    assert!(bits >= 1 && bits <= 16);
    let maxabs = crate::util::simd::max_abs(x).max(1e-30);
    let levels = (1u32 << bits) - 1;
    let q = x
        .iter()
        .map(|v| {
            let t = (v + maxabs) / (2.0 * maxabs) * levels as f32;
            let lo = t.floor();
            // stochastic rounding: unbiased reconstruction
            let up = rng.next_f32() < (t - lo);
            (lo as u32 + up as u32).min(levels)
        })
        .collect();
    (q, maxabs)
}

pub fn uniform_dequantize(q: &[u32], bits: u32, maxabs: f32) -> Vec<f32> {
    let levels = ((1u32 << bits) - 1) as f32;
    q.iter()
        .map(|&c| (c as f32 / levels) * 2.0 * maxabs - maxabs)
        .collect()
}

pub fn uniform_bytes(len: usize, bits: u32) -> usize {
    (len * bits as usize + 7) / 8 + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn sign_roundtrip_preserves_signs_and_scale() {
        propcheck(100, |rng| {
            let n = rng.below(500) + 1;
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let c = sign_compress(&x);
            let y = sign_decompress(&c);
            assert_eq!(y.len(), n);
            for (a, b) in x.iter().zip(&y) {
                if *a != 0.0 {
                    assert_eq!(a.signum(), b.signum());
                }
                assert!((b.abs() - c.scale).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn sign_is_32x_smaller_than_f32() {
        assert!(sign_bytes(32_000) < 32_000 * 4 / 30);
    }

    #[test]
    fn ternary_zeroes_small_entries_and_keeps_large_signs() {
        let x = vec![10.0f32, -0.01, 0.02, -9.0, 0.0];
        let c = ternary_compress(&x, 0.1);
        let y = ternary_decompress(&c);
        assert!(y[1] == 0.0 && y[2] == 0.0 && y[4] == 0.0);
        assert!(y[0] > 0.0 && y[3] < 0.0);
        assert!((y[0] - 9.5).abs() < 1e-5); // mean(10, 9)
    }

    #[test]
    fn uniform_quantization_is_unbiased_and_bounded() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        for bits in [2, 4, 8] {
            let (q, s) = uniform_quantize(&x, bits, &mut rng);
            let y = uniform_dequantize(&q, bits, s);
            let step = 2.0 * s / ((1u32 << bits) - 1) as f32;
            let mut bias = 0.0f64;
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() <= step + 1e-5, "bits={bits}");
                bias += (*b - *a) as f64;
            }
            assert!(
                (bias / x.len() as f64).abs() < 3.0 * step as f64 / (x.len() as f64).sqrt() + 1e-4,
                "bits={bits} bias {bias}"
            );
        }
    }

    #[test]
    fn byte_accounting() {
        assert_eq!(sign_bytes(8), 1 + 4);
        assert_eq!(ternary_bytes(8), 2 + 4);
        assert_eq!(uniform_bytes(8, 4), 4 + 4);
    }

    #[test]
    fn sparsified_topk_beats_quantization_on_heavy_tails() {
        // The paper's §2.3 claim at equal byte budget: for heavy-tailed LoRA
        // updates, top-k + f16 (EcoLoRA's choice) retains more L2 mass than
        // sign-1bit at the same wire size.
        let mut rng = Rng::new(9);
        let n = 20_000;
        let x: Vec<f32> = (0..n)
            .map(|_| {
                if rng.below(20) == 0 {
                    rng.normal() as f32 * 5.0
                } else {
                    rng.normal() as f32 * 0.02
                }
            })
            .collect();
        // byte budget = signSGD's
        let budget = sign_bytes(n);
        // top-k with ~18 bits/entry (f16 + coded position)
        let keep = budget * 8 / 18;
        let (idx, vals) = crate::compress::topk::sparsify(&x, keep);
        let err_topk: f64 = {
            let mut y = vec![0.0f32; n];
            for (&i, &v) in idx.iter().zip(&vals) {
                y[i as usize] = v;
            }
            x.iter().zip(&y).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        let y_sign = sign_decompress(&sign_compress(&x));
        let err_sign: f64 =
            x.iter().zip(&y_sign).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(
            err_topk < err_sign,
            "topk err {err_topk:.2} vs sign err {err_sign:.2} at equal bytes"
        );
    }
}
