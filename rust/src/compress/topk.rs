//! Top-k magnitude sparsification (paper §3.4).
//!
//! `SC_k` keeps the k-fraction of entries with the largest |value| and
//! zeroes the rest. The selection threshold is found with an O(n) in-place
//! quickselect over magnitudes (the paper budgets O(|P| log |P|) for a
//! sort; quickselect is the optimized hot path, see EXPERIMENTS.md §Perf).

/// Indices (ascending) of the `keep` largest-magnitude entries, written
/// into `out` using `mags` as selection scratch (both cleared first,
/// capacity retained — the zero-allocation hot path; see
/// docs/ARCHITECTURE.md §Codec hot path).
pub fn topk_indices_into(values: &[f32], keep: usize, mags: &mut Vec<f32>, out: &mut Vec<u32>) {
    out.clear();
    let n = values.len();
    if keep == 0 || n == 0 {
        return;
    }
    if keep >= n {
        out.extend(0..n as u32);
        return;
    }
    // Quickselect over magnitudes in the caller's scratch buffer; the
    // strictly-above count falls out of the partition bookkeeping, so no
    // second full scan is needed.
    crate::util::simd::abs_into(values, mags);
    let (thresh, above) = quickselect_desc(mags, keep - 1);

    // SIMD threshold scan collects every index with |v| >= thresh in
    // ascending order; the scalar trim below then keeps all strict
    // "aboves" plus the first (keep - above) ties by index — exactly the
    // selection (and tie-break order) of the old fused scalar loop.
    // `above <= keep - 1` always holds (quickselect's fused count starts
    // at the k-th rank), so the subtraction cannot underflow even on
    // NaN-containing input.
    crate::util::simd::select_ge_abs(values, thresh, out);
    let mut ties_allowed = keep - above;
    let (mut r, mut w) = (0usize, 0usize);
    while r < out.len() && w < keep {
        let i = out[r];
        r += 1;
        let m = values[i as usize].abs();
        if m > thresh {
            out[w] = i;
            w += 1;
        } else if ties_allowed > 0 {
            out[w] = i;
            w += 1;
            ties_allowed -= 1;
        }
    }
    out.truncate(w);
}

/// Indices (ascending) of the `keep` largest-magnitude entries.
pub fn topk_indices(values: &[f32], keep: usize) -> Vec<u32> {
    let mut mags = Vec::new();
    let mut out = Vec::new();
    topk_indices_into(values, keep, &mut mags, &mut out);
    out
}

/// k-th largest (0-based) element via iterative quickselect, plus the
/// exact count of elements strictly greater than it — fused into the
/// partition bookkeeping rather than recounted with a full scan
/// (§Perf: the count is needed for deterministic tie trimming).
/// O(n) expected.
fn quickselect_desc(v: &mut [f32], k: usize) -> (f32, usize) {
    let (mut lo, mut hi) = (0usize, v.len());
    let mut k = k;
    // Elements discarded to the LEFT of the live window when recursing
    // right are >= that step's pivot, while the final answer is strictly
    // below it — so they are exactly the elements proven strictly greater
    // than the answer. Left recursions discard only elements <= pivot
    // < answer, which contribute nothing.
    let mut above = 0usize;
    loop {
        if hi - lo <= 1 {
            return (v[lo], above);
        }
        // median-of-three pivot for resilience on sorted inputs
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (v[lo], v[mid], v[hi - 1]);
        let pivot = if (a <= b) == (b <= c) { b } else if (b <= a) == (a <= c) { a } else { c };

        // three-way partition (descending: > pivot | == pivot | < pivot)
        let (mut i, mut j, mut p) = (lo, lo, hi);
        while j < p {
            if v[j] > pivot {
                v.swap(i, j);
                i += 1;
                j += 1;
            } else if v[j] < pivot {
                p -= 1;
                v.swap(j, p);
            } else {
                j += 1;
            }
        }
        if k < i - lo {
            // answer is > pivot: everything at or below pivot drops out
            hi = i;
        } else if k < p - lo {
            // answer IS pivot: [lo, i) holds its strictly-greater peers
            return (pivot, above + (i - lo));
        } else {
            // answer is < pivot: all of [lo, p) is strictly greater
            above += p - lo;
            k -= p - lo;
            lo = p;
        }
    }
}

/// Apply SC_k: returns (indices, kept values) and leaves a dense sparse
/// image when asked (used by tests & the residual update).
pub fn sparsify(values: &[f32], keep: usize) -> (Vec<u32>, Vec<f32>) {
    let idx = topk_indices(values, keep);
    let vals = idx.iter().map(|&i| values[i as usize]).collect();
    (idx, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    fn brute_force_topk(values: &[f32], keep: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..values.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            values[b as usize]
                .abs()
                .partial_cmp(&values[a as usize].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut out: Vec<u32> = idx.into_iter().take(keep).collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_brute_force_on_magnitude_sum() {
        // Selection sets may differ on ties, but the kept |mass| must match.
        propcheck(200, |rng| {
            let n = rng.below(2_000) + 1;
            let keep = rng.below(n + 1);
            let values: Vec<f32> = (0..n)
                .map(|_| (rng.normal() as f32) * if rng.below(4) == 0 { 10.0 } else { 0.1 })
                .collect();
            let fast = topk_indices(&values, keep);
            let brute = brute_force_topk(&values, keep);
            assert_eq!(fast.len(), keep.min(n));
            let mass = |idx: &[u32]| -> f64 {
                idx.iter().map(|&i| values[i as usize].abs() as f64).sum()
            };
            assert!((mass(&fast) - mass(&brute)).abs() < 1e-4 * (1.0 + mass(&brute)));
            // sorted ascending, unique
            assert!(fast.windows(2).all(|w| w[0] < w[1]));
        });
    }

    #[test]
    fn exact_on_distinct_values() {
        let values = [0.1f32, -5.0, 3.0, 0.01, -2.0, 4.0];
        assert_eq!(topk_indices(&values, 3), vec![1, 2, 5]);
        let (idx, vals) = sparsify(&values, 2);
        assert_eq!(idx, vec![1, 5]);
        assert_eq!(vals, vec![-5.0, 4.0]);
    }

    #[test]
    fn all_ties_keeps_exactly_k() {
        let values = vec![1.0f32; 100];
        let idx = topk_indices(&values, 37);
        assert_eq!(idx.len(), 37);
        assert_eq!(idx, (0..37u32).collect::<Vec<_>>());
    }

    #[test]
    fn edge_cases() {
        assert!(topk_indices(&[], 5).is_empty());
        assert!(topk_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(topk_indices(&[1.0, 2.0], 5), vec![0, 1]);
    }

    #[test]
    fn fused_above_count_matches_full_scan() {
        // the partition-fused strictly-greater count must equal the count
        // the old implementation obtained with a second pass
        propcheck(300, |rng| {
            let n = rng.below(1_500) + 2;
            let keep = rng.below(n - 1) + 1; // 1..n so quickselect runs
            let values: Vec<f32> = (0..n)
                .map(|_| {
                    // heavy ties: quantized magnitudes
                    let v = (rng.normal() * 4.0).round() as f32 * 0.25;
                    if rng.below(2) == 0 { v } else { -v }
                })
                .collect();
            let mut mags: Vec<f32> = values.iter().map(|v| v.abs()).collect();
            let (thresh, above) = quickselect_desc(&mut mags, keep - 1);
            let scanned = values.iter().filter(|v| v.abs() > thresh).count();
            assert_eq!(above, scanned, "n={n} keep={keep} thresh={thresh}");
        });
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation() {
        // warm buffers across calls of varying size must not change results
        let mut mags = Vec::new();
        let mut out = Vec::new();
        let mut rng = crate::util::rng::Rng::new(11);
        for n in [500usize, 37, 1200, 1, 64] {
            let values: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let keep = n / 3;
            topk_indices_into(&values, keep, &mut mags, &mut out);
            assert_eq!(out, topk_indices(&values, keep), "n={n}");
        }
    }

    #[test]
    fn degenerate_inputs_are_stable() {
        let mut mags = vec![9.9f32; 8]; // dirty scratch must not leak through
        let mut out = vec![77u32; 8];

        // keep == 0 clears the output
        topk_indices_into(&[1.0, -2.0, 3.0], 0, &mut mags, &mut out);
        assert!(out.is_empty());

        // empty input clears the output
        out.extend([5, 6]);
        topk_indices_into(&[], 4, &mut mags, &mut out);
        assert!(out.is_empty());

        // keep == len and keep > len both select everything, in order
        topk_indices_into(&[4.0, -1.0, 0.0], 3, &mut mags, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        topk_indices_into(&[4.0, -1.0, 0.0], 100, &mut mags, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn all_equal_magnitudes_tie_break_by_lowest_index() {
        // every magnitude ties: selection must be the first `keep` indices,
        // pinning the tie-break order the SIMD threshold scan must preserve
        let values = vec![-2.5f32; 64];
        for keep in [1usize, 7, 63, 64] {
            let idx = topk_indices(&values, keep);
            assert_eq!(idx, (0..keep as u32).collect::<Vec<_>>(), "keep={keep}");
        }
    }

    #[test]
    fn tie_break_order_is_pinned_across_interleaved_ties() {
        // thresh = 1, above = 2 (5.0 and 9.0): two tie slots go to the
        // lowest-index ties (0 and 2), NOT to the later tie at index 5,
        // and the strict above at index 4 survives past skipped ties
        let values = [1.0f32, 5.0, 1.0, -1.0, 9.0, 1.0];
        assert_eq!(topk_indices(&values, 4), vec![0, 1, 2, 4]);
    }

    #[test]
    fn nan_values_are_never_selected() {
        let mut values: Vec<f32> = (0..200).map(|i| ((i as f32) - 100.0) * 0.1).collect();
        for i in (0..200).step_by(17) {
            values[i] = f32::NAN;
        }
        let keep = 40;
        let idx = topk_indices(&values, keep);
        // NaN fails every ordered compare, so it can shrink the selection
        // but must never enter it; order stays ascending unique
        assert!(idx.len() <= keep);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| !values[i as usize].is_nan()));
        // warm-scratch rerun is deterministic
        let mut mags = Vec::new();
        let mut out = Vec::new();
        topk_indices_into(&values, keep, &mut mags, &mut out);
        assert_eq!(out, idx);
    }

    #[test]
    fn sorted_input_no_quadratic_blowup() {
        // median-of-three: sorted inputs must still finish fast
        let values: Vec<f32> = (0..200_000).map(|i| i as f32).collect();
        let t0 = std::time::Instant::now();
        let idx = topk_indices(&values, 1000);
        assert_eq!(idx.len(), 1000);
        assert!(t0.elapsed().as_secs_f64() < 1.0);
        assert_eq!(idx[0], 199_000);
    }
}
