//! Golomb/Rice coding of position gaps (paper §3.5).
//!
//! With top-k sparsification each entry is nonzero with probability `k`,
//! so gaps between consecutive nonzero indices are Geometric(k); Golomb
//! coding with parameter `m ≈ -1/log2(1-k)` (Golomb 1966) is the optimal
//! prefix code for that distribution. We use the Rice restriction
//! (m = 2^b) which is within half a bit of optimal and decodes with shifts
//! only — this is the decode hot path of every round.

use crate::util::bitstream::{BitReader, BitWriter};

/// Optimal Rice parameter b (m = 2^b) for gap distribution Geometric(k).
///
/// Golomb's rule: choose m such that (1-k)^m ≈ 1/2, i.e.
/// m* = -1/log2(1-k); we take b = round(log2(m*)) clamped to [0, 24].
pub fn rice_param_for_density(k: f64) -> u32 {
    let k = k.clamp(1e-6, 1.0 - 1e-6);
    let m_star = -1.0 / (1.0 - k).log2();
    let b = m_star.log2().round();
    b.clamp(0.0, 24.0) as u32
}

/// Expected bits per gap under Geometric(k) with Rice parameter b.
/// (Used for accounting and in tests against measured stream sizes.)
pub fn expected_bits_per_gap(k: f64, b: u32) -> f64 {
    // gap g >= 0 encodes as unary(g >> b) + 1 terminator + b remainder bits.
    // E[quotient] = E[g] / 2^b approximately; exact: E[floor(g/m)] for
    // g ~ Geom(k) on {0,1,...} is (1-k)^m / (1 - (1-k)^m).
    let q = (1.0 - k).powi(1 << b);
    let e_quot = if q >= 1.0 { f64::INFINITY } else { q / (1.0 - q) };
    e_quot + 1.0 + b as f64
}

/// Encode one nonnegative gap with Rice parameter b (b < 64).
///
/// (Historical bug, fixed: the remainder used to be masked with
/// `((1u64 << b) - 1).min(u64::MAX)` — the `.min` was a no-op that did
/// NOT guard the `b == 64` shift overflow it was presumably written for.
/// `BitWriter::write_bits` masks to the low `b` bits itself, and is a
/// no-op for `b == 0`, so no pre-mask is needed at all.)
#[inline]
pub fn encode_gap(w: &mut BitWriter, gap: u64, b: u32) {
    debug_assert!(b < 64, "rice parameter must leave room for the quotient shift");
    w.write_unary(gap >> b);
    w.write_bits(gap, b);
}

/// Decode one gap.
#[inline]
pub fn decode_gap(r: &mut BitReader, b: u32) -> Option<u64> {
    let q = r.read_unary()?;
    let rem = if b == 0 { 0 } else { r.read_bits(b)? };
    Some((q << b) | rem)
}

/// Encode a sorted index list as Golomb-coded gaps into an existing
/// writer (scratch-reuse hot path; the writer is NOT cleared first).
pub fn encode_indices_into(indices: &[u32], b: u32, w: &mut BitWriter) {
    let mut prev = 0u64;
    for (i, &idx) in indices.iter().enumerate() {
        let gap = if i == 0 { idx as u64 } else { idx as u64 - prev - 1 };
        encode_gap(w, gap, b);
        prev = idx as u64;
    }
}

/// Encode a sorted index list as Golomb-coded gaps.
/// Returns the bitstream; `b` must match on decode.
pub fn encode_indices(indices: &[u32], b: u32) -> BitWriter {
    let mut w = BitWriter::new();
    encode_indices_into(indices, b, &mut w);
    w
}

/// Upper bound on the encoded bit length of `count` ascending indices
/// drawn from `[0, universe)` with Rice parameter `b`: each entry costs
/// `1 + b` bits (terminator + remainder) and the unary quotients sum to
/// at most `universe >> b` (the gaps sum to less than `universe`). Used
/// to presize scratch writers so the steady-state encode path never
/// reallocates.
pub fn max_stream_bits(count: usize, universe: usize, b: u32) -> u64 {
    debug_assert!(b < 64);
    count as u64 * (1 + b as u64) + ((universe as u64) >> b)
}

/// Decode `count` indices from a Golomb gap stream into `out`
/// (cleared and presized from the caller's header count). Returns the
/// number of bits consumed so the caller can cross-check the stream
/// length from its framing header.
pub fn decode_indices_into(
    bytes: &[u8],
    count: usize,
    b: u32,
    out: &mut Vec<u32>,
) -> Option<u64> {
    let mut r = BitReader::new(bytes);
    out.clear();
    out.reserve(count);
    let mut prev = 0u64;
    for i in 0..count {
        let gap = decode_gap(&mut r, b)?;
        let idx = if i == 0 { gap } else { prev + 1 + gap };
        out.push(u32::try_from(idx).ok()?);
        prev = idx;
    }
    Some(r.bits_consumed())
}

/// Decode `count` indices from a Golomb gap stream.
pub fn decode_indices(bytes: &[u8], count: usize, b: u32) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    decode_indices_into(bytes, count, b, &mut out)?;
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn single_gaps_roundtrip_all_params() {
        for b in 0..=12 {
            let mut w = BitWriter::new();
            let gaps = [0u64, 1, 2, 7, 63, 64, 1000, 4095];
            for &g in &gaps {
                encode_gap(&mut w, g, b);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &g in &gaps {
                assert_eq!(decode_gap(&mut r, b), Some(g), "b={b} g={g}");
            }
        }
    }

    #[test]
    fn sorted_indices_roundtrip_property() {
        propcheck(300, |rng| {
            let universe = rng.below(100_000) + 10;
            let k = rng.range_f64(0.005, 0.9);
            let n = ((universe as f64 * k) as usize).clamp(1, universe);
            let mut idx = rng.sample_indices(universe, n);
            idx.sort_unstable();
            let idx: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
            let b = rice_param_for_density(k);
            let stream = encode_indices(&idx, b);
            let bit_len = stream.bit_len();
            let bytes = stream.into_bytes();
            let mut decoded = Vec::new();
            let consumed = decode_indices_into(&bytes, idx.len(), b, &mut decoded).unwrap();
            assert_eq!(decoded, idx);
            // the decoder must consume exactly what the encoder wrote
            assert_eq!(consumed, bit_len);
            assert!(bit_len <= max_stream_bits(idx.len(), universe, b), "bound violated");
        });
    }

    #[test]
    fn b_zero_is_pure_unary_and_roundtrips() {
        // b == 0: no remainder bits at all; encode_gap must not emit a
        // zero-width field with garbage, and decode_gap must not read one
        let gaps = [0u64, 1, 5, 63, 64, 200];
        let mut w = BitWriter::new();
        for &g in &gaps {
            encode_gap(&mut w, g, 0);
        }
        // pure unary: total bits = sum(gaps) + one terminator each
        let expect_bits: u64 = gaps.iter().sum::<u64>() + gaps.len() as u64;
        assert_eq!(w.bit_len(), expect_bits);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &g in &gaps {
            assert_eq!(decode_gap(&mut r, 0), Some(g));
        }
        assert_eq!(r.bits_consumed(), expect_bits);
    }

    #[test]
    fn large_b_remainders_keep_all_bits() {
        // b = 24 (the clamp ceiling): remainders are wide fields; a gap
        // just below / at / above 2^b exercises the quotient boundary
        let b = 24u32;
        let gaps = [0u64, (1 << 24) - 1, 1 << 24, (1 << 24) + 1, (3 << 24) + 12345];
        let mut w = BitWriter::new();
        for &g in &gaps {
            encode_gap(&mut w, g, b);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &g in &gaps {
            assert_eq!(decode_gap(&mut r, b), Some(g), "g={g}");
        }
    }

    #[test]
    fn rice_param_matches_paper_example() {
        // Paper §3.5: k = 0.1 -> b* = 4.8 bits per position on average,
        // a ~3.3x factor vs 16-bit fixed positions.
        let b = rice_param_for_density(0.1);
        let bits = expected_bits_per_gap(0.1, b);
        assert!((4.0..6.0).contains(&bits), "bits={bits} b={b}");
        assert!(16.0 / bits > 2.6, "compression factor {}", 16.0 / bits);
    }

    #[test]
    fn measured_stream_size_close_to_expectation() {
        let mut rng = Rng::new(17);
        let universe = 200_000usize;
        for &k in &[0.02f64, 0.1, 0.3] {
            let mut idx: Vec<u32> = (0..universe as u32)
                .filter(|_| rng.next_f64() < k)
                .collect();
            idx.sort_unstable();
            let b = rice_param_for_density(k);
            let stream = encode_indices(&idx, b);
            let measured = stream.bit_len() as f64 / idx.len() as f64;
            let expected = expected_bits_per_gap(k, b);
            assert!(
                (measured - expected).abs() / expected < 0.15,
                "k={k}: measured {measured:.2} vs expected {expected:.2}"
            );
        }
    }

    #[test]
    fn golomb_beats_fixed_width_at_realistic_densities() {
        // The whole point of §3.5: at the adaptive-k densities (<= 0.5 for
        // B late in training) the coded stream must beat 32-bit and beat
        // ceil(log2(n)) fixed packing at low k.
        let mut rng = Rng::new(23);
        let universe = 100_000usize;
        for &k in &[0.05f64, 0.1, 0.2] {
            let mut idx: Vec<u32> =
                (0..universe as u32).filter(|_| rng.next_f64() < k).collect();
            idx.sort_unstable();
            let b = rice_param_for_density(k);
            let bits = encode_indices(&idx, b).bit_len() as f64 / idx.len() as f64;
            let fixed = (universe as f64).log2().ceil();
            assert!(bits < fixed, "k={k}: golomb {bits:.2} >= fixed {fixed}");
        }
    }

    #[test]
    fn param_monotone_in_sparsity() {
        // Sparser streams (smaller k) need larger Rice parameters.
        assert!(rice_param_for_density(0.01) > rice_param_for_density(0.1));
        assert!(rice_param_for_density(0.1) > rice_param_for_density(0.5));
    }
}
