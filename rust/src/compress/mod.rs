//! EcoLoRA compression pipeline (paper §3.4–3.5): matrix-adaptive top-k
//! sparsification with error feedback, f16 value quantization, and
//! Golomb-coded sparse wire messages.

pub mod adaptive;
pub mod golomb;
pub mod quant;
pub mod residual;
pub mod topk;
pub mod wire;

use std::sync::Arc;

pub use adaptive::AdaptiveSparsifier;
pub use residual::Residual;
pub use wire::{Encoding, KindIndex, SparseVec};

use crate::model::LoraKind;
use crate::util::half::quantize_f16;

/// How updates are sparsified (ablation axis for Tables 3 & 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SparsMode {
    /// Loss- and matrix-adaptive (the paper's scheme).
    Adaptive(AdaptiveSparsifier),
    /// Fixed ratio for both matrices ("w/ Fixed Sparsification").
    Fixed(f64),
    /// No sparsification ("w/o Sparsification"): dense f16 transmission.
    Off,
}

/// One endpoint's compression state (client uplink or server downlink).
pub struct Compressor {
    pub mode: SparsMode,
    pub encoding: Encoding,
    residual: Residual,
    kinds: Arc<Vec<LoraKind>>,
    kidx: Arc<KindIndex>,
    /// scratch: U + R
    combined: Vec<f32>,
}

/// Outcome of compressing one update.
pub struct Compressed {
    /// Quantized sparse update (what the receiver will reconstruct).
    pub sv: SparseVec,
    /// Densities used, (k_A, k_B) — for wire headers and accounting.
    pub k: (f64, f64),
    /// Dense fallback (mode == Off): full quantized vector.
    pub dense: Option<Vec<f32>>,
}

impl Compressor {
    pub fn new(
        mode: SparsMode,
        encoding: Encoding,
        kinds: Arc<Vec<LoraKind>>,
        kidx: Arc<KindIndex>,
    ) -> Self {
        let n = kinds.len();
        Compressor { mode, encoding, residual: Residual::new(n), kinds, kidx, combined: vec![0.0; n] }
    }

    pub fn kind_index(&self) -> &KindIndex {
        &self.kidx
    }

    /// Residual L1 mass (diagnostics; bounded under error feedback).
    pub fn residual_l1(&self) -> f64 {
        self.residual.l1()
    }

    /// Compress `update` given the loss signal (L0, L_{t-1}).
    ///
    /// Applies Eq. 4 per matrix family, Eq. 5 (SC_k over U + R), f16
    /// quantization, and Eq. 6 residual commit. In `Off` mode the update is
    /// transmitted dense (quantized, no residual needed beyond the f16
    /// error, which IS fed back).
    pub fn compress(&mut self, update: &[f32], l0: f64, l_prev: f64) -> Compressed {
        assert_eq!(update.len(), self.kinds.len());
        self.combined.copy_from_slice(update);
        self.residual.add_into(&mut self.combined);

        let (k_a, k_b) = match self.mode {
            SparsMode::Adaptive(sp) => sp.k_pair(l0, l_prev),
            SparsMode::Fixed(k) => (k, k),
            SparsMode::Off => (1.0, 1.0),
        };

        if matches!(self.mode, SparsMode::Off) {
            let dense: Vec<f32> = self.combined.iter().map(|&v| quantize_f16(v)).collect();
            let idx: Vec<u32> = (0..dense.len() as u32).collect();
            self.residual.commit(&self.combined, &idx, &dense);
            return Compressed {
                sv: SparseVec { idx, vals: dense.clone() },
                k: (1.0, 1.0),
                dense: Some(dense),
            };
        }

        // Per-family top-k over compacted coordinates, then merge.
        let mut idx = Vec::new();
        for (kind, k) in [(LoraKind::A, k_a), (LoraKind::B, k_b)] {
            let (fam, _r0) = self.kidx.in_range(kind, &(0..self.combined.len()));
            let famvals: Vec<f32> = fam.iter().map(|&p| self.combined[p as usize]).collect();
            let keep = ((famvals.len() as f64) * k).round() as usize;
            let kept = topk::topk_indices(&famvals, keep.min(famvals.len()));
            idx.extend(kept.iter().map(|&c| fam[c as usize]));
        }
        idx.sort_unstable();
        // Drop entries whose f16 image is exactly zero — transmitting them
        // is pure waste (e.g. FFA-LoRA's frozen-A updates are all zero).
        let mut kept_idx = Vec::with_capacity(idx.len());
        let mut vals = Vec::with_capacity(idx.len());
        for &i in &idx {
            let q = quantize_f16(self.combined[i as usize]);
            if q != 0.0 {
                kept_idx.push(i);
                vals.push(q);
            }
        }
        self.residual.commit(&self.combined, &kept_idx, &vals);
        Compressed { sv: SparseVec { idx: kept_idx, vals }, k: (k_a, k_b), dense: None }
    }

    /// Wire-encode a (possibly range-restricted) compressed update.
    pub fn encode_range(
        &self,
        c: &Compressed,
        range: &std::ops::Range<usize>,
    ) -> anyhow::Result<Vec<u8>> {
        let sv = c.sv.restrict(range);
        wire::encode(&sv, range, &self.kidx, c.k, self.encoding)
    }
}

/// Bytes for a dense f16 transmission of `n` parameters (baselines and the
/// `Off` mode; 2 bytes per value, negligible framing).
pub fn dense_bytes(n: usize) -> usize {
    2 * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>) {
        // alternate A/B blocks of 32 like the real layout
        let kinds: Vec<LoraKind> = (0..n)
            .map(|i| if (i / 32) % 2 == 0 { LoraKind::A } else { LoraKind::B })
            .collect();
        let kidx = KindIndex::new(&kinds);
        (Arc::new(kinds), Arc::new(kidx))
    }

    #[test]
    fn adaptive_mode_keeps_fewer_b_entries_late_in_training() {
        let (kinds, kidx) = setup(4096);
        let mut c = Compressor::new(
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds.clone(),
            kidx,
        );
        let mut rng = Rng::new(1);
        let update: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        // late in training: loss has dropped a lot
        let out = c.compress(&update, 3.0, 0.5);
        let n_a = out.sv.idx.iter().filter(|&&i| kinds[i as usize] == LoraKind::A).count();
        let n_b = out.sv.len() - n_a;
        assert!(n_b < n_a, "kept A={n_a} B={n_b}");
        assert!(out.k.1 < out.k.0);
    }

    #[test]
    fn off_mode_is_dense_and_f16_exact_feedback() {
        let (kinds, kidx) = setup(128);
        let mut c = Compressor::new(SparsMode::Off, Encoding::Golomb, kinds, kidx);
        let update = vec![0.1f32; 128];
        let out = c.compress(&update, 3.0, 3.0);
        assert_eq!(out.sv.len(), 128);
        assert!(out.dense.is_some());
        // residual carries exactly the f16 quantization error
        let err = 0.1f32 - quantize_f16(0.1);
        assert!((c.residual_l1() - 128.0 * err.abs() as f64).abs() < 1e-4);
    }

    #[test]
    fn residual_recovers_suppressed_updates_over_rounds() {
        let (kinds, kidx) = setup(256);
        let mut c = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx);
        // constant small update everywhere: each round transmits the top 10%,
        // accumulated residue must eventually cover every coordinate.
        let update = vec![0.01f32; 256];
        let mut touched = vec![false; 256];
        for _ in 0..30 {
            let out = c.compress(&update, 3.0, 3.0);
            for &i in &out.sv.idx {
                touched[i as usize] = true;
            }
        }
        let covered = touched.iter().filter(|&&t| t).count();
        assert!(covered > 250, "covered {covered}/256");
    }

    #[test]
    fn fixed_mode_keep_counts_match_ratio() {
        let (kinds, kidx) = setup(1024);
        let mut c = Compressor::new(SparsMode::Fixed(0.25), Encoding::Golomb, kinds, kidx);
        let mut rng = Rng::new(3);
        let update: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let out = c.compress(&update, 1.0, 1.0);
        assert_eq!(out.sv.len(), 256);
    }

    #[test]
    fn encode_range_roundtrip_through_wire() {
        let (kinds, kidx) = setup(512);
        let mut c = Compressor::new(
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds,
            kidx.clone(),
        );
        let mut rng = Rng::new(7);
        let update: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let out = c.compress(&update, 3.0, 2.0);
        let range = 100..300;
        let bytes = c.encode_range(&out, &range).unwrap();
        let dec = wire::decode(&bytes, &range, &kidx).unwrap();
        assert_eq!(dec, out.sv.restrict(&range));
    }
}
