//! EcoLoRA compression pipeline (paper §3.4–3.5): matrix-adaptive top-k
//! sparsification with error feedback, f16 value quantization, and
//! Golomb-coded sparse wire messages.

pub mod adaptive;
pub mod arena;
pub mod golomb;
pub mod quant;
pub mod residual;
pub mod topk;
pub mod wire;

use std::sync::Arc;

pub use adaptive::AdaptiveSparsifier;
pub use arena::{PayloadArena, SparsePool};
pub use residual::Residual;
pub use wire::{Decoder, EncodeScratch, Encoding, KindIndex, SparseVec};

use crate::model::LoraKind;
use crate::util::simd;

/// How updates are sparsified (ablation axis for Tables 3 & 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SparsMode {
    /// Loss- and matrix-adaptive (the paper's scheme).
    Adaptive(AdaptiveSparsifier),
    /// Fixed ratio for both matrices ("w/ Fixed Sparsification").
    Fixed(f64),
    /// No sparsification ("w/o Sparsification"): dense f16 transmission.
    Off,
}

/// Reusable per-compressor working buffers (§Perf, codec hot path).
///
/// Owned by exactly one `Compressor`, which is owned by exactly one
/// thread (a participant worker's client state, or the server's
/// per-client downlink channel) — never shared. Every buffer is cleared
/// (capacity kept) on use, so steady-state rounds run the whole
/// sparsify→quantize→encode pipeline without heap allocation.
#[derive(Default)]
struct Scratch {
    /// U + R (presized to the full vector at construction).
    combined: Vec<f32>,
    /// One family's gathered values (top-k input).
    fam_vals: Vec<f32>,
    /// Quickselect magnitude scratch.
    mags: Vec<f32>,
    /// One family's kept compact indices (top-k output).
    fam_kept: Vec<u32>,
    /// Merged global kept indices, pre f16-zero filter.
    merged: Vec<u32>,
    /// Gathered + f16-quantized kept values (batched kernel output).
    qvals: Vec<f32>,
    /// Wire-encode buffers (compacted blocks + bit writer).
    enc: wire::EncodeScratch,
}

/// One endpoint's compression state (client uplink or server downlink).
pub struct Compressor {
    pub mode: SparsMode,
    pub encoding: Encoding,
    residual: Residual,
    kinds: Arc<Vec<LoraKind>>,
    kidx: Arc<KindIndex>,
    scratch: Scratch,
}

/// Outcome of compressing one update. Reusable across rounds via
/// [`Compressor::compress_into`]: buffers are cleared but keep their
/// capacity, so a warmed `Compressed` costs no allocations to refill.
#[derive(Default)]
pub struct Compressed {
    /// Quantized sparse update (what the receiver will reconstruct).
    pub sv: SparseVec,
    /// Densities used, (k_A, k_B) — for wire headers and accounting.
    pub k: (f64, f64),
    /// Dense fallback (mode == Off): full quantized vector.
    pub dense: Option<Vec<f32>>,
}

impl Compressor {
    pub fn new(
        mode: SparsMode,
        encoding: Encoding,
        kinds: Arc<Vec<LoraKind>>,
        kidx: Arc<KindIndex>,
    ) -> Self {
        let n = kinds.len();
        let scratch = Scratch { combined: vec![0.0; n], ..Scratch::default() };
        Compressor { mode, encoding, residual: Residual::new(n), kinds, kidx, scratch }
    }

    pub fn kind_index(&self) -> &KindIndex {
        &self.kidx
    }

    /// Residual L1 mass (diagnostics; bounded under error feedback).
    pub fn residual_l1(&self) -> f64 {
        self.residual.l1()
    }

    /// Compress `update` given the loss signal (L0, L_{t-1}), writing the
    /// result into `out` (cleared first, capacity kept — the
    /// zero-allocation hot path).
    ///
    /// Applies Eq. 4 per matrix family, Eq. 5 (SC_k over U + R), f16
    /// quantization, and Eq. 6 residual commit. In `Off` mode the update is
    /// transmitted dense (quantized, no residual needed beyond the f16
    /// error, which IS fed back).
    pub fn compress_into(&mut self, update: &[f32], l0: f64, l_prev: f64, out: &mut Compressed) {
        assert_eq!(update.len(), self.kinds.len());
        let combined = &mut self.scratch.combined;
        combined.copy_from_slice(update);
        self.residual.add_into(combined);

        let (k_a, k_b) = match self.mode {
            SparsMode::Adaptive(sp) => sp.k_pair(l0, l_prev),
            SparsMode::Fixed(k) => (k, k),
            SparsMode::Off => (1.0, 1.0),
        };
        out.sv.clear();
        out.k = (k_a, k_b);

        if matches!(self.mode, SparsMode::Off) {
            let dense = out.dense.get_or_insert_with(Vec::new);
            dense.clear();
            simd::quantize_f16_extend(combined, dense);
            out.sv.idx.reserve(dense.len());
            out.sv.idx.extend(0..dense.len() as u32);
            out.sv.vals.extend_from_slice(dense);
            self.residual.commit(combined, &out.sv.idx, dense);
            return;
        }
        out.dense = None;

        // Per-family top-k over compacted coordinates, then merge.
        let merged = &mut self.scratch.merged;
        merged.clear();
        for (kind, k) in [(LoraKind::A, k_a), (LoraKind::B, k_b)] {
            let (fam, _r0) = self.kidx.in_range(kind, &(0..combined.len()));
            let fam_vals = &mut self.scratch.fam_vals;
            fam_vals.clear();
            simd::gather_f32(combined, fam, fam_vals);
            let keep = ((fam_vals.len() as f64) * k).round() as usize;
            topk::topk_indices_into(
                fam_vals,
                keep.min(fam_vals.len()),
                &mut self.scratch.mags,
                &mut self.scratch.fam_kept,
            );
            simd::gather_u32(fam, &self.scratch.fam_kept, merged);
        }
        merged.sort_unstable();
        // Drop entries whose f16 image is exactly zero — transmitting them
        // is pure waste (e.g. FFA-LoRA's frozen-A updates are all zero).
        // NaN survives the filter (NaN != 0.0) and -0.0 is dropped, exactly
        // like the old per-entry scalar quantize.
        let qvals = &mut self.scratch.qvals;
        qvals.clear();
        simd::gather_f32(combined, merged, qvals);
        simd::quantize_f16_inplace(qvals);
        out.sv.idx.reserve(merged.len());
        out.sv.vals.reserve(merged.len());
        for (&i, &q) in merged.iter().zip(qvals.iter()) {
            if q != 0.0 {
                out.sv.idx.push(i);
                out.sv.vals.push(q);
            }
        }
        self.residual.commit(combined, &out.sv.idx, &out.sv.vals);
    }

    /// Compress `update` (allocating convenience form of
    /// [`Compressor::compress_into`]).
    pub fn compress(&mut self, update: &[f32], l0: f64, l_prev: f64) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(update, l0, l_prev, &mut out);
        out
    }

    /// Wire-encode a (possibly range-restricted) compressed update into
    /// `out` (cleared first), reusing the compressor's encode scratch.
    /// The range window of `c.sv` is located with two binary searches —
    /// no restricted `SparseVec` copy is materialized.
    pub fn encode_range_into(
        &mut self,
        c: &Compressed,
        range: &std::ops::Range<usize>,
        out: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        wire::encode_into(&c.sv, range, &self.kidx, c.k, self.encoding, &mut self.scratch.enc, out)
    }

    /// Wire-encode a (possibly range-restricted) compressed update.
    pub fn encode_range(
        &mut self,
        c: &Compressed,
        range: &std::ops::Range<usize>,
    ) -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::new();
        self.encode_range_into(c, range, &mut out)?;
        Ok(out)
    }

    /// Wire-encode into a buffer taken from `arena` (warm, presized from
    /// the arena's high-water mark). The returned payload is owned — it
    /// flows through the `TrainResult` to the transport send — and every
    /// retirement site recycles it back into the same arena, closing the
    /// last per-task allocation (docs/ARCHITECTURE.md §Codec hot path).
    pub fn encode_range_arena(
        &mut self,
        c: &Compressed,
        range: &std::ops::Range<usize>,
        arena: &mut PayloadArena,
    ) -> anyhow::Result<Vec<u8>> {
        let mut out = arena.take();
        self.encode_range_into(c, range, &mut out)?;
        arena.note(out.len());
        Ok(out)
    }
}

/// Bytes for a dense f16 transmission of `n` parameters (baselines and the
/// `Off` mode; 2 bytes per value, negligible framing).
pub fn dense_bytes(n: usize) -> usize {
    2 * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::half::quantize_f16;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Arc<Vec<LoraKind>>, Arc<KindIndex>) {
        // alternate A/B blocks of 32 like the real layout
        let kinds: Vec<LoraKind> = (0..n)
            .map(|i| if (i / 32) % 2 == 0 { LoraKind::A } else { LoraKind::B })
            .collect();
        let kidx = KindIndex::new(&kinds);
        (Arc::new(kinds), Arc::new(kidx))
    }

    #[test]
    fn adaptive_mode_keeps_fewer_b_entries_late_in_training() {
        let (kinds, kidx) = setup(4096);
        let mut c = Compressor::new(
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds.clone(),
            kidx,
        );
        let mut rng = Rng::new(1);
        let update: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        // late in training: loss has dropped a lot
        let out = c.compress(&update, 3.0, 0.5);
        let n_a = out.sv.idx.iter().filter(|&&i| kinds[i as usize] == LoraKind::A).count();
        let n_b = out.sv.len() - n_a;
        assert!(n_b < n_a, "kept A={n_a} B={n_b}");
        assert!(out.k.1 < out.k.0);
    }

    #[test]
    fn off_mode_is_dense_and_f16_exact_feedback() {
        let (kinds, kidx) = setup(128);
        let mut c = Compressor::new(SparsMode::Off, Encoding::Golomb, kinds, kidx);
        let update = vec![0.1f32; 128];
        let out = c.compress(&update, 3.0, 3.0);
        assert_eq!(out.sv.len(), 128);
        assert!(out.dense.is_some());
        // residual carries exactly the f16 quantization error
        let err = 0.1f32 - quantize_f16(0.1);
        assert!((c.residual_l1() - 128.0 * err.abs() as f64).abs() < 1e-4);
    }

    #[test]
    fn residual_recovers_suppressed_updates_over_rounds() {
        let (kinds, kidx) = setup(256);
        let mut c = Compressor::new(SparsMode::Fixed(0.1), Encoding::Golomb, kinds, kidx);
        // constant small update everywhere: each round transmits the top 10%,
        // accumulated residue must eventually cover every coordinate.
        let update = vec![0.01f32; 256];
        let mut touched = vec![false; 256];
        for _ in 0..30 {
            let out = c.compress(&update, 3.0, 3.0);
            for &i in &out.sv.idx {
                touched[i as usize] = true;
            }
        }
        let covered = touched.iter().filter(|&&t| t).count();
        assert!(covered > 250, "covered {covered}/256");
    }

    #[test]
    fn fixed_mode_keep_counts_match_ratio() {
        let (kinds, kidx) = setup(1024);
        let mut c = Compressor::new(SparsMode::Fixed(0.25), Encoding::Golomb, kinds, kidx);
        let mut rng = Rng::new(3);
        let update: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let out = c.compress(&update, 1.0, 1.0);
        assert_eq!(out.sv.len(), 256);
    }

    #[test]
    fn compress_into_reuse_matches_fresh_allocation() {
        // a warmed Compressed + payload buffer reused across rounds must
        // be bit-identical to fresh allocations every round (the residual
        // states evolve in lockstep because the outputs match)
        let (kinds, kidx) = setup(2048);
        let mode = SparsMode::Adaptive(AdaptiveSparsifier::default());
        let mut c1 = Compressor::new(mode, Encoding::Golomb, kinds.clone(), kidx.clone());
        let mut c2 = Compressor::new(mode, Encoding::Golomb, kinds, kidx);
        let mut rng = Rng::new(21);
        let mut out = Compressed::default();
        let mut bytes = Vec::new();
        for round in 0..6 {
            let update: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
            let l_prev = 3.0 - 0.4 * round as f64;
            let fresh = c1.compress(&update, 3.0, l_prev);
            c2.compress_into(&update, 3.0, l_prev, &mut out);
            assert_eq!(out.sv, fresh.sv, "round {round}");
            assert_eq!(out.k, fresh.k, "round {round}");
            let range = 300..1500;
            let fresh_bytes = c1.encode_range(&fresh, &range).unwrap();
            c2.encode_range_into(&out, &range, &mut bytes).unwrap();
            assert_eq!(bytes, fresh_bytes, "round {round}");
        }
    }

    #[test]
    fn off_mode_compress_into_reuses_dense_buffer() {
        let (kinds, kidx) = setup(128);
        let mut c = Compressor::new(SparsMode::Off, Encoding::Golomb, kinds, kidx);
        let mut out = Compressed::default();
        for round in 0..3 {
            let update = vec![0.1f32 * (round + 1) as f32; 128];
            c.compress_into(&update, 3.0, 3.0, &mut out);
            let dense = out.dense.as_ref().expect("off mode is dense");
            assert_eq!(dense.len(), 128);
            assert_eq!(out.sv.len(), 128);
            assert_eq!(out.sv.vals, *dense);
        }
    }

    #[test]
    fn encode_range_roundtrip_through_wire() {
        let (kinds, kidx) = setup(512);
        let mut c = Compressor::new(
            SparsMode::Adaptive(AdaptiveSparsifier::default()),
            Encoding::Golomb,
            kinds,
            kidx.clone(),
        );
        let mut rng = Rng::new(7);
        let update: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let out = c.compress(&update, 3.0, 2.0);
        let range = 100..300;
        let bytes = c.encode_range(&out, &range).unwrap();
        let dec = wire::decode(&bytes, &range, &kidx).unwrap();
        assert_eq!(dec, out.sv.restrict(&range));
    }
}
