//! Sparse wire format (paper §3.5): what actually crosses the network.
//!
//! A message carries the kept entries of one flat-vector range (a
//! round-robin segment on the uplink; the whole vector on the downlink),
//! split into two blocks — LoRA-A entries and LoRA-B entries — because the
//! two families are sparsified at different densities and therefore get
//! different Golomb parameters.
//!
//! Per block: positions are compacted into the (range ∩ kind) coordinate
//! space — in that space the gap distribution is Geometric(k_kind), which
//! is exactly what Golomb/Rice coding is optimal for — and values travel as
//! IEEE f16 (sign included in the 16 bits). The `Fixed` encoding variant
//! (32-bit positions) implements the paper's "w/o Encoding" ablation.
//!
//! Layout (little-endian):
//!   u8  version | u8 encoding | u8 n_blocks
//!   per block: u8 kind | u8 rice_b | u32 count | u32 idx_bytes_len
//!              | idx bytes | count × u16 f16 values
//!
//! §Perf (codec hot path): the encode/decode entry points come in two
//! flavors — the allocating convenience wrappers ([`encode`]/[`decode`])
//! and the scratch-reusing hot-path forms ([`encode_into`] with an
//! [`EncodeScratch`], [`Decoder::decode_into`]) that do no heap
//! allocation once their buffers are warm. Both produce/accept identical
//! bytes. The decoder cross-checks the index block's framed byte length
//! against the bits the gap decoder actually consumed, so a truncated or
//! padded index stream is rejected instead of silently tolerated.

use std::ops::Range;

use anyhow::{anyhow, ensure, Result};

use crate::compress::golomb;
use crate::model::LoraKind;
use crate::util::bitstream::{BitReader, BitWriter};
use crate::util::simd;

const VERSION: u8 = 1;

/// Position encoding on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// Golomb/Rice-coded gaps (the paper's scheme).
    Golomb,
    /// Fixed 32-bit positions ("w/o Encoding" ablation).
    Fixed,
}

/// Precomputed flat positions per LoRA kind (built once per schema).
#[derive(Debug, Clone)]
pub struct KindIndex {
    pos: [Vec<u32>; 2],
}

impl KindIndex {
    pub fn new(kinds: &[LoraKind]) -> Self {
        let mut a = vec![];
        let mut b = vec![];
        for (i, k) in kinds.iter().enumerate() {
            match k {
                LoraKind::A => a.push(i as u32),
                LoraKind::B => b.push(i as u32),
            }
        }
        KindIndex { pos: [a, b] }
    }

    fn family(&self, kind: LoraKind) -> &[u32] {
        match kind {
            LoraKind::A => &self.pos[0],
            LoraKind::B => &self.pos[1],
        }
    }

    /// Sub-slice of this kind's positions falling inside `range`, plus the
    /// rank offset of its first element.
    pub fn in_range(&self, kind: LoraKind, range: &Range<usize>) -> (&[u32], usize) {
        let fam = self.family(kind);
        let lo = fam.partition_point(|&p| (p as usize) < range.start);
        let hi = fam.partition_point(|&p| (p as usize) < range.end);
        (&fam[lo..hi], lo)
    }

    pub fn count(&self, kind: LoraKind) -> usize {
        self.family(kind).len()
    }
}

/// A sparse update: ascending flat indices with values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl SparseVec {
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Empty both columns, keeping their capacity (scratch reuse).
    pub fn clear(&mut self) {
        self.idx.clear();
        self.vals.clear();
    }

    /// Restrict to a flat range (segment extraction, paper §3.3).
    pub fn restrict(&self, range: &Range<usize>) -> SparseVec {
        let lo = self.idx.partition_point(|&i| (i as usize) < range.start);
        let hi = self.idx.partition_point(|&i| (i as usize) < range.end);
        SparseVec { idx: self.idx[lo..hi].to_vec(), vals: self.vals[lo..hi].to_vec() }
    }

    /// Scatter-add into a dense vector.
    pub fn add_to(&self, dense: &mut [f32]) {
        for (&i, &v) in self.idx.iter().zip(&self.vals) {
            dense[i as usize] += v;
        }
    }
}

fn push_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn read_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    let b = buf
        .get(*pos..*pos + 4)
        .ok_or_else(|| anyhow!("wire: truncated u32 at {pos}"))?;
    *pos += 4;
    Ok(u32::from_le_bytes(b.try_into().unwrap()))
}

/// Reusable encode-side buffers: per-block compacted indices and values,
/// plus the bit writer. One per `Compressor` (or per encoding thread);
/// never shared across threads. All buffers are presized with worst-case
/// bounds on use, so a warm scratch never reallocates.
#[derive(Default)]
pub struct EncodeScratch {
    compact: Vec<u32>,
    /// Window positions of the kept entries of the current kind — the
    /// gather map feeding the SIMD value pack.
    wpos: Vec<u32>,
    vals: Vec<f32>,
    bw: BitWriter,
}

impl EncodeScratch {
    pub fn new() -> EncodeScratch {
        EncodeScratch::default()
    }
}

/// Encode a sparse update restricted to `range` into `out` (cleared
/// first), reusing `scratch`. `k_hint` = (k_A, k_B) densities used to
/// pick per-block Rice parameters. Values are quantized to f16 ON ENCODE
/// — the caller must feed the same quantization into its residual so
/// error feedback sees what the receiver saw.
///
/// `sv` may span more than `range`: the range window is located with two
/// binary searches (no restricted copy is materialized) and out-of-range
/// entries never influence the bytes.
pub fn encode_into(
    sv: &SparseVec,
    range: &Range<usize>,
    kidx: &KindIndex,
    k_hint: (f64, f64),
    encoding: Encoding,
    scratch: &mut EncodeScratch,
    out: &mut Vec<u8>,
) -> Result<()> {
    out.clear();
    let lo = sv.idx.partition_point(|&i| (i as usize) < range.start);
    let hi = sv.idx.partition_point(|&i| (i as usize) < range.end);
    let win_idx = &sv.idx[lo..hi];
    let win_vals = &sv.vals[lo..hi];

    out.reserve(3 + 2 * (2 + 4 + 4));
    out.push(VERSION);
    out.push(if encoding == Encoding::Golomb { 0 } else { 1 });
    out.push(2);
    for (kind, k) in [(LoraKind::A, k_hint.0), (LoraKind::B, k_hint.1)] {
        let (fam, _rank0) = kidx.in_range(kind, range);
        // Compact kept indices of this kind into family coordinates; the
        // window positions of the matches become the gather map for the
        // batched SIMD value pack below.
        let compact = &mut scratch.compact;
        let wpos = &mut scratch.wpos;
        compact.clear();
        wpos.clear();
        compact.reserve(win_idx.len());
        wpos.reserve(win_idx.len());
        let mut cursor = 0usize;
        for (w, &i) in win_idx.iter().enumerate() {
            // advance cursor in fam to find i (both ascending)
            while cursor < fam.len() && fam[cursor] < i {
                cursor += 1;
            }
            if cursor < fam.len() && fam[cursor] == i {
                compact.push(cursor as u32);
                wpos.push(w as u32);
                cursor += 1;
            }
        }
        let vals = &mut scratch.vals;
        vals.clear();
        simd::gather_f32(win_vals, wpos, vals);
        let b = golomb::rice_param_for_density(k);
        out.push(match kind {
            LoraKind::A => 0,
            LoraKind::B => 1,
        });
        out.push(b as u8);
        push_u32(out, compact.len() as u32);
        let bw = &mut scratch.bw;
        bw.clear();
        match encoding {
            Encoding::Golomb => {
                bw.reserve_bits(golomb::max_stream_bits(compact.len(), fam.len(), b));
                golomb::encode_indices_into(compact, b, bw);
            }
            Encoding::Fixed => {
                bw.reserve_bits(32 * compact.len() as u64);
                for &c in compact.iter() {
                    bw.write_bits(c as u64, 32);
                }
            }
        }
        push_u32(out, bw.byte_len() as u32);
        out.reserve(bw.byte_len() + 2 * vals.len());
        bw.drain_into(out);
        simd::f32_to_f16le_append(vals, out);
    }
    Ok(())
}

/// Encode a sparse update restricted to `range` (allocating convenience
/// form of [`encode_into`]; identical bytes).
pub fn encode(
    sv: &SparseVec,
    range: &Range<usize>,
    kidx: &KindIndex,
    k_hint: (f64, f64),
    encoding: Encoding,
) -> Result<Vec<u8>> {
    let mut scratch = EncodeScratch::default();
    let mut out = Vec::new();
    encode_into(sv, range, kidx, k_hint, encoding, &mut scratch, &mut out)?;
    Ok(out)
}

/// Reusable decode-side buffers: the compact-index scratch and the
/// per-kind block staging used by the ascending merge. One per
/// participant worker / shard thread; never shared across threads. Warm
/// buffers make [`Decoder::decode_into`] allocation-free in steady state.
#[derive(Default)]
pub struct Decoder {
    compact: Vec<u32>,
    /// Batch-widened f16 values of the current block.
    vals: Vec<f32>,
    blocks: Vec<Vec<(u32, f32)>>,
    cursors: Vec<usize>,
}

impl Decoder {
    pub fn new() -> Decoder {
        Decoder::default()
    }

    /// Decode a message produced by [`encode`] for the same
    /// (range, kidx) into `out` (cleared first, capacity retained).
    ///
    /// Every decode-side buffer is presized from the header's entry
    /// counts, and the index block's framed byte length must match the
    /// bits the gap decoder consumed (`ceil(bits/8) == idx_bytes_len`) —
    /// over- or under-running index streams are rejected.
    pub fn decode_into(
        &mut self,
        bytes: &[u8],
        range: &Range<usize>,
        kidx: &KindIndex,
        out: &mut SparseVec,
    ) -> Result<()> {
        out.clear();
        if bytes.len() < 3 || bytes[0] != VERSION {
            return Err(anyhow!("wire: bad header"));
        }
        let encoding = if bytes[1] == 0 { Encoding::Golomb } else { Encoding::Fixed };
        let n_blocks = bytes[2] as usize;
        let mut pos = 3usize;
        // per-block streams are ascending; a 2-way merge beats re-sorting
        if self.blocks.len() < n_blocks {
            self.blocks.resize_with(n_blocks, Vec::new);
        }
        for block in &mut self.blocks {
            block.clear();
        }
        for bi in 0..n_blocks {
            let kind = match bytes.get(pos) {
                Some(0) => LoraKind::A,
                Some(1) => LoraKind::B,
                other => return Err(anyhow!("wire: bad kind {other:?}")),
            };
            let b = *bytes.get(pos + 1).ok_or_else(|| anyhow!("wire: truncated"))? as u32;
            ensure!(b < 64, "wire: rice parameter {b} out of range");
            pos += 2;
            let count = read_u32(bytes, &mut pos)? as usize;
            let idx_len = read_u32(bytes, &mut pos)? as usize;
            let idx_bytes = bytes
                .get(pos..pos + idx_len)
                .ok_or_else(|| anyhow!("wire: truncated index block"))?;
            pos += idx_len;
            let compact = &mut self.compact;
            let bits_used = match encoding {
                Encoding::Golomb => golomb::decode_indices_into(idx_bytes, count, b, compact)
                    .ok_or_else(|| anyhow!("wire: golomb decode failed"))?,
                Encoding::Fixed => {
                    let mut r = BitReader::new(idx_bytes);
                    compact.clear();
                    compact.reserve(count);
                    for _ in 0..count {
                        let x = r
                            .read_bits(32)
                            .ok_or_else(|| anyhow!("wire: fixed decode failed"))?;
                        compact.push(x as u32);
                    }
                    r.bits_consumed()
                }
            };
            ensure!(
                bits_used.div_ceil(8) == idx_len as u64,
                "wire: index block length mismatch ({bits_used} bits decoded in {idx_len} framed bytes)"
            );
            let (fam, _rank0) = kidx.in_range(kind, range);
            for c in compact.iter() {
                if *c as usize >= fam.len() {
                    return Err(anyhow!("wire: compact index out of family range"));
                }
            }
            // batch-widen the whole value block (count == compact.len(),
            // guaranteed by the index decoders above)
            let vb = bytes
                .get(pos..pos + 2 * count)
                .ok_or_else(|| anyhow!("wire: truncated values"))?;
            pos += 2 * count;
            let vals = &mut self.vals;
            vals.clear();
            simd::f16le_to_f32_append(vb, vals);
            let block = &mut self.blocks[bi];
            block.reserve(count);
            for (&c, &v) in compact.iter().zip(vals.iter()) {
                block.push((fam[c as usize], v));
            }
        }
        // merge the (ascending) per-kind streams
        let blocks = &self.blocks[..n_blocks];
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        out.idx.reserve(total);
        out.vals.reserve(total);
        let cursors = &mut self.cursors;
        cursors.clear();
        cursors.resize(n_blocks, 0);
        for _ in 0..total {
            let mut best: Option<usize> = None;
            for (b, &c) in cursors.iter().enumerate() {
                if c < blocks[b].len()
                    && best.is_none_or(|bb| blocks[b][c].0 < blocks[bb][cursors[bb]].0)
                {
                    best = Some(b);
                }
            }
            let b = best.unwrap();
            let (i, v) = blocks[b][cursors[b]];
            cursors[b] += 1;
            out.idx.push(i);
            out.vals.push(v);
        }
        Ok(())
    }
}

/// Decode a message produced by `encode` for the same (range, kidx)
/// (allocating convenience form of [`Decoder::decode_into`]).
pub fn decode(bytes: &[u8], range: &Range<usize>, kidx: &KindIndex) -> Result<SparseVec> {
    let mut dec = Decoder::new();
    let mut out = SparseVec::default();
    dec.decode_into(bytes, range, kidx, &mut out)?;
    Ok(out)
}

/// Exact on-the-wire size accounting without building the message
/// (netsim fast path): header + per-block overhead + index stream + values.
pub fn encoded_size_estimate(n_a: usize, n_b: usize, k_a: f64, k_b: f64, encoding: Encoding) -> usize {
    let mut bytes = 3usize;
    for (n, k) in [(n_a, k_a), (n_b, k_b)] {
        bytes += 2 + 4 + 4;
        let idx_bits = match encoding {
            Encoding::Golomb => {
                let b = golomb::rice_param_for_density(k);
                (golomb::expected_bits_per_gap(k, b) * n as f64).ceil() as usize
            }
            Encoding::Fixed => 32 * n,
        };
        bytes += idx_bits.div_ceil(8) + 2 * n;
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::half::quantize_f16;
    use crate::util::propcheck::propcheck;

    fn kinds_interleaved(n: usize, block: usize) -> Vec<LoraKind> {
        // mimic the real layout: alternating A-blocks and B-blocks
        (0..n)
            .map(|i| if (i / block) % 2 == 0 { LoraKind::A } else { LoraKind::B })
            .collect()
    }

    #[test]
    fn roundtrip_property_full_range() {
        propcheck(150, |rng| {
            let n = rng.below(5_000) + 32;
            let kinds = kinds_interleaved(n, 16);
            let kidx = KindIndex::new(&kinds);
            let count = rng.below(n / 2) + 1;
            let mut idx: Vec<u32> =
                rng.sample_indices(n, count).iter().map(|&i| i as u32).collect();
            idx.sort_unstable();
            let vals: Vec<f32> = idx.iter().map(|_| quantize_f16(rng.normal() as f32)).collect();
            let sv = SparseVec { idx, vals };
            let range = 0..n;
            let enc = encode(&sv, &range, &kidx, (0.3, 0.2), Encoding::Golomb).unwrap();
            let dec = decode(&enc, &range, &kidx).unwrap();
            assert_eq!(dec, sv);
        });
    }

    #[test]
    fn roundtrip_segment_ranges() {
        propcheck(150, |rng| {
            let n = 4_096;
            let kinds = kinds_interleaved(n, 64);
            let kidx = KindIndex::new(&kinds);
            let lo = rng.below(n - 1);
            let hi = lo + 1 + rng.below(n - lo - 1);
            let range = lo..hi;
            let count = rng.below((hi - lo).min(500)) + 1;
            let mut idx: Vec<u32> = rng
                .sample_indices(hi - lo, count.min(hi - lo))
                .iter()
                .map(|&i| (lo + i) as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = idx.iter().map(|_| quantize_f16(rng.normal() as f32)).collect();
            let sv = SparseVec { idx, vals };
            let enc = encode(&sv, &range, &kidx, (0.5, 0.5), Encoding::Golomb).unwrap();
            let dec = decode(&enc, &range, &kidx).unwrap();
            assert_eq!(dec, sv);
        });
    }

    #[test]
    fn scratch_paths_match_allocating_paths_bytewise() {
        // encode_into with a warm reused scratch must emit the exact
        // bytes of the allocating encode(), and Decoder::decode_into must
        // agree with decode(), across ranges, encodings, and sv windows
        // wider than the range (the no-restrict path).
        let mut scratch = EncodeScratch::default();
        let mut dec = Decoder::new();
        let mut out = Vec::new();
        let mut dsv = SparseVec::default();
        // plain loop (not propcheck): the scratch must stay warm ACROSS
        // cases, which a Fn closure cannot mutably capture
        let mut rng = crate::util::rng::Rng::new(0xC0DEC);
        for case in 0..120 {
            let rng = &mut rng;
            let n = 2_048;
            let kinds = kinds_interleaved(n, 32);
            let kidx = KindIndex::new(&kinds);
            let count = rng.below(n / 2) + 1;
            let mut idx: Vec<u32> =
                rng.sample_indices(n, count).iter().map(|&i| i as u32).collect();
            idx.sort_unstable();
            let vals: Vec<f32> = idx.iter().map(|_| quantize_f16(rng.normal() as f32)).collect();
            let sv = SparseVec { idx, vals };
            let lo = rng.below(n - 1);
            let hi = lo + 1 + rng.below(n - lo - 1);
            let range = lo..hi;
            let encoding = if rng.below(2) == 0 { Encoding::Golomb } else { Encoding::Fixed };
            let k = (rng.range_f64(0.01, 0.95), rng.range_f64(0.01, 0.95));

            // NOTE: sv deliberately spans beyond `range` — encode() used
            // to rely on the caller restricting; encode_into windows
            // internally and must match encode() on the SAME input.
            let reference = encode(&sv, &range, &kidx, k, encoding).unwrap();
            let mut local_scratch = EncodeScratch::default();
            let mut fresh = Vec::new();
            encode_into(&sv, &range, &kidx, k, encoding, &mut local_scratch, &mut fresh).unwrap();
            assert_eq!(fresh, reference, "fresh scratch diverges (case {case})");

            encode_into(&sv, &range, &kidx, k, encoding, &mut scratch, &mut out).unwrap();
            assert_eq!(out, reference, "warm scratch diverges (case {case})");

            let expect = decode(&reference, &range, &kidx).unwrap();
            dec.decode_into(&reference, &range, &kidx, &mut dsv).unwrap();
            assert_eq!(dsv, expect, "warm decoder diverges (case {case})");
            assert_eq!(dsv, sv.restrict(&range), "decode loses the window (case {case})");
        }
    }

    #[test]
    fn index_block_length_mismatch_rejected() {
        // a message whose framed idx_bytes_len disagrees with the bits the
        // gap decoder consumes must be rejected (padded AND truncated)
        let n = 64;
        let kinds = kinds_interleaved(n, 8);
        let kidx = KindIndex::new(&kinds);
        let sv = SparseVec { idx: vec![3, 10, 17], vals: vec![1.0, -1.0, 0.5] };
        let range = 0..n;
        let good = encode(&sv, &range, &kidx, (0.2, 0.2), Encoding::Golomb).unwrap();
        assert!(decode(&good, &range, &kidx).is_ok());

        // block 0 starts at offset 3: kind(1) b(1) count(4) idx_len(4)
        let idx_len_off = 3 + 2 + 4;
        let old_len = u32::from_le_bytes(good[idx_len_off..idx_len_off + 4].try_into().unwrap());
        assert!(old_len > 0, "test needs a nonempty index block");

        // pad: one extra zero byte inside the framed index block
        let mut padded = good.clone();
        padded[idx_len_off..idx_len_off + 4].copy_from_slice(&(old_len + 1).to_le_bytes());
        let data_start = idx_len_off + 4;
        padded.insert(data_start + old_len as usize, 0);
        let err = decode(&padded, &range, &kidx).unwrap_err();
        assert!(format!("{err:#}").contains("length mismatch"), "{err:#}");

        // truncate: drop the frame's last byte (and the byte itself) —
        // the gap decoder runs out of bits mid-stream and must reject
        let mut truncated = good.clone();
        truncated[idx_len_off..idx_len_off + 4].copy_from_slice(&(old_len - 1).to_le_bytes());
        truncated.remove(data_start + old_len as usize - 1);
        assert!(decode(&truncated, &range, &kidx).is_err(), "truncated frame accepted");
    }

    #[test]
    fn fixed_encoding_roundtrips_and_is_larger() {
        let n = 10_000;
        let kinds = kinds_interleaved(n, 100);
        let kidx = KindIndex::new(&kinds);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut idx: Vec<u32> = (0..n as u32).filter(|_| rng.next_f64() < 0.1).collect();
        idx.sort_unstable();
        let vals: Vec<f32> = idx.iter().map(|_| 0.5f32).collect();
        let sv = SparseVec { idx, vals };
        let range = 0..n;
        let g = encode(&sv, &range, &kidx, (0.1, 0.1), Encoding::Golomb).unwrap();
        let f = encode(&sv, &range, &kidx, (0.1, 0.1), Encoding::Fixed).unwrap();
        assert_eq!(decode(&f, &range, &kidx).unwrap(), sv);
        assert!(g.len() < f.len(), "golomb {} vs fixed {}", g.len(), f.len());
    }

    #[test]
    fn size_estimate_close_to_actual() {
        let n = 50_000;
        let kinds = kinds_interleaved(n, 500);
        let kidx = KindIndex::new(&kinds);
        let mut rng = crate::util::rng::Rng::new(9);
        let (ka, kb) = (0.2f64, 0.08f64);
        let mut idx = vec![];
        for (i, k) in kinds.iter().enumerate() {
            let p = if *k == LoraKind::A { ka } else { kb };
            if rng.next_f64() < p {
                idx.push(i as u32);
            }
        }
        let vals: Vec<f32> = idx.iter().map(|_| 1.0f32).collect();
        let n_a = idx.iter().filter(|&&i| kinds[i as usize] == LoraKind::A).count();
        let n_b = idx.len() - n_a;
        let sv = SparseVec { idx, vals };
        let enc = encode(&sv, &(0..n), &kidx, (ka, kb), Encoding::Golomb).unwrap();
        let est = encoded_size_estimate(n_a, n_b, ka, kb, Encoding::Golomb);
        let rel = (enc.len() as f64 - est as f64).abs() / enc.len() as f64;
        assert!(rel < 0.05, "actual {} est {}", enc.len(), est);
    }

    #[test]
    fn values_quantized_to_f16_on_the_wire() {
        let kinds = kinds_interleaved(64, 8);
        let kidx = KindIndex::new(&kinds);
        let sv = SparseVec { idx: vec![3], vals: vec![0.1f32] }; // 0.1 not f16-exact
        let range = 0..64;
        let enc = encode(&sv, &range, &kidx, (0.1, 0.1), Encoding::Golomb).unwrap();
        let dec = decode(&enc, &range, &kidx).unwrap();
        assert_eq!(dec.vals[0], quantize_f16(0.1));
        assert_ne!(dec.vals[0], 0.1f32);
    }

    #[test]
    fn sparse_vec_restrict_and_scatter() {
        let sv = SparseVec { idx: vec![1, 5, 9], vals: vec![1.0, 2.0, 3.0] };
        let r = sv.restrict(&(2..9));
        assert_eq!(r.idx, vec![5]);
        let mut dense = vec![0.0f32; 10];
        sv.add_to(&mut dense);
        assert_eq!(dense[5], 2.0);
        assert_eq!(dense[9], 3.0);
    }

    #[test]
    fn corrupt_messages_rejected() {
        let kinds = kinds_interleaved(64, 8);
        let kidx = KindIndex::new(&kinds);
        let sv = SparseVec { idx: vec![3, 10], vals: vec![1.0, -1.0] };
        let range = 0..64;
        let enc = encode(&sv, &range, &kidx, (0.2, 0.2), Encoding::Golomb).unwrap();
        assert!(decode(&enc[..enc.len() - 1], &range, &kidx).is_err());
        let mut bad = enc.clone();
        bad[0] = 99;
        assert!(decode(&bad, &range, &kidx).is_err());
    }
}
