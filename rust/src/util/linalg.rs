//! Small host-side dense linear algebra substrate (f32). Used by the
//! TF-IDF/KMeans partitioner and by aggregation fast paths; the heavy
//! model math all runs in the compiled XLA artifacts, not here.

/// y += alpha * x (fused axpy — aggregation hot path).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    // Chunked to let LLVM autovectorize without bounds checks.
    let chunks = x.len() / 8 * 8;
    for i in (0..chunks).step_by(8) {
        for j in 0..8 {
            y[i + j] += alpha * x[i + j];
        }
    }
    for i in chunks..x.len() {
        y[i] += alpha * x[i];
    }
}

/// x *= alpha.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x {
        *v *= alpha;
    }
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
}

/// Squared L2 norm.
pub fn norm_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

/// Squared Euclidean distance.
pub fn dist_sq(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum()
}

/// C[m,n] = A[m,k] @ B[k,n], row-major. ikj loop order for locality.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aik = a[i * k + p];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Weighted in-place average: dst = (1-w)*dst + w*src (Eq. 3 mixing).
pub fn mix(w: f32, src: &[f32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = (1.0 - w) * *d + w * *s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_scalar_loop() {
        let x: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let mut y = vec![1.0f32; 37];
        axpy(0.5, &x, &mut y);
        for (i, v) in y.iter().enumerate() {
            assert!((v - (1.0 + 0.5 * i as f32)).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn mix_endpoints() {
        let src = vec![2.0f32; 4];
        let mut dst = vec![0.0f32; 4];
        mix(0.0, &src, &mut dst);
        assert_eq!(dst, vec![0.0; 4]);
        mix(1.0, &src, &mut dst);
        assert_eq!(dst, vec![2.0; 4]);
        mix(0.25, &vec![4.0; 4], &mut dst);
        assert_eq!(dst, vec![2.5; 4]);
    }

    #[test]
    fn dist_and_norm() {
        let x = [3.0f32, 4.0];
        assert!((norm_sq(&x) - 25.0).abs() < 1e-9);
        assert!((dist_sq(&x, &[0.0, 0.0]) - 25.0).abs() < 1e-9);
    }
}
