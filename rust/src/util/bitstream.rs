//! Bit-granular I/O substrate for the Golomb codec and the sparse wire
//! format. MSB-first within each byte; writer pads the tail with zeros.
//!
//! §Perf (codec hot path): both endpoints run word-at-a-time. The writer
//! packs bits into a 64-bit accumulator and flushes whole big-endian words
//! into the byte buffer; the reader pulls unaligned big-endian u64 loads
//! and extracts fields with two shifts. `read_unary` counts leading ones
//! across whole words. The byte stream is IDENTICAL to the historical
//! byte-at-a-time implementation (kept under `#[cfg(test)]` as
//! `reference` and enforced by an ungated equivalence propcheck below):
//! MSB-first within each byte, zero-padded tail.

/// Append-only bit writer (word-at-a-time).
///
/// Invariant between public calls: `nbits < 64`, `buf` holds only whole
/// flushed bytes, and the pending bits sit LEFT-aligned in `acc` (bit 63
/// is the next bit on the wire; the low `64 - nbits` bits are zero).
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, left-aligned (bit 63 leaves first).
    acc: u64,
    /// Number of valid bits in `acc` (0..=63 between calls).
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn flush_word(&mut self) {
        self.buf.extend_from_slice(&self.acc.to_be_bytes());
        self.acc = 0;
        self.nbits = 0;
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.acc |= (bit as u64) << (63 - self.nbits);
        self.nbits += 1;
        if self.nbits == 64 {
            self.flush_word();
        }
    }

    /// Write the low `n` bits of `v`, most-significant first (n <= 64).
    /// High bits of `v` beyond `n` are ignored; `n == 0` writes nothing.
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let free = 64 - self.nbits; // in [1, 64] by the invariant
        if n <= free {
            self.acc |= v << (free - n); // shift in [0, 63]
            self.nbits += n;
            if self.nbits == 64 {
                self.flush_word();
            }
        } else {
            let spill = n - free; // in [1, 63]
            self.acc |= v >> spill;
            self.flush_word();
            self.acc = v << (64 - spill);
            self.nbits = spill;
        }
    }

    /// Unary code: `q` ones followed by a zero (whole-word bulk writes).
    pub fn write_unary(&mut self, q: u64) {
        let mut q = q;
        while q >= 64 {
            self.write_bits(u64::MAX, 64);
            q -= 64;
        }
        // q (< 64) ones then the terminating zero, as one q+1-bit field
        self.write_bits(((1u64 << q) - 1) << 1, q as u32 + 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.nbits as u64
    }

    /// Byte length of the finished stream (`ceil(bit_len / 8)`).
    pub fn byte_len(&self) -> usize {
        self.buf.len() + (self.nbits as usize).div_ceil(8)
    }

    /// Reserve buffer capacity for `bits` more bits (scratch presizing; a
    /// no-op when the writer is already warm).
    pub fn reserve_bits(&mut self, bits: u64) {
        self.buf.reserve((bits as usize).div_ceil(8) + 8);
    }

    /// Reset for reuse, keeping the allocated capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.nbits = 0;
    }

    /// Append the finished stream (zero-padded tail) to `out` and reset
    /// the writer for reuse, keeping its capacity. The scratch-reuse
    /// equivalent of [`BitWriter::into_bytes`].
    pub fn drain_into(&mut self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.buf);
        if self.nbits > 0 {
            let tail = self.acc.to_be_bytes();
            out.extend_from_slice(&tail[..(self.nbits as usize).div_ceil(8)]);
        }
        self.clear();
    }

    /// Finish the stream: whole bytes, tail padded with zeros.
    pub fn into_bytes(mut self) -> Vec<u8> {
        let mut out = std::mem::take(&mut self.buf);
        if self.nbits > 0 {
            let tail = self.acc.to_be_bytes();
            out.extend_from_slice(&tail[..(self.nbits as usize).div_ceil(8)]);
        }
        out
    }

    /// Copy of the finished stream (test/diagnostic convenience; the hot
    /// paths use [`BitWriter::into_bytes`] or [`BitWriter::drain_into`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.clone().into_bytes()
    }
}

/// Sequential bit reader over a byte slice (word-at-a-time).
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.buf.len() {
            return None;
        }
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first (n <= 64). Fast path: one unaligned
    /// big-endian u64 load + two shifts (covers every field the codec
    /// emits — rice remainders <= 24 bits, fixed positions 32 bits).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos + n as u64 > self.buf.len() as u64 * 8 {
            return None;
        }
        if n == 0 {
            return Some(0);
        }
        let byte = (self.pos / 8) as usize;
        let off = (self.pos % 8) as u32;
        if n <= 56 && byte + 8 <= self.buf.len() {
            // off + n <= 7 + 56 < 64: the whole field is inside this word
            let w = u64::from_be_bytes(self.buf[byte..byte + 8].try_into().unwrap());
            self.pos += n as u64;
            return Some((w << off) >> (64 - n));
        }
        // slow path: wider than 56 bits, or within 8 bytes of the end
        let mut out = 0u64;
        let mut need = n;
        while need > 0 {
            let b = self.buf[(self.pos / 8) as usize];
            let o = (self.pos % 8) as u32;
            let avail = 8 - o;
            let take = avail.min(need);
            let chunk = (b >> (avail - take)) & (((1u16 << take) - 1) as u8);
            out = (out << take) | chunk as u64;
            self.pos += take as u64;
            need -= take;
        }
        Some(out)
    }

    /// Read a unary code (count of ones before the terminating zero),
    /// counting leading ones across whole 64-bit words.
    pub fn read_unary(&mut self) -> Option<u64> {
        let mut q = 0u64;
        loop {
            let byte = (self.pos / 8) as usize;
            let off = (self.pos % 8) as u32;
            if off == 0 && byte < self.buf.len() {
                // byte-aligned: bulk-skip whole 0xFF bytes with the blocked
                // SIMD scan (long Golomb unary runs); the run always stops
                // before the terminator byte, which the paths below decode
                let run = crate::util::simd::ones_run_bytes(&self.buf[byte..]);
                if run > 0 {
                    self.pos += 8 * run as u64;
                    q += 8 * run as u64;
                    continue;
                }
            }
            if byte + 8 <= self.buf.len() {
                // valid bits sit in the top 64-off after the shift; the
                // zeros shifted in at the bottom cannot extend a run past
                // `avail`, which the min() guards anyway
                let w = u64::from_be_bytes(self.buf[byte..byte + 8].try_into().unwrap()) << off;
                let avail = 64 - off;
                let ones = w.leading_ones().min(avail);
                if ones < avail {
                    self.pos += ones as u64 + 1; // the run plus its terminator
                    return Some(q + ones as u64);
                }
                self.pos += avail as u64;
                q += avail as u64;
            } else {
                if byte >= self.buf.len() {
                    return None;
                }
                let x = self.buf[byte] << off;
                let avail = 8 - off;
                let ones = x.leading_ones().min(avail);
                if ones < avail {
                    self.pos += ones as u64 + 1;
                    return Some(q + ones as u64);
                }
                self.pos += avail as u64;
                q += avail as u64;
            }
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.pos
    }
}

/// The historical byte-at-a-time implementation, kept verbatim as the
/// equivalence oracle for the word-at-a-time rewrite. The wire format is
/// frozen: whatever these two structs produce/consume IS the format.
#[cfg(test)]
pub(crate) mod reference {
    /// Pre-rewrite `BitWriter` (byte-granular).
    #[derive(Default, Debug, Clone)]
    pub struct RefBitWriter {
        buf: Vec<u8>,
        /// Number of valid bits in the last byte (0 = byte boundary).
        partial: u32,
    }

    impl RefBitWriter {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn write_bit(&mut self, bit: bool) {
            if self.partial == 0 {
                self.buf.push(0);
            }
            if bit {
                let last = self.buf.last_mut().unwrap();
                *last |= 1 << (7 - self.partial);
            }
            self.partial = (self.partial + 1) % 8;
        }

        pub fn write_bits(&mut self, v: u64, n: u32) {
            debug_assert!(n <= 64);
            let mut rem = n;
            while rem > 0 {
                if self.partial == 0 {
                    self.buf.push(0);
                }
                let free = 8 - self.partial;
                let take = free.min(rem);
                let chunk = ((v >> (rem - take)) & ((1u64 << take) - 1)) as u8;
                *self.buf.last_mut().unwrap() |= chunk << (free - take);
                self.partial = (self.partial + take) % 8;
                rem -= take;
            }
        }

        pub fn write_unary(&mut self, q: u64) {
            let mut q = q;
            while q > 0 {
                let take = q.min(32) as u32;
                self.write_bits((1u64 << take) - 1, take);
                q -= take as u64;
            }
            self.write_bit(false);
        }

        pub fn bit_len(&self) -> u64 {
            if self.partial == 0 {
                self.buf.len() as u64 * 8
            } else {
                (self.buf.len() as u64 - 1) * 8 + self.partial as u64
            }
        }

        pub fn into_bytes(self) -> Vec<u8> {
            self.buf
        }
    }

    /// Pre-rewrite `BitReader` (byte-granular).
    pub struct RefBitReader<'a> {
        buf: &'a [u8],
        pos: u64,
    }

    impl<'a> RefBitReader<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Self { buf, pos: 0 }
        }

        pub fn read_bit(&mut self) -> Option<bool> {
            let byte = (self.pos / 8) as usize;
            if byte >= self.buf.len() {
                return None;
            }
            let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
            self.pos += 1;
            Some(bit)
        }

        pub fn read_bits(&mut self, n: u32) -> Option<u64> {
            if self.pos + n as u64 > self.buf.len() as u64 * 8 {
                return None;
            }
            let mut out = 0u64;
            let mut need = n;
            while need > 0 {
                let byte = self.buf[(self.pos / 8) as usize];
                let off = (self.pos % 8) as u32;
                let avail = 8 - off;
                let take = avail.min(need);
                let chunk = (byte >> (avail - take)) & (((1u16 << take) - 1) as u8);
                out = (out << take) | chunk as u64;
                self.pos += take as u64;
                need -= take;
            }
            Some(out)
        }

        pub fn read_unary(&mut self) -> Option<u64> {
            let mut q = 0u64;
            loop {
                let byte_idx = (self.pos / 8) as usize;
                if byte_idx >= self.buf.len() {
                    return None;
                }
                let off = (self.pos % 8) as u32;
                let avail = 8 - off;
                let x = self.buf[byte_idx] << off;
                let ones = x.leading_ones().min(avail);
                if ones < avail {
                    self.pos += ones as u64 + 1;
                    return Some(q + ones as u64);
                }
                self.pos += avail as u64;
                q += avail as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::reference::{RefBitReader, RefBitWriter};
    use super::*;
    use crate::util::propcheck::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn fixed_width_fields_roundtrip() {
        let mut rng = Rng::new(2);
        let mut vals = vec![];
        let mut w = BitWriter::new();
        for _ in 0..500 {
            let n = 1 + (rng.below(63) as u32);
            let v = rng.next_u64() & ((1u64 << n) - 1).max(1);
            let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
            w.write_bits(v, n);
            vals.push((v, n));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (v, n) in vals {
            assert_eq!(r.read_bits(n), Some(v));
        }
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        for q in 0..40u64 {
            w.write_unary(q);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for q in 0..40u64 {
            assert_eq!(r.read_unary(), Some(q));
        }
    }

    #[test]
    fn long_unary_runs_cross_word_boundaries() {
        // runs of 63, 64, 65, 127, 128, 129 ones stress the whole-word
        // leading-ones path on both sides
        let runs = [0u64, 1, 7, 8, 63, 64, 65, 127, 128, 129, 500];
        let mut w = BitWriter::new();
        for &q in &runs {
            w.write_unary(q);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &q in &runs {
            assert_eq!(r.read_unary(), Some(q), "q={q}");
        }
    }

    #[test]
    fn full_width_64_bit_fields_roundtrip() {
        // n == 64 is the shift-overflow hazard: masking with (1<<64)-1 or
        // shifting by 64 is UB; exercise it aligned and misaligned.
        for lead in 0..9u32 {
            let mut w = BitWriter::new();
            w.write_bits(0b1, lead.min(63));
            w.write_bits(u64::MAX, 64);
            w.write_bits(0xDEAD_BEEF_CAFE_F00D, 64);
            w.write_bits(0, 64);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            assert_eq!(r.read_bits(lead.min(63)), Some(if lead == 0 { 0 } else { 1 }));
            assert_eq!(r.read_bits(64), Some(u64::MAX), "lead={lead}");
            assert_eq!(r.read_bits(64), Some(0xDEAD_BEEF_CAFE_F00D), "lead={lead}");
            assert_eq!(r.read_bits(64), Some(0), "lead={lead}");
        }
    }

    #[test]
    fn zero_width_fields_are_noops() {
        let mut w = BitWriter::new();
        w.write_bits(0xFFFF, 0); // must write nothing regardless of v
        w.write_bits(0b101, 3);
        w.write_bits(u64::MAX, 0);
        assert_eq!(w.bit_len(), 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(0), Some(0));
        assert_eq!(r.bits_consumed(), 0);
        assert_eq!(r.read_bits(3), Some(0b101));
    }

    #[test]
    fn reader_exhaustion_returns_none() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), Some(0b101));
        // remaining 5 padding bits then exhaustion
        assert!(r.read_bits(5).is_some());
        assert_eq!(r.read_bit(), None);
        assert_eq!(r.read_bits(1), None);
        // an unterminated unary run must not read past the end
        let all_ones = [0xFFu8; 3];
        let mut r2 = BitReader::new(&all_ones);
        assert_eq!(r2.read_unary(), None);
    }

    #[test]
    fn bit_len_accounts_padding() {
        let mut w = BitWriter::new();
        w.write_bits(0xFF, 8);
        assert_eq!(w.bit_len(), 8);
        assert_eq!(w.byte_len(), 1);
        w.write_bit(true);
        assert_eq!(w.bit_len(), 9);
        assert_eq!(w.byte_len(), 2);
        assert_eq!(w.to_bytes().len(), 2);
        assert_eq!(w.to_bytes(), vec![0xFF, 0x80]);
    }

    #[test]
    fn drain_into_matches_into_bytes_and_resets() {
        let mut w = BitWriter::new();
        w.write_bits(0xABC, 12);
        w.write_unary(9);
        let expected = w.to_bytes();
        let mut out = vec![0x55u8]; // pre-existing content must be kept
        w.drain_into(&mut out);
        assert_eq!(out[0], 0x55);
        assert_eq!(&out[1..], &expected[..]);
        assert_eq!(w.bit_len(), 0);
        // the writer is reusable after draining
        w.write_bits(0b11, 2);
        assert_eq!(w.to_bytes(), vec![0b1100_0000]);
    }

    /// The heart of the format-parity guarantee: on random operation
    /// sequences the word-at-a-time writer emits BYTE-IDENTICAL streams
    /// to the historical byte-at-a-time writer, and both readers agree
    /// on every field read back (ungated).
    #[test]
    fn word_writer_and_reader_match_byte_reference() {
        propcheck(300, |rng| {
            let mut w = BitWriter::new();
            let mut rw = RefBitWriter::new();
            let ops = rng.below(200) + 1;
            let mut script = Vec::with_capacity(ops);
            for _ in 0..ops {
                match rng.below(4) {
                    0 => {
                        let bit = rng.below(2) == 1;
                        w.write_bit(bit);
                        rw.write_bit(bit);
                        script.push((0u8, bit as u64, 1u32));
                    }
                    1 => {
                        let n = 1 + rng.below(64) as u32;
                        let v = rng.next_u64();
                        w.write_bits(v, n);
                        rw.write_bits(v, n);
                        script.push((1, v, n));
                    }
                    2 => {
                        let q = match rng.below(3) {
                            0 => rng.below(8) as u64,
                            1 => 56 + rng.below(20) as u64,
                            _ => rng.below(300) as u64,
                        };
                        w.write_unary(q);
                        rw.write_unary(q);
                        script.push((2, q, 0));
                    }
                    _ => {
                        // n == 64 specifically (the hazard case)
                        let v = rng.next_u64();
                        w.write_bits(v, 64);
                        rw.write_bits(v, 64);
                        script.push((1, v, 64));
                    }
                }
            }
            assert_eq!(w.bit_len(), rw.bit_len());
            let new_bytes = w.into_bytes();
            let ref_bytes = rw.into_bytes();
            assert_eq!(new_bytes, ref_bytes, "writer streams diverge");

            let mut r = BitReader::new(&new_bytes);
            let mut rr = RefBitReader::new(&ref_bytes);
            for (op, v, n) in script {
                match op {
                    0 => {
                        let got = r.read_bit();
                        assert_eq!(got, rr.read_bit());
                        assert_eq!(got, Some(v == 1));
                    }
                    1 => {
                        let got = r.read_bits(n);
                        assert_eq!(got, rr.read_bits(n));
                        let want = if n == 64 { v } else { v & ((1u64 << n) - 1) };
                        assert_eq!(got, Some(want));
                    }
                    _ => {
                        let got = r.read_unary();
                        assert_eq!(got, rr.read_unary());
                        assert_eq!(got, Some(v));
                    }
                }
            }
            assert_eq!(r.bits_consumed(), rr.bits_consumed());
        });
    }

    #[test]
    fn reader_tail_path_matches_reference_near_buffer_end() {
        // fields that straddle the last 8 bytes exercise the slow path;
        // the reference reader is the oracle
        propcheck(200, |rng| {
            let len = 1 + rng.below(24);
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let mut r = BitReader::new(&bytes);
            let mut rr = RefBitReader::new(&bytes);
            loop {
                let n = rng.below(66) as u32; // 0..=65 clamped below
                let n = n.min(64);
                let a = r.read_bits(n);
                let b = rr.read_bits(n);
                assert_eq!(a, b, "n={n} len={len}");
                if a.is_none() {
                    break;
                }
            }
            assert_eq!(r.bits_consumed(), rr.bits_consumed());
        });
    }
}
