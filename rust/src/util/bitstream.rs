//! Bit-granular I/O substrate for the Golomb codec and the sparse wire
//! format. MSB-first within each byte; writer pads the tail with zeros.

/// Append-only bit writer.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 = byte boundary).
    partial: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_bit(&mut self, bit: bool) {
        if self.partial == 0 {
            self.buf.push(0);
        }
        if bit {
            let last = self.buf.last_mut().unwrap();
            *last |= 1 << (7 - self.partial);
        }
        self.partial = (self.partial + 1) % 8;
    }

    /// Write the low `n` bits of `v`, most-significant first (n <= 64).
    /// Byte-granular fast path (§Perf: Golomb codec hot loop).
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        let mut rem = n;
        while rem > 0 {
            if self.partial == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.partial;
            let take = free.min(rem);
            let chunk = ((v >> (rem - take)) & ((1u64 << take) - 1)) as u8;
            *self.buf.last_mut().unwrap() |= chunk << (free - take);
            self.partial = (self.partial + take) % 8;
            rem -= take;
        }
    }

    /// Unary code: `q` ones followed by a zero (bulk-written).
    pub fn write_unary(&mut self, q: u64) {
        let mut q = q;
        while q > 0 {
            let take = q.min(32) as u32;
            self.write_bits((1u64 << take) - 1, take);
            q -= take as u64;
        }
        self.write_bit(false);
    }

    pub fn bit_len(&self) -> u64 {
        if self.partial == 0 {
            self.buf.len() as u64 * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.partial as u64
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Sequential bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.buf.len() {
            return None;
        }
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first, byte-granular fast path.
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        if self.pos + n as u64 > self.buf.len() as u64 * 8 {
            return None;
        }
        let mut out = 0u64;
        let mut need = n;
        while need > 0 {
            let byte = self.buf[(self.pos / 8) as usize];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(need);
            let chunk = (byte >> (avail - take)) & (((1u16 << take) - 1) as u8);
            out = (out << take) | chunk as u64;
            self.pos += take as u64;
            need -= take;
        }
        Some(out)
    }

    /// Read a unary code (count of ones before the terminating zero),
    /// scanning whole bytes via leading-ones counting.
    pub fn read_unary(&mut self) -> Option<u64> {
        let mut q = 0u64;
        loop {
            let byte_idx = (self.pos / 8) as usize;
            if byte_idx >= self.buf.len() {
                return None;
            }
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            // remaining bits of this byte, MSB-aligned in a u8
            let x = self.buf[byte_idx] << off;
            let ones = x.leading_ones().min(avail);
            if ones < avail {
                self.pos += ones as u64 + 1; // the run plus its terminator
                return Some(q + ones as u64);
            }
            self.pos += avail as u64;
            q += avail as u64;
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn fixed_width_fields_roundtrip() {
        let mut rng = Rng::new(2);
        let mut vals = vec![];
        let mut w = BitWriter::new();
        for _ in 0..500 {
            let n = 1 + (rng.below(63) as u32);
            let v = rng.next_u64() & ((1u64 << n) - 1).max(1);
            let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
            w.write_bits(v, n);
            vals.push((v, n));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (v, n) in vals {
            assert_eq!(r.read_bits(n), Some(v));
        }
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        for q in 0..40u64 {
            w.write_unary(q);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for q in 0..40u64 {
            assert_eq!(r.read_unary(), Some(q));
        }
    }

    #[test]
    fn reader_exhaustion_returns_none() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), Some(0b101));
        // remaining 5 padding bits then exhaustion
        assert!(r.read_bits(5).is_some());
        assert_eq!(r.read_bit(), None);
        assert_eq!(r.read_bits(1), None);
    }

    #[test]
    fn bit_len_accounts_padding() {
        let mut w = BitWriter::new();
        w.write_bits(0xFF, 8);
        assert_eq!(w.bit_len(), 8);
        w.write_bit(true);
        assert_eq!(w.bit_len(), 9);
        assert_eq!(w.as_bytes().len(), 2);
    }
}
