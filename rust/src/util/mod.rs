//! Substrate utilities built from scratch for the offline environment:
//! deterministic PRNG, JSON codec, bit I/O, IEEE f16, statistics, host
//! linear algebra, property-check and CLI parsing.

pub mod bitstream;
pub mod cli;
pub mod half;
pub mod json;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod stats;
