//! Substrate utilities built from scratch for the offline environment:
//! deterministic PRNG, JSON codec, bit I/O, IEEE f16, statistics, host
//! linear algebra, property-check and CLI parsing.

pub mod bitstream;
pub mod cli;
pub mod half;
pub mod json;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod simd;
pub mod stats;

/// Poison-tolerant mutex lock: recover the guarded value even if another
/// thread panicked while holding the lock. Cluster participants run on
/// worker threads; one crashed worker must not poison shared engine state
/// for everyone else (the guarded values here are plain caches/counters,
/// valid regardless of where the holder panicked).
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
