//! Minimal JSON substrate (parser + writer) — no serde available offline.
//!
//! Parses the artifact manifests emitted by `python/compile/aot.py` and
//! serializes experiment configs / reports. Supports the full JSON value
//! grammar with `\uXXXX` escapes; numbers are held as f64 (adequate for
//! every manifest field, all of which are < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- construction helpers -------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("short \\u")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    // copy a run of plain UTF-8 bytes
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(v.req("c").req("d").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_through_serializer() {
        let src = r#"{"arr":[1,2.5,"s",null,true],"n":-3,"o":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"preset":"tiny","config":{"vocab":128,"rank":4},
            "lora":{"total":512,"tensors":[
              {"name":"l0.q.at","shape":[32,4],"offset":0,"size":128,"kind":"A"}]}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("lora").req("total").as_usize(), Some(512));
        let t = &v.req("lora").req("tensors").as_arr().unwrap()[0];
        assert_eq!(t.req("kind").as_str(), Some("A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ⊕\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ⊕"));
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
