//! IEEE 754 binary16 conversion substrate (wire format uses sign + FP16
//! magnitudes; no `half` crate available offline).
//!
//! Round-to-nearest-even f32→f16, exact f16→f32, with correct handling of
//! subnormals, infinities and NaN.

/// Convert f32 to IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    // Re-bias: f32 exp-127, f16 exp-15.
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal (or zero) in f16.
        if e < -10 {
            return sign; // underflow to signed zero
        }
        // Implicit leading 1 becomes explicit; shift right by (1 - e).
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let rounded = m + half_ulp - 1 + ((m >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // Normal: round mantissa from 23 to 10 bits (nearest even); a mantissa
    // carry propagates into the exponent by plain addition.
    let rounded = mant + 0x0000_0FFF + ((mant >> 13) & 1);
    let out = ((e as u32) << 10) + (rounded >> 13);
    if out >= 0x7C00 {
        return sign | 0x7C00; // overflow -> inf
    }
    sign | out as u16
}

/// Convert IEEE binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: value = mant * 2^-24. Normalize with s left
            // shifts until bit 10 is set; then value = 1.f * 2^(-14 - s),
            // so the f32 exponent field is 127 - 14 - s = 113 - s.
            let mut m = mant;
            let mut s = 0u32;
            while m & 0x0400 == 0 {
                m <<= 1;
                s += 1;
            }
            sign | ((113 - s) << 23) | ((m & 0x03FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize through the wire format: what the receiver reconstructs.
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(quantize_f16(x), x, "{x}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // overflow -> inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn zero_signs() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x8000), -0.0);
    }

    #[test]
    fn subnormal_roundtrip() {
        // smallest positive f16 subnormal
        let tiny = f16_bits_to_f32(0x0001);
        assert!(tiny > 0.0 && tiny < 1e-7);
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        let mut rng = Rng::new(99);
        for _ in 0..50_000 {
            let x = (rng.normal() as f32) * 10f32.powi(rng.below(7) as i32 - 3);
            if x == 0.0 || x.abs() < 6.2e-5 || x.abs() > 65000.0 {
                continue;
            }
            let q = quantize_f16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 1.0 / 1024.0, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn nan_inputs_collapse_to_canonical_quiet_nan() {
        // every f32 NaN (any payload, either sign) maps to sign | 0x7E00;
        // the SIMD twin is held to the same canonicalization bit-for-bit
        assert_eq!(f32_to_f16_bits(f32::NAN), 0x7E00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x7FC0_1234)), 0x7E00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x7F80_0001)), 0x7E00); // signaling
        assert_eq!(f32_to_f16_bits(f32::from_bits(0xFF80_0001)), 0xFE00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0xFFFF_FFFF)), 0xFE00);
        // and every f16 NaN pattern re-canonicalizes through f32
        for h in [0x7C01u16, 0x7DFF, 0x7FFF, 0xFC01, 0xFFFF] {
            let f = f16_bits_to_f32(h);
            assert!(f.is_nan(), "pattern {h:#06x}");
            assert_eq!(f32_to_f16_bits(f), (h & 0x8000) | 0x7E00, "pattern {h:#06x}");
        }
    }

    #[test]
    fn round_to_nearest_even_halfway_cases() {
        // 1 + 0x1000/2^23 sits exactly between 0x3C00 and 0x3C01: ties to
        // the even code 0x3C00; 1 + 0x3000/2^23 ties up to even 0x3C02
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3F80_1000)), 0x3C00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3F80_3000)), 0x3C02);
        // one ulp past / short of halfway breaks the tie normally
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3F80_1001)), 0x3C01);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3F80_2FFF)), 0x3C01);
    }

    #[test]
    fn overflow_boundary_rounds_to_infinity() {
        // 65520 is halfway between f16::MAX (65504) and 2^16: RNE ties up
        // and out of range -> inf, both signs; just below stays at MAX
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
        assert_eq!(f32_to_f16_bits(-65520.0), 0xFC00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x477F_EFFF)), 0x7BFF);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
    }

    #[test]
    fn subnormal_underflow_boundaries() {
        // 2^-24 is the smallest f16 subnormal; 2^-25 ties between it and
        // zero (even -> zero); anything past 2^-25 rounds up to one ulp;
        // at/below 2^-26 the magnitude collapses to a signed zero
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3380_0000)), 0x0001); // 2^-24
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3300_0000)), 0x0000); // 2^-25
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3300_0001)), 0x0001);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x3280_0000)), 0x0000); // 2^-26
        assert_eq!(f32_to_f16_bits(f32::from_bits(0xB280_0000)), 0x8000); // -2^-26
    }

    #[test]
    fn roundtrip_all_f16_bit_patterns() {
        // f16 -> f32 -> f16 must be the identity on non-NaN patterns.
        for h in 0u16..=0xFFFF {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "pattern {h:#06x}");
        }
    }
}
