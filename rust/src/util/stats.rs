//! Statistics substrate: Gini coefficient (paper §3.4 / Figure 2),
//! summary statistics, and simple online accumulators used by metrics.

/// Gini coefficient of the |values| distribution (0 = perfectly equal,
/// -> 1 = all mass in few entries). The paper uses this to quantify the
/// growing sparsity of LoRA matrices A and B over training.
pub fn gini(values: &[f32]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut mags: Vec<f64> = values.iter().map(|v| v.abs() as f64).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = mags.len() as f64;
    let total: f64 = mags.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    // G = (2 * sum_i i*x_i) / (n * sum x) - (n + 1) / n  with 1-based i.
    let weighted: f64 = mags
        .iter()
        .enumerate()
        .map(|(i, x)| (i as f64 + 1.0) * x)
        .sum();
    (2.0 * weighted) / (n * total) - (n + 1.0) / n
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() as f64 - 1.0)).sqrt()
}

/// Percentile via linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Fraction of entries with |x| <= eps (the paper's sparsity notion).
pub fn sparsity(values: &[f32], eps: f32) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|v| v.abs() <= eps).count() as f64 / values.len() as f64
}

/// Online mean/min/max accumulator for timers and loss curves.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gini_uniform_is_zero() {
        let v = vec![3.0f32; 1000];
        assert!(gini(&v).abs() < 1e-9);
    }

    #[test]
    fn gini_single_spike_near_one() {
        let mut v = vec![0.0f32; 1000];
        v[17] = 5.0;
        assert!(gini(&v) > 0.99);
    }

    #[test]
    fn gini_is_scale_invariant_and_monotone_in_concentration() {
        let mut rng = Rng::new(4);
        let dense: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> = dense
            .iter()
            .enumerate()
            .map(|(i, &x)| if i % 8 == 0 { x * 8.0 } else { x * 0.01 })
            .collect();
        let g1 = gini(&dense);
        let scaled: Vec<f32> = dense.iter().map(|x| x * 100.0).collect();
        assert!((gini(&scaled) - g1).abs() < 1e-9);
        assert!(gini(&sparse) > g1);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sparsity_counts_small_entries() {
        let v = [0.0f32, 1e-9, 0.5, -0.5];
        assert!((sparsity(&v, 1e-6) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn running_accumulator() {
        let mut r = Running::default();
        for x in [2.0, -1.0, 5.0] {
            r.add(x);
        }
        assert_eq!(r.n, 3);
        assert_eq!(r.min, -1.0);
        assert_eq!(r.max, 5.0);
        assert!((r.mean() - 2.0).abs() < 1e-12);
    }
}
