//! Command-line parsing substrate (no clap offline).
//!
//! Grammar: `ecolora <subcommand> [--flag value | --switch] ...`
//! Flags may appear in any order; `--flag=value` is accepted too.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--preset", "small", "--rounds=40", "--verbose"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.get_usize("rounds", 0), 40);
        assert!(a.has("verbose"));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["x", "--beta", "0.5", "--offset=-3"]);
        assert_eq!(a.get_f64("beta", 0.0), 0.5);
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn trailing_switch_is_switch() {
        let a = parse(&["t", "--fast"]);
        assert!(a.has("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["t"]);
        assert_eq!(a.get_or("preset", "tiny"), "tiny");
        assert_eq!(a.get_usize("rounds", 40), 40);
        assert_eq!(a.get_f64("alpha", 0.5), 0.5);
    }

    #[test]
    fn positional_args_collected() {
        let a = parse(&["repro", "one", "--k", "v", "two"]);
        assert_eq!(a.positional, vec!["one", "two"]);
    }
}
