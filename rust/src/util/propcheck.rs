//! Property-based testing substrate (no proptest offline): run a property
//! over many seeded random cases; on failure, report the reproducing seed.
//!
//! ```ignore
//! propcheck(500, |rng| {
//!     let n = rng.below(1000) + 1;
//!     let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
//!     let enc = encode(&v);
//!     assert_eq!(decode(&enc), v);
//! });
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` seeded RNG streams. Panics with the failing
/// seed so the case is reproducible with `propcheck_seed`.
pub fn propcheck<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, prop: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0xEC0_10A ^ seed.wrapping_mul(0x2545F4914F6CDD1D));
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case seed {seed}: {msg}");
        }
    }
}

/// Re-run a single failing case by seed (debugging helper).
pub fn propcheck_seed<F: FnOnce(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(0xEC0_10A ^ seed.wrapping_mul(0x2545F4914F6CDD1D));
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        propcheck(50, |rng| {
            let a = rng.below(100) as i64;
            let b = rng.below(100) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            propcheck(50, |rng| {
                assert!(rng.below(10) < 9, "found the 9");
            })
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("case seed"), "{msg}");
    }
}
