//! Deterministic PRNG substrate (no crates.io `rand` available offline).
//!
//! xoshiro256** seeded via SplitMix64 — fast, high-quality, and fully
//! reproducible across runs: every stochastic component in the system
//! (client sampling, Dirichlet partitioning, corpus generation, LoRA init)
//! derives its stream from an experiment seed.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-client / per-round use).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Raw xoshiro state. The cluster protocol ships per-task batch-RNG
    /// streams so participant results are independent of scheduling order.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream captured with [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here:
        // bias is < 2^-32 for our n, and determinism is what matters.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.next_f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * ones(k)) sample.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    ///
    /// Sparse Fisher–Yates: instead of materializing the full `0..n`
    /// index array (O(n) — ruinous when n is a 10⁵–10⁶ client population
    /// and k is a small cohort), only the displaced positions live in a
    /// hash map. Draw count and draw arguments (`below(n - i)`) are
    /// identical to the dense version, so the output sequence and the
    /// post-call RNG state are bitwise-unchanged — cohort sampling parity
    /// across releases depends on that.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut displaced: std::collections::HashMap<usize, usize> = Default::default();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            out.push(vj);
            // position i is never revisited (future draws start at i+1),
            // so only slot j needs the swapped-in value recorded.
            displaced.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(3);
        for &shape in &[0.5, 1.0, 2.5] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(1.0), "shape {shape} mean {mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        let d = r.dirichlet(0.5, 8);
        assert_eq!(d.len(), 8);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(d.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let s = r.sample_indices(100, 10);
            assert_eq!(s.len(), 10);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 10);
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn sample_indices_matches_dense_fisher_yates() {
        // Reference: the historical O(n) implementation. The sparse
        // rewrite must reproduce both its output and its RNG consumption.
        fn dense(r: &mut Rng, n: usize, k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + r.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
        for (n, k) in [(1, 1), (5, 5), (10, 3), (100, 10), (1000, 32), (4096, 1)] {
            let mut a = Rng::new(1234 + n as u64);
            let mut b = a.clone();
            assert_eq!(a.sample_indices(n, k), dense(&mut b, n, k), "n={n} k={k}");
            assert_eq!(a.state(), b.state(), "RNG consumption must match at n={n} k={k}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }
}
