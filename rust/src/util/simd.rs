//! Runtime-dispatched SIMD kernels for the codec hot path (§Perf,
//! docs/ARCHITECTURE.md §Codec hot path).
//!
//! Every kernel ships as a pair: a portable **scalar reference twin** in
//! [`scalar`] (the semantic ground truth, used on non-x86_64 targets and
//! under `ECOLORA_SIMD=scalar`) and, on x86_64, a vector implementation
//! dispatched at runtime through [`level`]. The vector paths are required
//! to be **bitwise identical** to their twins on every input — including
//! NaN, infinities, subnormals and signed zeros — because the wire format
//! is frozen by golden vectors; ungated propchecks in this module enforce
//! the equivalence.
//!
//! Dispatch policy: the CPU feature level is detected once (cached in an
//! atomic), SSE2 is the x86_64 baseline, AVX2 is used when detected, and
//! `ECOLORA_SIMD=scalar|sse2` clamps the level downward for debugging and
//! for benchmarking the scalar twins. All `unsafe` in the crate's SIMD
//! story is confined to the private `x86` module here: each vector kernel
//! is an `unsafe fn` with a `#[target_feature]` attribute, and the only
//! callers are the dispatch wrappers in this file, which prove the
//! feature via `level()` first.

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set level the dispatcher resolved to, ordered so that
/// `>=` comparisons express "at least this wide".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Portable scalar reference kernels (always available).
    Scalar = 0,
    /// x86_64 SSE2 — the architectural baseline, always present.
    Sse2 = 1,
    /// x86_64 AVX2 — runtime-detected.
    Avx2 = 2,
}

/// Cached dispatch level; `u8::MAX` means "not yet detected".
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

/// Resolved SIMD dispatch level (feature-detected once, then cached).
///
/// `ECOLORA_SIMD=scalar|sse2` clamps the hardware level downward; any
/// other value (or unset) uses the best level the host supports.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        2 => Level::Avx2,
        1 => Level::Sse2,
        0 => Level::Scalar,
        _ => {
            let hw = hw_level();
            let lv = match std::env::var("ECOLORA_SIMD").ok().as_deref() {
                Some("scalar") => Level::Scalar,
                Some("sse2") => hw.min(Level::Sse2),
                _ => hw,
            };
            LEVEL.store(lv as u8, Ordering::Relaxed);
            lv
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn hw_level() -> Level {
    if is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        Level::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn hw_level() -> Level {
    Level::Scalar
}

pub mod scalar {
    //! Scalar reference twins: the semantic ground truth every vector
    //! kernel must match bitwise. Kept callable so benches can measure
    //! scalar-vs-SIMD and tests can compare against dispatch.

    use crate::util::half;

    /// Clear `dst` and fill it with `|src[i]|` (sign bit cleared, so NaN
    /// payloads are preserved exactly like `f32::abs`).
    pub fn abs_into(src: &[f32], dst: &mut Vec<f32>) {
        dst.clear();
        dst.reserve(src.len());
        dst.extend(src.iter().map(|v| v.abs()));
    }

    /// Clear `out` and fill it with the ascending indices where
    /// `|values[i]| >= thresh` (NaN never selects: ordered compare).
    pub fn select_ge_abs(values: &[f32], thresh: f32, out: &mut Vec<u32>) {
        out.clear();
        for (i, v) in values.iter().enumerate() {
            if v.abs() >= thresh {
                out.push(i as u32);
            }
        }
    }

    /// Append `src[idx[j]]` for each index (panics on out-of-bounds).
    pub fn gather_f32(src: &[f32], idx: &[u32], dst: &mut Vec<f32>) {
        dst.reserve(idx.len());
        dst.extend(idx.iter().map(|&i| src[i as usize]));
    }

    /// Append `src[idx[j]]` for each index (panics on out-of-bounds).
    pub fn gather_u32(src: &[u32], idx: &[u32], dst: &mut Vec<u32>) {
        dst.reserve(idx.len());
        dst.extend(idx.iter().map(|&i| src[i as usize]));
    }

    /// Append each value as little-endian binary16 bytes (RNE rounding,
    /// `util::half` semantics: NaN collapses to `sign|0x7E00`).
    pub fn f32_to_f16le_append(src: &[f32], dst: &mut Vec<u8>) {
        dst.reserve(2 * src.len());
        for &v in src {
            dst.extend_from_slice(&half::f32_to_f16_bits(v).to_le_bytes());
        }
    }

    /// Append the exact f32 widening of each little-endian binary16 pair
    /// (a trailing odd byte is ignored).
    pub fn f16le_to_f32_append(bytes: &[u8], dst: &mut Vec<f32>) {
        dst.reserve(bytes.len() / 2);
        for c in bytes.chunks_exact(2) {
            dst.push(half::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])));
        }
    }

    /// Add the f32 widening of each little-endian binary16 pair into
    /// `dst` elementwise (stops at the shorter of the two lengths).
    pub fn f16le_add_to_f32(bytes: &[u8], dst: &mut [f32]) {
        for (c, d) in bytes.chunks_exact(2).zip(dst.iter_mut()) {
            *d += half::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }

    /// Append `quantize_f16(src[i])` — the value the receiver of the
    /// binary16 wire format reconstructs.
    pub fn quantize_f16_extend(src: &[f32], dst: &mut Vec<f32>) {
        dst.reserve(src.len());
        dst.extend(src.iter().map(|&v| half::quantize_f16(v)));
    }

    /// Quantize each element through binary16 in place.
    pub fn quantize_f16_inplace(v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = half::quantize_f16(*x);
        }
    }

    /// Maximum |x| over the slice; NaN entries are ignored (like the
    /// `m.max(x.abs())` fold) and the empty slice yields `0.0`.
    pub fn max_abs(v: &[f32]) -> f32 {
        v.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Length of the leading run of `0xFF` bytes (the Golomb unary-run
    /// fast path in `BitReader::read_unary`).
    pub fn ones_run_bytes(buf: &[u8]) -> usize {
        buf.iter().take_while(|&&b| b == 0xFF).count()
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86_64 vector kernels. Every fn is `unsafe` with a
    //! `#[target_feature]` attribute; the only callers are the dispatch
    //! wrappers in the parent module, which prove the feature through
    //! `level()` first. Vector operations sit directly in the `unsafe fn`
    //! bodies (no nested `unsafe` blocks), so the module compiles
    //! warning-free both before and after std's intrinsics became
    //! safe-callable under `target_feature`.
    //!
    //! Spare-capacity write pattern used throughout: `reserve`, write
    //! through the raw spare pointer, then `set_len` — a panic before
    //! `set_len` (only possible in scalar tails) leaves the Vec length
    //! untouched, so the partial writes are simply discarded.

    use std::arch::x86_64::*;

    const ABS_MASK: i32 = 0x7FFF_FFFFu32 as i32;

    #[target_feature(enable = "sse2")]
    pub unsafe fn abs_into_sse2(src: &[f32], dst: &mut Vec<f32>) {
        dst.clear();
        let n = src.len();
        dst.reserve(n);
        let mask = _mm_castsi128_ps(_mm_set1_epi32(ABS_MASK));
        let out = dst.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let v = _mm_loadu_ps(src.as_ptr().add(i));
            _mm_storeu_ps(out.add(i), _mm_and_ps(v, mask));
            i += 4;
        }
        while i < n {
            *out.add(i) = src[i].abs();
            i += 1;
        }
        dst.set_len(n);
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn select_ge_abs_sse2(values: &[f32], thresh: f32, out: &mut Vec<u32>) {
        out.clear();
        let mask = _mm_castsi128_ps(_mm_set1_epi32(ABS_MASK));
        let t = _mm_set1_ps(thresh);
        let n = values.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let v = _mm_and_ps(_mm_loadu_ps(values.as_ptr().add(i)), mask);
            // cmpge is an ordered compare: NaN lanes yield false, exactly
            // like the scalar `v.abs() >= thresh`
            let mut m = _mm_movemask_ps(_mm_cmpge_ps(v, t)) as u32;
            while m != 0 {
                out.push(i as u32 + m.trailing_zeros());
                m &= m - 1;
            }
            i += 4;
        }
        while i < n {
            if values[i].abs() >= thresh {
                out.push(i as u32);
            }
            i += 1;
        }
    }

    /// Exact f16→f32 widening on 4 lanes; each 32-bit lane of `h` holds
    /// one zero-extended binary16 pattern. Mirrors
    /// `util::half::f16_bits_to_f32` bitwise: subnormals are rebuilt as
    /// `mant * 2^-24` (an exact power-of-two float multiply, so the
    /// result bits are identical to the scalar normalization loop).
    #[target_feature(enable = "sse2")]
    unsafe fn f16_to_f32_4(h: __m128i) -> __m128 {
        let sign = _mm_slli_epi32::<16>(_mm_and_si128(h, _mm_set1_epi32(0x8000)));
        let exp = _mm_and_si128(_mm_srli_epi32::<10>(h), _mm_set1_epi32(0x1F));
        let mant = _mm_and_si128(h, _mm_set1_epi32(0x03FF));
        let mant13 = _mm_slli_epi32::<13>(mant);
        let normal =
            _mm_or_si128(_mm_slli_epi32::<23>(_mm_add_epi32(exp, _mm_set1_epi32(112))), mant13);
        let infnan = _mm_or_si128(_mm_set1_epi32(0x7F80_0000), mant13);
        let scale = _mm_castsi128_ps(_mm_set1_epi32(0x3380_0000)); // 2^-24
        let sub = _mm_castps_si128(_mm_mul_ps(_mm_cvtepi32_ps(mant), scale));
        let is0 = _mm_cmpeq_epi32(exp, _mm_setzero_si128());
        let is31 = _mm_cmpeq_epi32(exp, _mm_set1_epi32(0x1F));
        // SSE2 blend: or(and(mask, b), andnot(mask, a))
        let r = _mm_or_si128(_mm_and_si128(is0, sub), _mm_andnot_si128(is0, normal));
        let r = _mm_or_si128(_mm_and_si128(is31, infnan), _mm_andnot_si128(is31, r));
        _mm_castsi128_ps(_mm_or_si128(sign, r))
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn f16le_to_f32_append_sse2(bytes: &[u8], dst: &mut Vec<f32>) {
        let n = bytes.len() / 2;
        let old = dst.len();
        dst.reserve(n);
        let out = dst.as_mut_ptr().add(old);
        let zero = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 4 <= n {
            let h4 = _mm_loadl_epi64(bytes.as_ptr().add(2 * i) as *const __m128i);
            _mm_storeu_ps(out.add(i), f16_to_f32_4(_mm_unpacklo_epi16(h4, zero)));
            i += 4;
        }
        while i < n {
            let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            *out.add(i) = crate::util::half::f16_bits_to_f32(h);
            i += 1;
        }
        dst.set_len(old + n);
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn f16le_add_to_f32_sse2(bytes: &[u8], dst: &mut [f32]) {
        let n = (bytes.len() / 2).min(dst.len());
        let zero = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 4 <= n {
            let h4 = _mm_loadl_epi64(bytes.as_ptr().add(2 * i) as *const __m128i);
            let v = f16_to_f32_4(_mm_unpacklo_epi16(h4, zero));
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_add_ps(d, v));
            i += 4;
        }
        while i < n {
            let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            dst[i] += crate::util::half::f16_bits_to_f32(h);
            i += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn max_abs_sse2(v: &[f32]) -> f32 {
        let mask = _mm_castsi128_ps(_mm_set1_epi32(ABS_MASK));
        let mut acc = _mm_setzero_ps();
        let n = v.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let a = _mm_and_ps(_mm_loadu_ps(v.as_ptr().add(i)), mask);
            // maxps returns its SECOND operand when either lane is NaN;
            // keeping `acc` second makes NaN inputs transparent, matching
            // the scalar `m.max(x.abs())` fold
            acc = _mm_max_ps(a, acc);
            i += 4;
        }
        let mut lanes = [0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = lanes[0].max(lanes[1]).max(lanes[2].max(lanes[3]));
        while i < n {
            m = m.max(v[i].abs());
            i += 1;
        }
        m
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn ones_run_bytes_sse2(buf: &[u8]) -> usize {
        let n = buf.len();
        let ones = _mm_set1_epi8(-1);
        let mut i = 0usize;
        while i + 16 <= n {
            let v = _mm_loadu_si128(buf.as_ptr().add(i) as *const __m128i);
            let m = _mm_movemask_epi8(_mm_cmpeq_epi8(v, ones)) as u32;
            if m != 0xFFFF {
                return i + (!m).trailing_zeros() as usize;
            }
            i += 16;
        }
        while i < n && buf[i] == 0xFF {
            i += 1;
        }
        i
    }

    /// Exact f16→f32 widening on 8 lanes (256-bit mirror of
    /// [`f16_to_f32_4`], blends via `blendv_epi8` on full-lane masks).
    #[target_feature(enable = "avx2")]
    unsafe fn f16_to_f32_8(h: __m256i) -> __m256 {
        let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(h, _mm256_set1_epi32(0x8000)));
        let exp = _mm256_and_si256(_mm256_srli_epi32::<10>(h), _mm256_set1_epi32(0x1F));
        let mant = _mm256_and_si256(h, _mm256_set1_epi32(0x03FF));
        let mant13 = _mm256_slli_epi32::<13>(mant);
        let normal = _mm256_or_si256(
            _mm256_slli_epi32::<23>(_mm256_add_epi32(exp, _mm256_set1_epi32(112))),
            mant13,
        );
        let infnan = _mm256_or_si256(_mm256_set1_epi32(0x7F80_0000), mant13);
        let scale = _mm256_castsi256_ps(_mm256_set1_epi32(0x3380_0000)); // 2^-24
        let sub = _mm256_castps_si256(_mm256_mul_ps(_mm256_cvtepi32_ps(mant), scale));
        let is0 = _mm256_cmpeq_epi32(exp, _mm256_setzero_si256());
        let is31 = _mm256_cmpeq_epi32(exp, _mm256_set1_epi32(0x1F));
        let r = _mm256_blendv_epi8(normal, sub, is0);
        let r = _mm256_blendv_epi8(r, infnan, is31);
        _mm256_castsi256_ps(_mm256_or_si256(sign, r))
    }

    /// f32→f16 (RNE) on 8 lanes, an integer transliteration of
    /// `util::half::f32_to_f16_bits` (each result lane holds the u16
    /// pattern zero-extended). F16C's `vcvtps2ph` is deliberately NOT
    /// used: it preserves NaN payloads while the scalar twin collapses
    /// every NaN to `sign|0x7E00`, and bitwise parity wins. Variable
    /// shifts past 31 yield 0 in `sllv`/`srlv`, which collapses deep
    /// subnormal underflow (e < -10) to the scalar path's signed zero.
    #[target_feature(enable = "avx2")]
    unsafe fn f32_to_f16_8(x: __m256) -> __m256i {
        let bits = _mm256_castps_si256(x);
        let sign = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(0x8000));
        let exp = _mm256_and_si256(_mm256_srli_epi32::<23>(bits), _mm256_set1_epi32(0xFF));
        let mant = _mm256_and_si256(bits, _mm256_set1_epi32(0x007F_FFFF));
        let one = _mm256_set1_epi32(1);
        let e = _mm256_sub_epi32(exp, _mm256_set1_epi32(112));

        // normal path: round 23→10 mantissa bits to nearest-even; a
        // mantissa carry rides into the exponent by plain addition, and
        // the clamp catches both e >= 31 and rounding overflow
        let rn = _mm256_add_epi32(
            _mm256_add_epi32(mant, _mm256_set1_epi32(0x0FFF)),
            _mm256_and_si256(_mm256_srli_epi32::<13>(mant), one),
        );
        let outn = _mm256_add_epi32(_mm256_slli_epi32::<10>(e), _mm256_srli_epi32::<13>(rn));
        let outn = _mm256_blendv_epi8(
            outn,
            _mm256_set1_epi32(0x7C00),
            _mm256_cmpgt_epi32(outn, _mm256_set1_epi32(0x7BFF)),
        );

        // subnormal path: explicit leading 1, variable-shift RNE
        let m = _mm256_or_si256(mant, _mm256_set1_epi32(0x0080_0000));
        let shift = _mm256_sub_epi32(_mm256_set1_epi32(14), e);
        let half = _mm256_sllv_epi32(one, _mm256_sub_epi32(shift, one));
        let rs = _mm256_sub_epi32(
            _mm256_add_epi32(
                _mm256_add_epi32(m, half),
                _mm256_and_si256(_mm256_srlv_epi32(m, shift), one),
            ),
            one,
        );
        let outs = _mm256_srlv_epi32(rs, shift);

        // inf/NaN path: canonical quiet NaN bit when any mantissa bit set
        let outi = _mm256_or_si256(
            _mm256_set1_epi32(0x7C00),
            _mm256_andnot_si256(
                _mm256_cmpeq_epi32(mant, _mm256_setzero_si256()),
                _mm256_set1_epi32(0x0200),
            ),
        );

        let is_sub = _mm256_cmpgt_epi32(one, e); // e <= 0
        let is_if = _mm256_cmpeq_epi32(exp, _mm256_set1_epi32(0xFF));
        let out = _mm256_blendv_epi8(outn, outs, is_sub);
        let out = _mm256_blendv_epi8(out, outi, is_if);
        _mm256_or_si256(sign, out)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn f32_to_f16le_append_avx2(src: &[f32], dst: &mut Vec<u8>) {
        let n = src.len();
        let old = dst.len();
        dst.reserve(2 * n);
        let out = dst.as_mut_ptr().add(old);
        let mut i = 0usize;
        while i + 8 <= n {
            let h = f32_to_f16_8(_mm256_loadu_ps(src.as_ptr().add(i)));
            // each lane value fits u16, so packus saturation is a no-op;
            // pairing the 128-bit halves keeps element order
            let packed =
                _mm_packus_epi32(_mm256_castsi256_si128(h), _mm256_extracti128_si256::<1>(h));
            _mm_storeu_si128(out.add(2 * i) as *mut __m128i, packed);
            i += 8;
        }
        while i < n {
            let b = crate::util::half::f32_to_f16_bits(src[i]).to_le_bytes();
            *out.add(2 * i) = b[0];
            *out.add(2 * i + 1) = b[1];
            i += 1;
        }
        dst.set_len(old + 2 * n);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn f16le_to_f32_append_avx2(bytes: &[u8], dst: &mut Vec<f32>) {
        let n = bytes.len() / 2;
        let old = dst.len();
        dst.reserve(n);
        let out = dst.as_mut_ptr().add(old);
        let mut i = 0usize;
        while i + 8 <= n {
            let h8 = _mm_loadu_si128(bytes.as_ptr().add(2 * i) as *const __m128i);
            _mm256_storeu_ps(out.add(i), f16_to_f32_8(_mm256_cvtepu16_epi32(h8)));
            i += 8;
        }
        while i < n {
            let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            *out.add(i) = crate::util::half::f16_bits_to_f32(h);
            i += 1;
        }
        dst.set_len(old + n);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn f16le_add_to_f32_avx2(bytes: &[u8], dst: &mut [f32]) {
        let n = (bytes.len() / 2).min(dst.len());
        let mut i = 0usize;
        while i + 8 <= n {
            let h8 = _mm_loadu_si128(bytes.as_ptr().add(2 * i) as *const __m128i);
            let v = f16_to_f32_8(_mm256_cvtepu16_epi32(h8));
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, v));
            i += 8;
        }
        while i < n {
            let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            dst[i] += crate::util::half::f16_bits_to_f32(h);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_f16_extend_avx2(src: &[f32], dst: &mut Vec<f32>) {
        let n = src.len();
        let old = dst.len();
        dst.reserve(n);
        let out = dst.as_mut_ptr().add(old);
        let mut i = 0usize;
        while i + 8 <= n {
            let q = f16_to_f32_8(f32_to_f16_8(_mm256_loadu_ps(src.as_ptr().add(i))));
            _mm256_storeu_ps(out.add(i), q);
            i += 8;
        }
        while i < n {
            *out.add(i) = crate::util::half::quantize_f16(src[i]);
            i += 1;
        }
        dst.set_len(old + n);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_f16_inplace_avx2(v: &mut [f32]) {
        let n = v.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let q = f16_to_f32_8(f32_to_f16_8(_mm256_loadu_ps(v.as_ptr().add(i))));
            _mm256_storeu_ps(v.as_mut_ptr().add(i), q);
            i += 8;
        }
        while i < n {
            v[i] = crate::util::half::quantize_f16(v[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_f32_avx2(src: &[f32], idx: &[u32], dst: &mut Vec<f32>) {
        let n = src.len();
        let k = idx.len();
        let old = dst.len();
        dst.reserve(k);
        let out = dst.as_mut_ptr().add(old);
        let mut i = 0usize;
        if n > 0 && n <= i32::MAX as usize {
            let nm1 = _mm256_set1_epi32((n - 1) as i32);
            while i + 8 <= k {
                let v = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
                // every index in the block must be in-bounds before the
                // hardware gather touches memory; a failing block drops
                // to the scalar tail, which panics cleanly on the
                // offending index (same observable as the scalar twin)
                let inb = _mm256_cmpeq_epi32(_mm256_min_epu32(v, nm1), v);
                if _mm256_movemask_epi8(inb) != -1 {
                    break;
                }
                _mm256_storeu_ps(out.add(i), _mm256_i32gather_ps::<4>(src.as_ptr(), v));
                i += 8;
            }
        }
        let mut w = i;
        while w < k {
            *out.add(w) = src[idx[w] as usize];
            w += 1;
        }
        dst.set_len(old + k);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_u32_avx2(src: &[u32], idx: &[u32], dst: &mut Vec<u32>) {
        let n = src.len();
        let k = idx.len();
        let old = dst.len();
        dst.reserve(k);
        let out = dst.as_mut_ptr().add(old);
        let mut i = 0usize;
        if n > 0 && n <= i32::MAX as usize {
            let nm1 = _mm256_set1_epi32((n - 1) as i32);
            while i + 8 <= k {
                let v = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
                let inb = _mm256_cmpeq_epi32(_mm256_min_epu32(v, nm1), v);
                if _mm256_movemask_epi8(inb) != -1 {
                    break;
                }
                let g = _mm256_i32gather_epi32::<4>(src.as_ptr() as *const i32, v);
                _mm256_storeu_si256(out.add(i) as *mut __m256i, g);
                i += 8;
            }
        }
        let mut w = i;
        while w < k {
            *out.add(w) = src[idx[w] as usize];
            w += 1;
        }
        dst.set_len(old + k);
    }
}

/// Clear `dst` and fill it with `|src[i]|` (dispatched).
pub fn abs_into(src: &[f32], dst: &mut Vec<f32>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Sse2 {
        // SAFETY: `level()` proved SSE2 support on this host.
        return unsafe { x86::abs_into_sse2(src, dst) };
    }
    scalar::abs_into(src, dst);
}

/// Clear `out` and fill it with indices where `|values[i]| >= thresh`
/// (dispatched; NaN values never select).
pub fn select_ge_abs(values: &[f32], thresh: f32, out: &mut Vec<u32>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Sse2 {
        // SAFETY: `level()` proved SSE2 support on this host.
        return unsafe { x86::select_ge_abs_sse2(values, thresh, out) };
    }
    scalar::select_ge_abs(values, thresh, out);
}

/// Append `src[idx[j]]` for each index (dispatched; panics on OOB).
pub fn gather_f32(src: &[f32], idx: &[u32], dst: &mut Vec<f32>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Avx2 {
        // SAFETY: `level()` proved AVX2 support on this host.
        return unsafe { x86::gather_f32_avx2(src, idx, dst) };
    }
    scalar::gather_f32(src, idx, dst);
}

/// Append `src[idx[j]]` for each index (dispatched; panics on OOB).
pub fn gather_u32(src: &[u32], idx: &[u32], dst: &mut Vec<u32>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Avx2 {
        // SAFETY: `level()` proved AVX2 support on this host.
        return unsafe { x86::gather_u32_avx2(src, idx, dst) };
    }
    scalar::gather_u32(src, idx, dst);
}

/// Append each value as little-endian binary16 bytes (dispatched).
pub fn f32_to_f16le_append(src: &[f32], dst: &mut Vec<u8>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Avx2 {
        // SAFETY: `level()` proved AVX2 support on this host.
        return unsafe { x86::f32_to_f16le_append_avx2(src, dst) };
    }
    scalar::f32_to_f16le_append(src, dst);
}

/// Append the f32 widening of each LE binary16 pair (dispatched).
pub fn f16le_to_f32_append(bytes: &[u8], dst: &mut Vec<f32>) {
    #[cfg(target_arch = "x86_64")]
    {
        let lv = level();
        if lv >= Level::Avx2 {
            // SAFETY: `level()` proved AVX2 support on this host.
            return unsafe { x86::f16le_to_f32_append_avx2(bytes, dst) };
        }
        if lv >= Level::Sse2 {
            // SAFETY: `level()` proved SSE2 support on this host.
            return unsafe { x86::f16le_to_f32_append_sse2(bytes, dst) };
        }
    }
    scalar::f16le_to_f32_append(bytes, dst);
}

/// Add the f32 widening of each LE binary16 pair into `dst` elementwise
/// (dispatched; stops at the shorter length).
pub fn f16le_add_to_f32(bytes: &[u8], dst: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        let lv = level();
        if lv >= Level::Avx2 {
            // SAFETY: `level()` proved AVX2 support on this host.
            return unsafe { x86::f16le_add_to_f32_avx2(bytes, dst) };
        }
        if lv >= Level::Sse2 {
            // SAFETY: `level()` proved SSE2 support on this host.
            return unsafe { x86::f16le_add_to_f32_sse2(bytes, dst) };
        }
    }
    scalar::f16le_add_to_f32(bytes, dst);
}

/// Append `quantize_f16(src[i])` — the receiver-visible value of each
/// element after the binary16 wire round-trip (dispatched).
pub fn quantize_f16_extend(src: &[f32], dst: &mut Vec<f32>) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Avx2 {
        // SAFETY: `level()` proved AVX2 support on this host.
        return unsafe { x86::quantize_f16_extend_avx2(src, dst) };
    }
    scalar::quantize_f16_extend(src, dst);
}

/// Quantize each element through binary16 in place (dispatched).
pub fn quantize_f16_inplace(v: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Avx2 {
        // SAFETY: `level()` proved AVX2 support on this host.
        return unsafe { x86::quantize_f16_inplace_avx2(v) };
    }
    scalar::quantize_f16_inplace(v);
}

/// Maximum |x| over the slice, ignoring NaN; `0.0` on empty (dispatched).
pub fn max_abs(v: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Sse2 {
        // SAFETY: `level()` proved SSE2 support on this host.
        return unsafe { x86::max_abs_sse2(v) };
    }
    scalar::max_abs(v)
}

/// Length of the leading run of `0xFF` bytes (dispatched).
pub fn ones_run_bytes(buf: &[u8]) -> usize {
    #[cfg(target_arch = "x86_64")]
    if level() >= Level::Sse2 {
        // SAFETY: `level()` proved SSE2 support on this host.
        return unsafe { x86::ones_run_bytes_sse2(buf) };
    }
    scalar::ones_run_bytes(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::half;
    use crate::util::propcheck::propcheck;
    use crate::util::rng::Rng;

    /// Values that exercise every branch of the float kernels: signed
    /// zeros, infinities, NaN payloads, f16 overflow/underflow edges,
    /// RNE halfway cases, and the smallest subnormals.
    fn specials() -> Vec<f32> {
        vec![
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7FC0_1234), // NaN with payload
            f32::from_bits(0xFF80_0001), // negative signaling-style NaN
            f32::MIN_POSITIVE,
            f32::from_bits(1), // smallest f32 subnormal
            65504.0,           // f16 max
            65520.0,           // rounds to f16 inf
            -65520.0,
            6.1e-5, // near f16 min normal
            5.9e-8, // f16 subnormal range
            1e30,
            -1e30,
            f32::from_bits(0x3F80_1000), // RNE halfway (ties to even)
            f32::from_bits(0x3380_0000), // 2^-24: smallest f16 subnormal
            f32::from_bits(0x3300_0000), // 2^-25: rounds to zero
        ]
    }

    fn mixed_input(rng: &mut Rng, n: usize) -> Vec<f32> {
        let sp = specials();
        (0..n)
            .map(|_| {
                if rng.below(8) == 0 {
                    sp[rng.below(sp.len())]
                } else {
                    (rng.normal() as f32) * 10f32.powi(rng.below(9) as i32 - 4)
                }
            })
            .collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn level_is_cached_and_consistent() {
        let a = level();
        assert_eq!(a, level());
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(a, Level::Scalar);
    }

    #[test]
    fn dispatched_kernels_match_scalar_twins_bitwise() {
        propcheck(60, |rng| {
            let n = rng.below(700) + 1;
            let v = mixed_input(rng, n);

            let (mut a, mut b) = (Vec::new(), Vec::new());
            scalar::abs_into(&v, &mut a);
            abs_into(&v, &mut b);
            assert_bits_eq(&a, &b, "abs_into");

            let thresh = v[rng.below(n)].abs();
            let (mut sa, mut sb) = (Vec::new(), Vec::new());
            scalar::select_ge_abs(&v, thresh, &mut sa);
            select_ge_abs(&v, thresh, &mut sb);
            assert_eq!(sa, sb, "select_ge_abs");

            // gathers: valid indices, appended after a sentinel prefix to
            // pin the append (not clear+fill) contract
            let idx: Vec<u32> = (0..rng.below(300)).map(|_| rng.below(n) as u32).collect();
            let (mut ga, mut gb) = (vec![7.5f32], vec![7.5f32]);
            scalar::gather_f32(&v, &idx, &mut ga);
            gather_f32(&v, &idx, &mut gb);
            assert_bits_eq(&ga, &gb, "gather_f32");
            let u: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
            let (mut ua, mut ub) = (vec![42u32], vec![42u32]);
            scalar::gather_u32(&u, &idx, &mut ua);
            gather_u32(&u, &idx, &mut ub);
            assert_eq!(ua, ub, "gather_u32");

            let (mut ha, mut hb) = (vec![0xEEu8], vec![0xEEu8]);
            scalar::f32_to_f16le_append(&v, &mut ha);
            f32_to_f16le_append(&v, &mut hb);
            assert_eq!(ha, hb, "f32_to_f16le_append");

            // drop the sentinel byte: an odd tail byte must be ignored,
            // so feed an even-length slice here
            let bytes = &ha[1..];
            let (mut fa, mut fb) = (vec![1.25f32], vec![1.25f32]);
            scalar::f16le_to_f32_append(bytes, &mut fa);
            f16le_to_f32_append(bytes, &mut fb);
            assert_bits_eq(&fa, &fb, "f16le_to_f32_append");

            let (mut da, mut db) = (v.clone(), v.clone());
            scalar::f16le_add_to_f32(bytes, &mut da);
            f16le_add_to_f32(bytes, &mut db);
            assert_bits_eq(&da, &db, "f16le_add_to_f32");

            let (mut qa, mut qb) = (vec![3.5f32], vec![3.5f32]);
            scalar::quantize_f16_extend(&v, &mut qa);
            quantize_f16_extend(&v, &mut qb);
            assert_bits_eq(&qa, &qb, "quantize_f16_extend");
            let (mut ia, mut ib) = (v.clone(), v.clone());
            scalar::quantize_f16_inplace(&mut ia);
            quantize_f16_inplace(&mut ib);
            assert_bits_eq(&ia, &ib, "quantize_f16_inplace");

            assert_eq!(scalar::max_abs(&v).to_bits(), max_abs(&v).to_bits(), "max_abs");
        });
    }

    #[test]
    fn f16_to_f32_exhaustive_all_bit_patterns() {
        let mut bytes = Vec::with_capacity(2 * 65536);
        for h in 0u16..=0xFFFF {
            bytes.extend_from_slice(&h.to_le_bytes());
        }
        let mut out = Vec::new();
        f16le_to_f32_append(&bytes, &mut out);
        assert_eq!(out.len(), 65536);
        for h in 0u16..=0xFFFF {
            let want = half::f16_bits_to_f32(h);
            assert_eq!(out[h as usize].to_bits(), want.to_bits(), "pattern {h:#06x}");
        }
    }

    #[test]
    fn f32_to_f16_matches_scalar_on_f16_image_and_random_bits() {
        // the full f16 image (incl. every NaN pattern), the specials,
        // and a dense random sweep of raw f32 bit patterns
        let mut vals: Vec<f32> = (0u16..=0xFFFF).map(half::f16_bits_to_f32).collect();
        vals.extend(specials());
        let mut rng = Rng::new(0x51D);
        for _ in 0..200_000 {
            vals.push(f32::from_bits(rng.below(1 << 32) as u32));
        }
        let mut got = Vec::new();
        f32_to_f16le_append(&vals, &mut got);
        for (i, &v) in vals.iter().enumerate() {
            let want = half::f32_to_f16_bits(v);
            let g = u16::from_le_bytes([got[2 * i], got[2 * i + 1]]);
            assert_eq!(g, want, "elem {i}: input bits {:#010x}", v.to_bits());
        }
    }

    #[test]
    fn select_and_max_ignore_nan_like_scalar() {
        let mut v: Vec<f32> = (0..97).map(|i| (i as f32) - 48.0).collect();
        for i in (0..97).step_by(17) {
            v[i] = f32::NAN;
        }
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        scalar::select_ge_abs(&v, 10.0, &mut sa);
        select_ge_abs(&v, 10.0, &mut sb);
        assert_eq!(sa, sb);
        assert!(sb.iter().all(|&i| !v[i as usize].is_nan()));
        assert_eq!(max_abs(&v).to_bits(), scalar::max_abs(&v).to_bits());

        // NaN threshold selects nothing; all-NaN and empty max to 0.0
        select_ge_abs(&v, f32::NAN, &mut sb);
        assert!(sb.is_empty());
        let nans = vec![f32::NAN; 13];
        assert_eq!(max_abs(&nans).to_bits(), 0.0f32.to_bits());
        assert_eq!(max_abs(&[]).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn ones_run_scan_matches_scalar_across_block_boundaries() {
        for run in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 100] {
            for pad in [0usize, 1, 5, 40] {
                let mut buf = vec![0xFFu8; run];
                buf.push(0x7F);
                buf.resize(buf.len() + pad, 0xA5);
                assert_eq!(ones_run_bytes(&buf), run, "run={run} pad={pad}");
                assert_eq!(scalar::ones_run_bytes(&buf), run);
            }
            // no terminator: the whole buffer is the run
            let buf = vec![0xFFu8; run];
            assert_eq!(ones_run_bytes(&buf), run, "unterminated run={run}");
        }
    }

    #[test]
    #[should_panic]
    fn gather_f32_panics_on_out_of_bounds_index() {
        let src = vec![1.0f32; 32];
        let idx: Vec<u32> = (0..16).map(|i| if i == 11 { 99 } else { i }).collect();
        let mut dst = Vec::new();
        gather_f32(&src, &idx, &mut dst);
    }

    /// On an AVX2 host the dispatcher never exercises the SSE2 kernels,
    /// so test them directly (SSE2 is the x86_64 baseline — always safe).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_kernels_match_scalar_even_when_avx2_dispatches() {
        propcheck(40, |rng| {
            let n = rng.below(500) + 1;
            let v = mixed_input(rng, n);
            let mut bytes = Vec::new();
            scalar::f32_to_f16le_append(&v, &mut bytes);

            let (mut a, mut b) = (Vec::new(), Vec::new());
            scalar::f16le_to_f32_append(&bytes, &mut a);
            // SAFETY: SSE2 is the x86_64 baseline.
            unsafe { x86::f16le_to_f32_append_sse2(&bytes, &mut b) };
            assert_bits_eq(&a, &b, "f16le_to_f32 sse2");

            let (mut da, mut db) = (v.clone(), v.clone());
            scalar::f16le_add_to_f32(&bytes, &mut da);
            // SAFETY: SSE2 is the x86_64 baseline.
            unsafe { x86::f16le_add_to_f32_sse2(&bytes, &mut db) };
            assert_bits_eq(&da, &db, "f16le_add sse2");

            let t = v[rng.below(n)].abs();
            let (mut sa, mut sb) = (Vec::new(), Vec::new());
            scalar::select_ge_abs(&v, t, &mut sa);
            // SAFETY: SSE2 is the x86_64 baseline.
            unsafe { x86::select_ge_abs_sse2(&v, t, &mut sb) };
            assert_eq!(sa, sb, "select_ge_abs sse2");

            let (mut aa, mut ab) = (Vec::new(), Vec::new());
            scalar::abs_into(&v, &mut aa);
            // SAFETY: SSE2 is the x86_64 baseline.
            unsafe { x86::abs_into_sse2(&v, &mut ab) };
            assert_bits_eq(&aa, &ab, "abs sse2");

            // SAFETY: SSE2 is the x86_64 baseline.
            let m = unsafe { x86::max_abs_sse2(&v) };
            assert_eq!(scalar::max_abs(&v).to_bits(), m.to_bits(), "max_abs sse2");
        });
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_f16_widening_exhaustive() {
        let mut bytes = Vec::with_capacity(2 * 65536);
        for h in 0u16..=0xFFFF {
            bytes.extend_from_slice(&h.to_le_bytes());
        }
        let mut out = Vec::new();
        // SAFETY: SSE2 is the x86_64 baseline.
        unsafe { x86::f16le_to_f32_append_sse2(&bytes, &mut out) };
        for h in 0u16..=0xFFFF {
            let want = half::f16_bits_to_f32(h);
            assert_eq!(out[h as usize].to_bits(), want.to_bits(), "pattern {h:#06x}");
        }
    }
}
