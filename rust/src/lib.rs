//! # EcoLoRA
//!
//! Reproduction of *EcoLoRA: Communication-Efficient Federated Fine-Tuning
//! of Large Language Models* (EMNLP 2025) as a three-layer rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning system: the
//!   paper's round-robin segment sharing, adaptive sparsification with
//!   error feedback, Golomb-coded sparse wire format, the FedIT / FLoRA /
//!   FFA-LoRA baselines, a discrete-event network simulator, non-IID data
//!   partitioners, the evaluation + metrics stack, and the `cluster`
//!   subsystem — an actor-style coordinator/participant deployment of the
//!   protocol over pluggable transports (in-memory channels or framed
//!   TCP) that reproduces the monolithic `fed::FedRunner` bitwise (see
//!   docs/ARCHITECTURE.md).
//! * **Layer 2** — `python/compile/model.py`: JAX transformer with LoRA,
//!   AOT-lowered to HLO text once by `make artifacts`.
//! * **Layer 1** — `python/compile/kernels/`: the fused LoRA-linear Pallas
//!   kernel the model calls on its hot path.
//!
//! Python never runs at request time: the coordinator executes the compiled
//! artifacts through PJRT (`runtime`).

// Everything in this crate reaches PJRT through `crate::xla`: a re-export
// of the native bindings when the `pjrt` feature is on, or the compile-time
// stub when it is off. Import `crate::xla::…`, never the extern crate.
// The `pjrt` feature expects you to add the xla-rs dependency by hand —
// see the feature comment in Cargo.toml.
#[cfg(feature = "pjrt")]
pub mod xla {
    //! Native PJRT bindings (`xla-rs`); twin of `xla_stub.rs`.
    pub use ::xla::*;
}
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;

pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod compress;
pub mod config;
pub mod data;
pub mod eval;
pub mod fed;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod runtime;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
