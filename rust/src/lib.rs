//! # EcoLoRA
//!
//! Reproduction of *EcoLoRA: Communication-Efficient Federated Fine-Tuning
//! of Large Language Models* (EMNLP 2025) as a three-layer rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator: the
//!   paper's round-robin segment sharing, adaptive sparsification with
//!   error feedback, Golomb-coded sparse wire format, the FedIT / FLoRA /
//!   FFA-LoRA baselines, a discrete-event network simulator, non-IID data
//!   partitioners, and the evaluation + metrics stack.
//! * **Layer 2** — `python/compile/model.py`: JAX transformer with LoRA,
//!   AOT-lowered to HLO text once by `make artifacts`.
//! * **Layer 1** — `python/compile/kernels/`: the fused LoRA-linear Pallas
//!   kernel the model calls on its hot path.
//!
//! Python never runs at request time: the coordinator executes the compiled
//! artifacts through PJRT (`runtime`).

pub mod baselines;
pub mod bench;
pub mod compress;
pub mod config;
pub mod data;
pub mod eval;
pub mod fed;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod runtime;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
