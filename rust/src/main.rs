//! `ecolora` CLI — leader entrypoint. Subcommands are implemented in
//! `config::commands`; see `ecolora help`.

fn main() {
    if let Err(e) = ecolora::config::commands::dispatch() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
