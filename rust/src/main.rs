//! `ecolora` CLI — leader entrypoint. Subcommands are implemented in
//! `config::commands`; see `ecolora help`.
//!
//! Exit codes: 0 success, 1 generic failure, 3 the coordinator refused
//! this process's join handshake (`ecolora worker` or `ecolora shard`
//! against a `serve` peer). A 3 for a bad token, config mismatch, full
//! cluster or malformed join is deterministic — deployment scripts must
//! not blindly retry it; a 3 naming `duplicate_worker` means the rejoin
//! race outlived the worker's own `--reconnect` budget and is worth one
//! supervised restart after the coordinator logs the drop (see
//! docs/PROTOCOL.md §5a). `ecolora shard` processes never retry a 3:
//! a shard slot never reopens within a run (docs/PROTOCOL.md §9).

fn main() {
    if let Err(e) = ecolora::config::commands::dispatch() {
        eprintln!("error: {e:#}");
        let code = if e.downcast_ref::<ecolora::cluster::Rejected>().is_some() { 3 } else { 1 };
        std::process::exit(code);
    }
}
