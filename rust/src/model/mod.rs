//! Model parameter schema, loaded from the artifact manifest emitted by
//! `python/compile/aot.py`.
//!
//! The schema is the single source of truth the coordinator shares with the
//! compiled HLO: flat-vector sizes, per-tensor offsets/shapes, LoRA A/B
//! kinds (driving matrix-adaptive sparsification, paper §3.4), and the
//! round-robin segment partition of the flat LoRA vector (paper §3.3).

use std::ops::Range;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Which LoRA factor a tensor belongs to (paper: B grows sparser than A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoraKind {
    A,
    B,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: String,
    /// `None` for base tensors.
    pub kind: Option<LoraKind>,
    pub layer: i64,
}

/// One AOT-compiled entry point (train / eval / pretrain / merge / dpo).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<(String, Vec<usize>, String)>,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub rank: usize,
    pub lora_alpha: f64,
    pub lora_scale: f64,
    pub batch: usize,
    pub eval_batch: usize,
}

/// Parsed manifest for one preset.
#[derive(Debug, Clone)]
pub struct Schema {
    pub preset: String,
    pub init_std: f64,
    pub config: ModelConfig,
    pub base_total: usize,
    pub lora_total: usize,
    pub base_tensors: Vec<TensorSpec>,
    pub lora_tensors: Vec<TensorSpec>,
    pub artifacts: std::collections::BTreeMap<String, ArtifactSpec>,
}

fn tensor_from_json(t: &Json, lora: bool) -> Result<TensorSpec> {
    let kind = if lora {
        match t.req("kind").as_str() {
            Some("A") => Some(LoraKind::A),
            Some("B") => Some(LoraKind::B),
            other => return Err(anyhow!("bad lora kind {other:?}")),
        }
    } else {
        None
    };
    Ok(TensorSpec {
        name: t.req("name").as_str().unwrap_or_default().to_string(),
        shape: t
            .req("shape")
            .as_arr()
            .unwrap_or_default()
            .iter()
            .filter_map(|x| x.as_usize())
            .collect(),
        offset: t.req("offset").as_usize().unwrap_or(0),
        size: t.req("size").as_usize().unwrap_or(0),
        init: t.req("init").as_str().unwrap_or("zeros").to_string(),
        kind,
        layer: t.get("layer").and_then(|x| x.as_f64()).unwrap_or(-1.0) as i64,
    })
}

fn args_from_json(a: &Json) -> Vec<(String, Vec<usize>, String)> {
    a.as_arr()
        .unwrap_or_default()
        .iter()
        .map(|x| {
            (
                x.req("name").as_str().unwrap_or_default().to_string(),
                x.req("shape")
                    .as_arr()
                    .unwrap_or_default()
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                x.req("dtype").as_str().unwrap_or("f32").to_string(),
            )
        })
        .collect()
}

impl Schema {
    /// Load `<dir>/<preset>.manifest.json`.
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Schema> {
        let path = artifacts_dir.join(format!("{preset}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let c = v.req("config");
        let config = ModelConfig {
            vocab: c.req("vocab").as_usize().unwrap(),
            d_model: c.req("d_model").as_usize().unwrap(),
            n_layers: c.req("n_layers").as_usize().unwrap(),
            n_heads: c.req("n_heads").as_usize().unwrap(),
            d_ff: c.req("d_ff").as_usize().unwrap(),
            seq_len: c.req("seq_len").as_usize().unwrap(),
            rank: c.req("rank").as_usize().unwrap(),
            lora_alpha: c.req("lora_alpha").as_f64().unwrap(),
            lora_scale: c.req("lora_scale").as_f64().unwrap(),
            batch: c.req("batch").as_usize().unwrap(),
            eval_batch: c.req("eval_batch").as_usize().unwrap(),
        };

        let base_tensors: Vec<TensorSpec> = v
            .req("base")
            .req("tensors")
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(|t| tensor_from_json(t, false))
            .collect::<Result<_>>()?;
        let lora_tensors: Vec<TensorSpec> = v
            .req("lora")
            .req("tensors")
            .as_arr()
            .unwrap_or_default()
            .iter()
            .map(|t| tensor_from_json(t, true))
            .collect::<Result<_>>()?;

        let mut artifacts = std::collections::BTreeMap::new();
        if let Json::Obj(m) = v.req("artifacts") {
            for (tag, a) in m {
                artifacts.insert(
                    tag.clone(),
                    ArtifactSpec {
                        file: a.req("file").as_str().unwrap_or_default().to_string(),
                        args: args_from_json(a.req("args")),
                        outputs: args_from_json(a.req("outputs")),
                    },
                );
            }
        }

        let schema = Schema {
            preset: v.req("preset").as_str().unwrap_or_default().to_string(),
            init_std: v.req("init_std").as_f64().unwrap_or(0.02),
            config,
            base_total: v.req("base").req("total").as_usize().unwrap(),
            lora_total: v.req("lora").req("total").as_usize().unwrap(),
            base_tensors,
            lora_tensors,
            artifacts,
        };
        schema.validate()?;
        Ok(schema)
    }

    /// Manifest-free schema for the session-free scale path
    /// (`--preset synthetic`): no artifact files on disk and no compiled
    /// entry points, so a `WorldSeed` can be built without PJRT and a
    /// single host can simulate 10⁴–10⁶ clients through the mux plane.
    /// Shapes are transformer-plausible and `lora_total` = 4096 is large
    /// enough to exercise segment round-robin, adaptive top-k, and the
    /// Golomb wire codec realistically.
    pub fn synthetic() -> Schema {
        let (d, r) = (64usize, 8usize);
        let mut lora_tensors = Vec::new();
        let mut off = 0;
        for layer in 0..2i64 {
            for (proj, kind) in [("q", LoraKind::A), ("q", LoraKind::B),
                                 ("v", LoraKind::A), ("v", LoraKind::B)] {
                let (suffix, shape, init) = match kind {
                    LoraKind::A => ("a", vec![d, r], "normal"),
                    LoraKind::B => ("b", vec![r, d], "zeros"),
                };
                let size = shape.iter().product();
                lora_tensors.push(TensorSpec {
                    name: format!("layer{layer}.{proj}_{suffix}"),
                    shape,
                    offset: off,
                    size,
                    init: init.into(),
                    kind: Some(kind),
                    layer,
                });
                off += size;
            }
        }
        let schema = Schema {
            preset: "synthetic".into(),
            init_std: 0.02,
            config: ModelConfig {
                vocab: 64, d_model: d, n_layers: 2, n_heads: 4, d_ff: 128,
                seq_len: 16, rank: r, lora_alpha: 16.0, lora_scale: 2.0,
                batch: 4, eval_batch: 8,
            },
            base_total: 256,
            lora_total: off,
            base_tensors: vec![TensorSpec {
                name: "embed".into(), shape: vec![256], offset: 0, size: 256,
                init: "normal".into(), kind: None, layer: -1,
            }],
            lora_tensors,
            artifacts: Default::default(),
        };
        debug_assert!(schema.validate().is_ok());
        schema
    }

    /// Layout invariants: contiguity and totals.
    pub fn validate(&self) -> Result<()> {
        for (tensors, total, fam) in [
            (&self.base_tensors, self.base_total, "base"),
            (&self.lora_tensors, self.lora_total, "lora"),
        ] {
            let mut off = 0;
            for t in tensors.iter() {
                if t.offset != off {
                    return Err(anyhow!("{fam} tensor {} offset {} != {}", t.name, t.offset, off));
                }
                let numel: usize = t.shape.iter().product();
                if numel != t.size {
                    return Err(anyhow!("{fam} tensor {} size mismatch", t.name));
                }
                off += t.size;
            }
            if off != total {
                return Err(anyhow!("{fam} total {} != sum {}", total, off));
            }
        }
        Ok(())
    }

    // ---- initialization --------------------------------------------------

    fn init_flat(&self, tensors: &[TensorSpec], total: usize, rng: &mut Rng) -> Vec<f32> {
        let mut flat = vec![0.0f32; total];
        let std = self.init_std as f32;
        for t in tensors {
            match t.init.as_str() {
                "normal" => {
                    for v in &mut flat[t.offset..t.offset + t.size] {
                        *v = std * rng.normal() as f32;
                    }
                }
                "ones" => flat[t.offset..t.offset + t.size].fill(1.0),
                _ => {} // zeros
            }
        }
        flat
    }

    /// Random base initialization (before in-repo pretraining).
    pub fn init_base(&self, rng: &mut Rng) -> Vec<f32> {
        self.init_flat(&self.base_tensors, self.base_total, rng)
    }

    /// Standard LoRA init: A ~ N(0, std), B = 0 (adapter starts as identity).
    pub fn init_lora(&self, rng: &mut Rng) -> Vec<f32> {
        self.init_flat(&self.lora_tensors, self.lora_total, rng)
    }

    // ---- masks & kinds -----------------------------------------------------

    /// Per-entry LoRA kind lookup table (A=false, B=true packing avoided
    /// for clarity; one byte per entry, built once).
    pub fn kind_map(&self) -> Vec<LoraKind> {
        let mut map = vec![LoraKind::A; self.lora_total];
        for t in &self.lora_tensors {
            if t.kind == Some(LoraKind::B) {
                map[t.offset..t.offset + t.size].fill(LoraKind::B);
            }
        }
        map
    }

    /// grad mask: all ones (FedIT / FLoRA — train both factors).
    pub fn mask_all(&self) -> Vec<f32> {
        vec![1.0; self.lora_total]
    }

    /// grad mask freezing A (FFA-LoRA trains B only).
    pub fn mask_b_only(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.lora_total];
        for t in &self.lora_tensors {
            if t.kind == Some(LoraKind::B) {
                m[t.offset..t.offset + t.size].fill(1.0);
            }
        }
        m
    }

    /// Count of trainable params under a mask (for comm accounting).
    pub fn mask_count(mask: &[f32]) -> usize {
        mask.iter().filter(|&&x| x != 0.0).count()
    }
}

/// Partition `total` flat entries into `n_s` near-equal contiguous segments
/// (paper §3.3: "equally sized segments"; remainder spread over the first
/// `total % n_s` segments so sizes differ by at most 1).
pub fn segment_ranges(total: usize, n_s: usize) -> Vec<Range<usize>> {
    assert!(n_s >= 1 && n_s <= total.max(1));
    let base = total / n_s;
    let rem = total % n_s;
    let mut out = Vec::with_capacity(n_s);
    let mut off = 0;
    for s in 0..n_s {
        let len = base + usize::from(s < rem);
        out.push(off..off + len);
        off += len;
    }
    debug_assert_eq!(off, total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn segment_ranges_cover_exactly() {
        propcheck(200, |rng| {
            let total = rng.below(10_000) + 1;
            let n_s = rng.below(total.min(16)) + 1;
            let segs = segment_ranges(total, n_s);
            assert_eq!(segs.len(), n_s);
            let mut covered = 0;
            let mut prev_end = 0;
            for r in &segs {
                assert_eq!(r.start, prev_end);
                prev_end = r.end;
                covered += r.len();
            }
            assert_eq!(covered, total);
            let min = segs.iter().map(|r| r.len()).min().unwrap();
            let max = segs.iter().map(|r| r.len()).max().unwrap();
            assert!(max - min <= 1, "near-equal sizes");
        });
    }

    fn fake_schema() -> Schema {
        // hand-built two-tensor schema (A then B)
        Schema {
            preset: "fake".into(),
            init_std: 0.02,
            config: ModelConfig {
                vocab: 16, d_model: 4, n_layers: 1, n_heads: 1, d_ff: 8,
                seq_len: 8, rank: 2, lora_alpha: 4.0, lora_scale: 2.0,
                batch: 2, eval_batch: 4,
            },
            base_total: 10,
            lora_total: 16,
            base_tensors: vec![TensorSpec {
                name: "w".into(), shape: vec![10], offset: 0, size: 10,
                init: "normal".into(), kind: None, layer: -1,
            }],
            lora_tensors: vec![
                TensorSpec { name: "a".into(), shape: vec![4, 2], offset: 0, size: 8,
                             init: "normal".into(), kind: Some(LoraKind::A), layer: 0 },
                TensorSpec { name: "b".into(), shape: vec![2, 4], offset: 8, size: 8,
                             init: "zeros".into(), kind: Some(LoraKind::B), layer: 0 },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn synthetic_schema_validates_with_mixed_kinds() {
        let s = Schema::synthetic();
        s.validate().unwrap();
        assert_eq!(s.lora_total, 4096);
        assert!(s.artifacts.is_empty(), "synthetic has no compiled entry points");
        let km = s.kind_map();
        assert!(km.iter().any(|&k| k == LoraKind::A));
        assert!(km.iter().any(|&k| k == LoraKind::B));
        // LoRA identity init: A ~ N(0, std), B = 0
        let flat = s.init_lora(&mut Rng::new(0));
        assert!(flat.iter().any(|&x| x != 0.0));
        for (t, k) in s.lora_tensors.iter().zip([LoraKind::A, LoraKind::B].iter().cycle()) {
            assert_eq!(t.kind, Some(*k));
        }
    }

    #[test]
    fn validate_catches_gaps() {
        let mut s = fake_schema();
        s.validate().unwrap();
        s.lora_tensors[1].offset = 9;
        assert!(s.validate().is_err());
    }

    #[test]
    fn lora_init_is_a_normal_b_zero() {
        let s = fake_schema();
        let mut rng = Rng::new(0);
        let flat = s.init_lora(&mut rng);
        assert!(flat[..8].iter().any(|&x| x != 0.0));
        assert!(flat[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn masks_and_kind_map() {
        let s = fake_schema();
        let m = s.mask_b_only();
        assert_eq!(Schema::mask_count(&m), 8);
        assert!(m[..8].iter().all(|&x| x == 0.0));
        let km = s.kind_map();
        assert!(km[..8].iter().all(|&k| k == LoraKind::A));
        assert!(km[8..].iter().all(|&k| k == LoraKind::B));
        assert_eq!(Schema::mask_count(&s.mask_all()), 16);
    }
}
