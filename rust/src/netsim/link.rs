//! Access-link model: store-and-forward FIFO serialization with one-way
//! propagation latency. Each client owns an asymmetric (UL, DL) link pair;
//! flows on the same direction of the same link queue behind each other.

/// Static link parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub ul_mbps: f64,
    pub dl_mbps: f64,
    pub latency_s: f64,
}

impl LinkSpec {
    /// Pure serialization time of `bytes` at `mbps` (no queueing/latency).
    pub fn serialize_s(bytes: usize, mbps: f64) -> f64 {
        (bytes as f64 * 8.0) / (mbps * 1e6)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Up,
    Down,
}

/// One directed link with FIFO occupancy.
#[derive(Debug, Clone)]
pub struct Link {
    pub mbps: f64,
    pub latency_s: f64,
    busy_until: f64,
}

impl Link {
    pub fn new(mbps: f64, latency_s: f64) -> Self {
        Link { mbps, latency_s, busy_until: 0.0 }
    }

    /// Enqueue a flow of `bytes` arriving at the sender at `start`;
    /// returns the receiver-side completion time. Transmission begins when
    /// the link frees up (FIFO), then takes serialization + latency.
    pub fn transfer(&mut self, start: f64, bytes: usize) -> f64 {
        let begin = start.max(self.busy_until);
        let tx = LinkSpec::serialize_s(bytes, self.mbps);
        self.busy_until = begin + tx;
        self.busy_until + self.latency_s
    }

    /// Completion time without mutating state (capacity probe).
    pub fn peek_transfer(&self, start: f64, bytes: usize) -> f64 {
        let begin = start.max(self.busy_until);
        begin + LinkSpec::serialize_s(bytes, self.mbps) + self.latency_s
    }

    pub fn reset(&mut self) {
        self.busy_until = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_time_matches_bandwidth() {
        // 1 MB at 8 Mbps = 1 second
        let t = LinkSpec::serialize_s(1_000_000, 8.0);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_flow_includes_latency() {
        let mut l = Link::new(8.0, 0.05);
        let done = l.transfer(0.0, 1_000_000);
        assert!((done - 1.05).abs() < 1e-9);
    }

    #[test]
    fn fifo_queueing_serializes_flows() {
        let mut l = Link::new(8.0, 0.05);
        let d1 = l.transfer(0.0, 1_000_000);
        let d2 = l.transfer(0.0, 1_000_000); // queued behind flow 1
        assert!((d1 - 1.05).abs() < 1e-9);
        assert!((d2 - 2.05).abs() < 1e-9);
        // a later flow that arrives after the link is free is not delayed
        let d3 = l.transfer(10.0, 1_000_000);
        assert!((d3 - 11.05).abs() < 1e-9);
    }

    #[test]
    fn peek_does_not_mutate() {
        let mut l = Link::new(8.0, 0.0);
        let p = l.peek_transfer(0.0, 1_000_000);
        let t = l.transfer(0.0, 1_000_000);
        assert_eq!(p, t);
        assert!(l.peek_transfer(0.0, 1_000_000) > p);
    }
}
