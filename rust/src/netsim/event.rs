//! Virtual-time event queue: a binary heap keyed by (time, sequence), the
//! core of the discrete-event engine. Sequence numbers break ties
//! deterministically (FIFO for simultaneous events).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub time: f64,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: reverse on (time, seq)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-time event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    next_seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn push(&mut self, time: f64, event: E) {
        debug_assert!(time >= self.now, "cannot schedule into the past");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let s = self.heap.pop()?;
        self.now = s.time;
        Some(s)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop().unwrap().event, "a");
        assert_eq!(q.pop().unwrap().event, "b");
        assert_eq!(q.now(), 2.0);
        assert_eq!(q.pop().unwrap().event, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_broken_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().event, 1);
        assert_eq!(q.pop().unwrap().event, 2);
        assert_eq!(q.pop().unwrap().event, 3);
    }

    #[test]
    fn interleaved_push_pop_keeps_clock_monotone() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        let e = q.pop().unwrap();
        assert_eq!(e.time, 1.0);
        q.push(1.5, "b");
        q.push(5.0, "d");
        assert_eq!(q.pop().unwrap().event, "b");
        q.push(2.0, "c");
        assert_eq!(q.pop().unwrap().event, "c");
        assert_eq!(q.pop().unwrap().event, "d");
        assert_eq!(q.now(), 5.0);
    }
}
