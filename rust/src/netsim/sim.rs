//! Round-level FL network simulation: broadcast → local compute → upload,
//! driven by the event queue over per-client links and an optional finite
//! server egress link.

use super::event::EventQueue;
use super::link::{Link, LinkSpec};

/// Per-round inputs for one sampled client.
#[derive(Debug, Clone, Copy)]
pub struct RoundPlan {
    pub dl_bytes: usize,
    pub compute_s: f64,
    pub ul_bytes: usize,
}

/// Timing decomposition of one round (the Figure 3 quantities).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundTiming {
    /// Wall-clock of the round: upload landing (max over clients / the
    /// quorum-th landing) plus any modeled server aggregation share.
    pub round_s: f64,
    /// max_i compute_i — the computation share of the round.
    pub compute_s: f64,
    /// Communication share (incl. queueing) up to the closing upload.
    pub comm_s: f64,
    /// Modeled server-side aggregation share (0 unless the caller models
    /// it — see `cluster::netshim::SimProfile::agg_mbps`; divided by the
    /// shard count, since shards aggregate disjoint segments in
    /// parallel).
    pub agg_s: f64,
    /// mean per-client download completion time.
    pub mean_dl_s: f64,
    /// mean per-client upload duration.
    pub mean_ul_s: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    DlDone { client: usize },
    ComputeDone { client: usize },
    UlDone { client: usize },
}

/// Discrete-event simulator for synchronous FL rounds.
pub struct NetSim {
    /// Per-client (uplink, downlink).
    links: Vec<(Link, Link)>,
    /// Finite server egress (broadcast serialization); `None` = unbounded.
    server_egress: Option<Link>,
}

impl NetSim {
    /// Homogeneous fleet: every client has the same access link.
    pub fn homogeneous(n_clients: usize, spec: LinkSpec) -> Self {
        NetSim {
            links: (0..n_clients)
                .map(|_| {
                    (Link::new(spec.ul_mbps, spec.latency_s), Link::new(spec.dl_mbps, spec.latency_s))
                })
                .collect(),
            server_egress: None,
        }
    }

    /// Heterogeneous fleet (per-client specs).
    pub fn heterogeneous(specs: &[LinkSpec]) -> Self {
        NetSim {
            links: specs
                .iter()
                .map(|s| (Link::new(s.ul_mbps, s.latency_s), Link::new(s.dl_mbps, s.latency_s)))
                .collect(),
            server_egress: None,
        }
    }

    pub fn with_server_egress(mut self, mbps: f64, latency_s: f64) -> Self {
        self.server_egress = Some(Link::new(mbps, latency_s));
        self
    }

    pub fn n_clients(&self) -> usize {
        self.links.len()
    }

    /// Simulate one synchronous round over the sampled `clients`.
    ///
    /// Sequence per client: server egress (if finite) → client downlink →
    /// local compute → client uplink. The round completes when the last
    /// upload lands.
    pub fn run_round(&mut self, clients: &[usize], plans: &[RoundPlan]) -> RoundTiming {
        self.run_round_quorum(clients, plans, clients.len())
    }

    /// Simulate one round that closes as soon as `quorum` uploads have
    /// landed (K-of-N aggregation): `round_s` is the quorum-th upload
    /// completion time and the compute share is taken over the quorum-
    /// fastest clients only — stragglers keep their links busy but no
    /// longer gate the round. `quorum == clients.len()` reproduces
    /// [`NetSim::run_round`] exactly.
    pub fn run_round_quorum(
        &mut self,
        clients: &[usize],
        plans: &[RoundPlan],
        quorum: usize,
    ) -> RoundTiming {
        assert_eq!(clients.len(), plans.len());
        let quorum = quorum.clamp(1, clients.len().max(1));
        for (ul, dl) in &mut self.links {
            ul.reset();
            dl.reset();
        }
        if let Some(e) = &mut self.server_egress {
            e.reset();
        }

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut dl_done = vec![0.0f64; clients.len()];
        let mut ul_dur = vec![0.0f64; clients.len()];
        // (completion time, slot) of every landed upload, in event order
        let mut completions: Vec<(f64, usize)> = Vec::with_capacity(clients.len());

        // Kick off broadcasts at t=0 (serialized on the server egress when
        // finite, concurrent otherwise).
        for (slot, (&c, plan)) in clients.iter().zip(plans).enumerate() {
            let egress_done = match &mut self.server_egress {
                Some(e) => e.transfer(0.0, plan.dl_bytes),
                None => 0.0,
            };
            let done = self.links[c].1.transfer(egress_done, plan.dl_bytes);
            dl_done[slot] = done;
            q.push(done, Ev::DlDone { client: slot });
        }

        while let Some(s) = q.pop() {
            match s.event {
                Ev::DlDone { client } => {
                    q.push(s.time + plans[client].compute_s, Ev::ComputeDone { client });
                }
                Ev::ComputeDone { client } => {
                    let c = clients[client];
                    let done = self.links[c].0.transfer(s.time, plans[client].ul_bytes);
                    ul_dur[client] = done - s.time;
                    q.push(done, Ev::UlDone { client });
                }
                Ev::UlDone { client } => {
                    completions.push((s.time, client));
                }
            }
        }

        // the event queue pops in time order, so `completions` is sorted;
        // the quorum-th landing closes the round
        let round_end = completions.get(quorum - 1).map_or(0.0, |&(t, _)| t);
        let compute = completions
            .iter()
            .take(quorum)
            .map(|&(_, slot)| plans[slot].compute_s)
            .fold(0.0, f64::max);
        let n = clients.len().max(1) as f64;
        RoundTiming {
            round_s: round_end,
            compute_s: compute,
            comm_s: round_end - compute,
            agg_s: 0.0,
            mean_dl_s: dl_done.iter().sum::<f64>() / n,
            mean_ul_s: ul_dur.iter().sum::<f64>() / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(ul: f64, dl: f64) -> LinkSpec {
        LinkSpec { ul_mbps: ul, dl_mbps: dl, latency_s: 0.05 }
    }

    #[test]
    fn closed_form_single_client() {
        let mut sim = NetSim::homogeneous(1, spec(1.0, 5.0));
        // 1 MB down at 5 Mbps = 1.6s; 0.5 MB up at 1 Mbps = 4.0s
        let t = sim.run_round(
            &[0],
            &[RoundPlan { dl_bytes: 1_000_000, compute_s: 2.0, ul_bytes: 500_000 }],
        );
        let expect = (1.6 + 0.05) + 2.0 + (4.0 + 0.05);
        assert!((t.round_s - expect).abs() < 1e-9, "{} vs {expect}", t.round_s);
        assert!((t.compute_s - 2.0).abs() < 1e-12);
        assert!((t.comm_s - (expect - 2.0)).abs() < 1e-9);
    }

    #[test]
    fn parallel_clients_round_is_max_not_sum() {
        let mut sim = NetSim::homogeneous(4, spec(1.0, 5.0));
        let plan = RoundPlan { dl_bytes: 1_000_000, compute_s: 1.0, ul_bytes: 1_000_000 };
        let t = sim.run_round(&[0, 1, 2, 3], &[plan; 4]);
        let single = (1.6 + 0.05) + 1.0 + (8.0 + 0.05);
        assert!((t.round_s - single).abs() < 1e-9, "clients have independent links");
    }

    #[test]
    fn slower_uplink_dominates_under_asymmetry() {
        let mut sim = NetSim::homogeneous(1, spec(0.2, 1.0));
        let t = sim.run_round(
            &[0],
            &[RoundPlan { dl_bytes: 500_000, compute_s: 1.0, ul_bytes: 500_000 }],
        );
        assert!(t.mean_ul_s > 4.0 * t.mean_dl_s, "ul {} dl {}", t.mean_ul_s, t.mean_dl_s);
    }

    #[test]
    fn finite_server_egress_serializes_broadcast() {
        let plan = RoundPlan { dl_bytes: 1_000_000, compute_s: 0.0, ul_bytes: 0 };
        let mut free = NetSim::homogeneous(2, spec(100.0, 8.0));
        let t_free = free.run_round(&[0, 1], &[plan; 2]);
        let mut tight =
            NetSim::homogeneous(2, spec(100.0, 8.0)).with_server_egress(8.0, 0.0);
        let t_tight = tight.run_round(&[0, 1], &[plan; 2]);
        // with an 8 Mbps egress the second client's 1 MB broadcast waits 1s
        assert!(t_tight.round_s > t_free.round_s + 0.9);
    }

    #[test]
    fn full_quorum_reproduces_sync_round() {
        let plan = RoundPlan { dl_bytes: 500_000, compute_s: 1.0, ul_bytes: 500_000 };
        let t_sync = NetSim::homogeneous(3, spec(1.0, 5.0)).run_round(&[0, 1, 2], &[plan; 3]);
        let t_q =
            NetSim::homogeneous(3, spec(1.0, 5.0)).run_round_quorum(&[0, 1, 2], &[plan; 3], 3);
        assert_eq!(t_sync, t_q);
    }

    #[test]
    fn quorum_excludes_the_slow_link_from_round_time() {
        // client 2 sits on a link 10x slower: a 2-of-3 quorum round closes
        // on the two fast clients while the sync round waits for the slow one
        let specs =
            [spec(1.0, 5.0), spec(1.0, 5.0), LinkSpec { ul_mbps: 0.1, dl_mbps: 0.5, latency_s: 0.05 }];
        let plan = RoundPlan { dl_bytes: 500_000, compute_s: 1.0, ul_bytes: 500_000 };
        let t_sync = NetSim::heterogeneous(&specs).run_round(&[0, 1, 2], &[plan; 3]);
        let t_q = NetSim::heterogeneous(&specs).run_round_quorum(&[0, 1, 2], &[plan; 3], 2);
        assert!(
            t_q.round_s < t_sync.round_s / 2.0,
            "quorum {} vs sync {}",
            t_q.round_s,
            t_sync.round_s
        );
        // the fast clients' own timing is unchanged by the policy
        let t_fast =
            NetSim::homogeneous(2, spec(1.0, 5.0)).run_round(&[0, 1], &[plan; 2]);
        assert!((t_q.round_s - t_fast.round_s).abs() < 1e-9);
    }

    #[test]
    fn smaller_payloads_reduce_comm_share_monotonically() {
        let mut sim = NetSim::homogeneous(3, spec(1.0, 5.0));
        let mut last = f64::INFINITY;
        for bytes in [4_000_000usize, 1_000_000, 200_000] {
            let t = sim.run_round(
                &[0, 1, 2],
                &[RoundPlan { dl_bytes: bytes, compute_s: 5.0, ul_bytes: bytes }; 3],
            );
            assert!(t.comm_s < last);
            last = t.comm_s;
            assert!((t.compute_s - 5.0).abs() < 1e-12);
        }
    }
}
