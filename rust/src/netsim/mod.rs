//! Discrete-event network simulator — the ns-3 substitute (paper §4.3).
//!
//! The paper evaluates communication time on a simulated FL platform
//! (ns3-fl) with asymmetric uplink/downlink access links per client and
//! 50 ms latency. This module reproduces that measurement: store-and-
//! forward flows over per-client access links plus an optional finite
//! server egress link, driven by a virtual-time event queue.
//!
//! What Figure 3 depends on is flow-completion time under bandwidth
//! asymmetry — latency + serialization + FIFO queueing — which this model
//! captures exactly; packet-level effects (slow start, loss) are not
//! modelled, matching the paper's observation that "actual throughput
//! typically falls short of theoretical bandwidth" only qualitatively.

pub mod event;
pub mod link;
pub mod sim;

pub use link::{Link, LinkSpec};
pub use sim::{NetSim, RoundPlan, RoundTiming};

/// A named bandwidth scenario (uplink/downlink in Mbps + one-way latency).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scenario {
    pub name: &'static str,
    pub ul_mbps: f64,
    pub dl_mbps: f64,
    pub latency_s: f64,
}

impl Scenario {
    pub const fn new(name: &'static str, ul_mbps: f64, dl_mbps: f64) -> Self {
        Scenario { name, ul_mbps, dl_mbps, latency_s: 0.05 }
    }

    pub fn link(&self) -> LinkSpec {
        LinkSpec { ul_mbps: self.ul_mbps, dl_mbps: self.dl_mbps, latency_s: self.latency_s }
    }
}

/// The paper's four UL/DL settings (§4.3, Figure 3).
pub const PAPER_SCENARIOS: [Scenario; 4] = [
    Scenario::new("0.2/1 Mbps", 0.2, 1.0),
    Scenario::new("1/5 Mbps", 1.0, 5.0),
    Scenario::new("2/10 Mbps", 2.0, 10.0),
    Scenario::new("5/25 Mbps", 5.0, 25.0),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scenarios_are_asymmetric() {
        for s in PAPER_SCENARIOS {
            assert!(s.ul_mbps < s.dl_mbps, "{}", s.name);
            assert_eq!(s.latency_s, 0.05);
        }
    }
}
