//! Compile-time stand-in for the `xla` PJRT bindings, used when the `pjrt`
//! feature is off (see Cargo.toml). Every constructor returns a clean
//! error, so the pure-Rust layers — compression, cluster protocol and
//! transports, netsim, data, metrics — build and test without the native
//! XLA extension, while anything that actually needs device execution
//! surfaces "built without the `pjrt` feature" instead of a link failure.
//!
//! The surface mirrors exactly the subset of xla-rs this crate calls
//! (`runtime::Engine`, `fed::session::Session`); keep the two in sync.

#![allow(dead_code)]

/// Error type standing in for `xla::Error` (only ever formatted `{:?}`).
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: ecolora was built without the `pjrt` feature; \
         rebuild with `--features pjrt` (needs the native XLA extension)"
    )))
}

pub struct PjRtClient(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct Literal(());
pub struct HloModuleProto(());
pub struct XlaComputation(());

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Invalid,
    Tuple,
    F32,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn primitive_type(&self) -> Result<PrimitiveType> {
        unavailable("Literal::primitive_type")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
