//! The sharded aggregation plane: N [`ShardAggregator`]s, each owning a
//! contiguous slice of the round-robin segment space, running the Eq. 2
//! merge (and the Eq. 3 staleness-discounted late fold) off the control
//! plane's thread.
//!
//! A shard receives uplink payloads as they arrive (any order), decodes
//! them EAGERLY — overlap with the network wait is where sharding buys
//! wall-clock — but ACCUMULATES them only at round close, strictly in
//! slot order within each segment. Since every flat index belongs to
//! exactly one segment and every segment to exactly one shard, the
//! per-index floating-point reduction of an N-shard round is the same
//! sequence of operations as the single-shard (and monolithic) one:
//! `--shards N` is bitwise-identical to `--shards 1` by construction,
//! and `tests/integration_cluster.rs` enforces it.
//!
//! Each shard also owns its slice of the straggler [`LateBuffer`]: a late
//! uplink covers one segment, so buffering it on the owning shard keeps
//! the fold local. The buffer is byte-capped ([`LATE_BUFFER_MAX_BYTES`])
//! so a pathological slow tail cannot grow server memory without bound;
//! evictions are counted and surfaced in the round metrics.

use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::compress::{dense_bytes, wire, KindIndex, PayloadArena, SparsePool, SparseVec};
use crate::fed::robust::{Aggregator, RobustAggregator, RobustStats};
use crate::fed::staleness;
use crate::metrics::CommTotals;

use super::journal;
use super::protocol::{Message, TrainResult, UpPayload};
use super::transport::{ConnRx, TcpConn};

/// Cap on buffered straggler payload bytes (sparse wire bytes, or
/// 4 bytes/param for dense). 64 MiB comfortably buffers thousands of
/// compressed LoRA segment uplinks; past it the slow tail is dropping
/// results faster than rounds can fold them, and buffering more would
/// only defer the memory blow-up — new arrivals are evicted (counted in
/// the round metrics) rather than admitted.
///
/// The AUTHORITATIVE admission check runs in the control plane
/// (`control::ControlPlane::accept_late`) against this cap as a GLOBAL
/// budget, BEFORE the entry is routed to a shard — an eviction decision
/// made there depends only on arrival order, never on how the segment
/// space is sharded, which keeps `--shards N` bitwise-identical to
/// `--shards 1` even when the cap binds. Each shard's [`LateBuffer`]
/// enforces the same cap per shard purely as a memory-safety backstop
/// (per-shard bytes ≤ admitted bytes ≤ cap, so it cannot fire first).
pub const LATE_BUFFER_MAX_BYTES: usize = 64 << 20;

/// Byte cost a straggler payload is charged against
/// [`LATE_BUFFER_MAX_BYTES`] (shared by the control plane's global
/// admission meter and the per-shard buffer's backstop).
pub fn late_payload_bytes(res: &TrainResult) -> usize {
    match &res.up {
        UpPayload::SparseWire(b) => b.len(),
        UpPayload::DenseUpdate(v) | UpPayload::DenseModule(v) => 4 * v.len(),
    }
}

/// Everything [`LateBuffer::fold_into`] needs from the folding round.
#[derive(Debug, Clone, Copy)]
pub struct FoldCtx<'a> {
    /// Per-client FedAvg weights (the coordinator's partition sizes).
    pub weights: &'a [f64],
    /// Staleness decay β (Eq. 3).
    pub beta: f64,
    /// The round whose aggregate absorbs the fold.
    pub now_round: u64,
    /// `Method::dense_upload_params` — the parameter count an ON-TIME
    /// dense uplink is charged, so a late arrival of the identical
    /// payload costs the same in comm telemetry.
    pub dense_params: usize,
}

/// Aggregation-side tallies a shard accumulates over one round (merged
/// across shards by the router at round close).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AggStats {
    /// Uplink comm accounting for everything folded into the aggregate
    /// (on-time wire/dense uploads plus late folds).
    pub up: CommTotals,
    /// Buffered late uplinks from earlier rounds folded into this round.
    pub late_folds: usize,
    /// Late entries discarded instead of folded (geometry mismatch,
    /// non-positive weight).
    pub orphaned: usize,
    /// Robust-aggregation counters (`clients_trimmed` / `clip_applied`
    /// CSV columns; always zero under `--aggregator mean`).
    pub robust: RobustStats,
}

impl AggStats {
    /// Merge another shard's tallies (order-independent: counts and ints).
    pub fn merge(&mut self, other: &AggStats) {
        self.up.merge(&other.up);
        self.late_folds += other.late_folds;
        self.orphaned += other.orphaned;
        self.robust.merge(&other.robust);
    }
}

/// Buffer of straggler uplinks that arrived after their round closed,
/// awaiting the next round's staleness-discounted fold.
///
/// Arrival order carries no meaning: entries are deduped by
/// (origin round, slot) — first arrival wins — and folded in
/// (origin round, slot) order, so the resulting aggregate is a pure
/// function of the SET of buffered results (property-tested in
/// `tests/integration_cluster.rs`). Total buffered payload bytes are
/// capped at [`LATE_BUFFER_MAX_BYTES`]; arrivals past the cap are
/// rejected and counted in [`LateBuffer::evicted`].
#[derive(Default)]
pub struct LateBuffer {
    entries: Vec<TrainResult>,
    /// (origin round, slot) of every buffered entry — O(1) dedup, so
    /// admission stays O(active cohort) when thousands of stragglers
    /// from a 10⁵-client population land in one buffer.
    seen: std::collections::HashSet<(u64, u32)>,
    bytes: usize,
    /// Results discarded instead of buffered/folded: duplicates of an
    /// already buffered (round, slot), FLoRA module uploads (their
    /// restart base has already advanced), or geometry mismatches against
    /// the folding round's aggregator.
    pub dropped: usize,
    /// Results rejected by the [`LATE_BUFFER_MAX_BYTES`] byte cap.
    pub evicted: usize,
}

impl LateBuffer {
    /// Fresh empty buffer.
    pub fn new() -> LateBuffer {
        LateBuffer::default()
    }

    /// Buffered entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Payload bytes currently buffered (what the cap meters).
    pub fn buffered_bytes(&self) -> usize {
        self.bytes
    }

    /// Buffer one late result; returns true when it was kept. FLoRA
    /// module uploads are rejected outright — a restart module only makes
    /// sense against the base it restarted from, which a later round has
    /// already merged past. Arrivals that would push the buffered payload
    /// bytes past [`LATE_BUFFER_MAX_BYTES`] are evicted instead of kept
    /// (a backstop — the control plane's global admission meter normally
    /// fires first; see the cap's docs).
    pub fn push(&mut self, res: TrainResult) -> bool {
        if matches!(res.up, UpPayload::DenseModule(_)) {
            self.dropped += 1;
            return false;
        }
        if self.seen.contains(&(res.stale_from_round, res.slot)) {
            self.dropped += 1;
            return false;
        }
        let cost = late_payload_bytes(&res);
        if self.bytes + cost > LATE_BUFFER_MAX_BYTES {
            // not recorded in `seen`: a cap-evicted identity that arrives
            // again is evicted again (same count), not mislabeled a dup
            self.evicted += 1;
            return false;
        }
        self.bytes += cost;
        self.seen.insert((res.stale_from_round, res.slot));
        self.entries.push(res);
        true
    }

    /// Drain the buffer into `agg`, weighting every entry by its FedAvg
    /// weight times the Eq. 3 staleness discount
    /// `e^{−β·(now_round − origin_round)}`. Folds in (origin round, slot)
    /// order regardless of arrival order; undecodable or mismatched
    /// entries are counted in [`LateBuffer::dropped`] and
    /// `stats.orphaned` rather than failing the round. Comm accounting
    /// for the folded uplinks lands in `stats.up` (the bytes crossed the
    /// wire in the round that folds them, not the round that lost them);
    /// dense uplinks are charged `FoldCtx::dense_params` parameters — the
    /// same `Method::dense_upload_params` figure an on-time arrival of
    /// the identical payload is charged. Returns the (origin round, slot)
    /// identities that actually folded, so the caller can mark them
    /// aggregated and reject any future racer for the same slot.
    pub fn fold_into(
        &mut self,
        agg: &mut RobustAggregator,
        kidx: &KindIndex,
        ctx: FoldCtx<'_>,
        stats: &mut AggStats,
    ) -> Vec<(u64, u32)> {
        let mut entries = std::mem::take(&mut self.entries);
        self.seen.clear();
        self.bytes = 0;
        entries.sort_by_key(|e| (e.stale_from_round, e.slot));
        let mut folded_ids = Vec::new();
        for res in entries {
            let ci = res.client as usize;
            let staleness = ctx.now_round.saturating_sub(res.stale_from_round).max(1);
            let w = ctx.weights.get(ci).copied().unwrap_or(0.0)
                * staleness::stale_discount(ctx.beta, staleness);
            if w <= 0.0 {
                self.dropped += 1;
                stats.orphaned += 1;
                continue;
            }
            let folded = match &res.up {
                UpPayload::SparseWire(bytes) => {
                    let seg = res.segment as usize;
                    agg.owns(seg)
                        && agg
                            .add_wire(seg, bytes, kidx, w)
                            .map(|params| stats.up.add(params, bytes.len()))
                            .is_ok()
                }
                UpPayload::DenseUpdate(v) => {
                    let fits = agg.owns(0) && v.len() == agg.range(0).len();
                    if fits {
                        agg.add_dense(0, v, w);
                        stats.up.add(ctx.dense_params, dense_bytes(ctx.dense_params));
                    }
                    fits
                }
                // push() rejects these; defensive
                UpPayload::DenseModule(_) => false,
            };
            if folded {
                stats.late_folds += 1;
                folded_ids.push((res.stale_from_round, res.slot));
            } else {
                self.dropped += 1;
                stats.orphaned += 1;
            }
        }
        folded_ids
    }
}

/// One on-time uplink payload routed to a shard (the envelope's typed
/// body; the segment id that picked the shard came from the v2 header).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Compressed round-robin segment update (`compress::wire` bytes).
    Wire(Vec<u8>),
    /// Dense f32 update over the whole vector (baselines, `n_s = 1`).
    Dense(Vec<f32>),
}

/// A decoded on-time contribution waiting for round close.
enum Decoded {
    Sparse { sv: SparseVec, params: usize, bytes: usize },
    Dense(Vec<f32>),
}

struct Pending {
    slot: u32,
    seg: usize,
    w: f64,
    d: Decoded,
}

/// One shard of the aggregation plane: a contiguous slice of the segment
/// space, its Eq. 2 accumulator, and its slice of the straggler buffer.
/// Runs synchronously; [`run_shard`] wraps it in a worker-thread loop.
pub struct ShardAggregator {
    id: usize,
    total: usize,
    /// Robust statistic this plane runs (`FedConfig::aggregator`; every
    /// shard of a plane uses the same one — config-digest enforced for
    /// remote shards).
    kind: Aggregator,
    agg: RobustAggregator,
    late: LateBuffer,
    pending: Vec<Pending>,
    stats: AggStats,
    agg_s: f64,
    error: Option<String>,
    /// Wire decoder scratch, owned by this shard's thread (§Perf, codec
    /// hot path): eager decodes reuse its buffers round after round.
    dec: wire::Decoder,
    /// Recycled `SparseVec`s: close() returns each decoded contribution
    /// here instead of dropping it, so steady-state rounds decode into
    /// warm buffers without heap allocation.
    pool: SparsePool,
}

/// Cap on recycled decode buffers a shard retains (bounds pool memory at
/// roughly one round's worth of contributions).
const DECODE_POOL_MAX: usize = 64;

/// What one shard hands back at round close. Crosses process boundaries
/// as a protocol-v4 `ShardReport` envelope when the aggregation plane
/// runs remotely (`ecolora shard`), so it derives the comparison traits
/// the wire codec's roundtrip property needs.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardReport {
    /// Shard index (router-side gather key).
    pub shard: usize,
    /// First flat index `delta` refers to.
    pub base: usize,
    /// Weighted-average delta over the shard's owned index span.
    pub delta: Vec<f32>,
    /// Per-round aggregation tallies (comm accounting, folds, orphans).
    pub stats: AggStats,
    /// (origin round, slot) identities that late-folded this round.
    pub folded: Vec<(u64, u32)>,
    /// Per owned segment: did it receive at least one contribution?
    pub covered: Vec<bool>,
    /// Wall seconds this shard spent decoding + accumulating this round.
    pub agg_s: f64,
    /// Late arrivals evicted by the byte-cap backstop this round
    /// (normally 0 — the control plane's global meter fires first).
    pub late_evicted: usize,
    /// FNV-1a-64 digest of `delta`'s bit pattern — journaled at round
    /// close so `serve --resume` replay can prove each rebuilt shard
    /// slice matches the crashed run's, before the global advance.
    pub digest: u64,
    /// Fatal shard error (a poisoned round: the run must fail loudly).
    pub error: Option<String>,
}

impl ShardAggregator {
    /// Fresh shard `id` over a `total`-parameter vector running the
    /// `kind` statistic; geometry is set per round by
    /// [`ShardAggregator::begin`].
    pub fn new(id: usize, total: usize, kind: Aggregator) -> ShardAggregator {
        ShardAggregator {
            id,
            total,
            kind,
            agg: RobustAggregator::for_segments(kind, total, 1, 0, 0),
            late: LateBuffer::new(),
            pending: Vec::new(),
            stats: AggStats::default(),
            agg_s: 0.0,
            error: None,
            dec: wire::Decoder::new(),
            pool: SparsePool::new(DECODE_POOL_MAX),
        }
    }

    /// Open a round: own global segments `[seg_lo, seg_hi)` of an
    /// `n_s`-segment space and reset the per-round state. The late buffer
    /// persists across rounds — it holds OTHER rounds' stragglers.
    pub fn begin(&mut self, n_s: usize, seg_lo: usize, seg_hi: usize) {
        self.agg = RobustAggregator::for_segments(self.kind, self.total, n_s, seg_lo, seg_hi);
        self.pending.clear();
        self.stats = AggStats::default();
        self.agg_s = 0.0;
        self.error = None;
        self.late.evicted = 0;
    }

    /// Accept one on-time contribution (any arrival order). Wire payloads
    /// decode NOW — concurrent with the control plane's collect wait —
    /// but fold into the accumulator only at [`ShardAggregator::close`],
    /// in slot order. Errors poison the round and surface in the close
    /// report rather than panicking the worker thread.
    pub fn add(&mut self, slot: u32, seg: usize, w: f64, payload: Payload, kidx: &KindIndex) {
        if self.error.is_some() {
            return;
        }
        let t0 = Instant::now();
        let decoded = match payload {
            Payload::Wire(bytes) => {
                if !self.agg.owns(seg) {
                    self.error = Some(format!("shard {}: segment {seg} not owned", self.id));
                    return;
                }
                let mut sv = self.pool.take();
                match self.dec.decode_into(&bytes, self.agg.range(seg), kidx, &mut sv) {
                    Ok(()) => {
                        let params = sv.len();
                        Decoded::Sparse { sv, params, bytes: bytes.len() }
                    }
                    Err(e) => {
                        self.pool.recycle(sv);
                        self.error = Some(format!("shard {}: slot {slot} decode: {e:#}", self.id));
                        return;
                    }
                }
            }
            Payload::Dense(v) => {
                if !(self.agg.owns(seg) && seg == 0 && v.len() == self.agg.range(0).len()) {
                    self.error = Some(format!(
                        "shard {}: dense update of {} params does not fit segment {seg}",
                        self.id,
                        v.len()
                    ));
                    return;
                }
                Decoded::Dense(v)
            }
        };
        self.agg_s += t0.elapsed().as_secs_f64();
        self.pending.push(Pending { slot, seg, w, d: decoded });
    }

    /// Buffer a straggler from an already-closed round for a later fold.
    pub fn add_late(&mut self, res: TrainResult) {
        self.late.push(res);
    }

    /// Close the round: accumulate the pending on-time contributions in
    /// slot order, fold the buffered stragglers (origin-round/slot order,
    /// Eq. 3 discount), and emit the shard's delta + tallies.
    pub fn close(&mut self, ctx: FoldCtx<'_>, kidx: &KindIndex) -> ShardReport {
        let t0 = Instant::now();
        self.pending.sort_by_key(|p| p.slot);
        let dense_params = ctx.dense_params;
        for p in self.pending.drain(..) {
            match p.d {
                Decoded::Sparse { sv, params, bytes } => {
                    self.agg.add_sparse(p.seg, &sv, p.w);
                    self.stats.up.add(params, bytes);
                    self.pool.recycle(sv); // cap enforced by the pool
                }
                Decoded::Dense(v) => {
                    self.agg.add_dense(p.seg, &v, p.w);
                    self.stats.up.add(dense_params, dense_bytes(dense_params));
                }
            }
        }
        let folded = self.late.fold_into(&mut self.agg, kidx, ctx, &mut self.stats);
        let agg =
            std::mem::replace(&mut self.agg, RobustAggregator::for_segments(self.kind, 0, 1, 0, 0));
        let base = agg.base();
        let covered = agg.covered();
        let (delta, robust) = agg.finish();
        self.stats.robust.merge(&robust);
        self.agg_s += t0.elapsed().as_secs_f64();
        let digest = journal::digest_f32(&delta);
        ShardReport {
            shard: self.id,
            base,
            delta,
            stats: std::mem::take(&mut self.stats),
            folded,
            covered,
            agg_s: self.agg_s,
            late_evicted: self.late.evicted,
            digest,
            error: self.error.take(),
        }
    }
}

/// Message contract between the router and one shard worker thread.
pub enum ShardMsg {
    /// Open round `round` owning segments `[seg_lo, seg_hi)` of `n_s`.
    Begin {
        /// Round index (display/debug only; geometry is what matters).
        round: u64,
        /// Round-robin segment count this round.
        n_s: usize,
        /// First owned global segment.
        seg_lo: usize,
        /// One past the last owned global segment.
        seg_hi: usize,
    },
    /// On-time contribution for the open round.
    Add {
        /// Cohort slot (accumulation order key).
        slot: u32,
        /// Global segment id (already verified to be this shard's).
        seg: usize,
        /// FedAvg weight n_i.
        w: f64,
        /// The uplink payload.
        payload: Payload,
    },
    /// Straggler from an earlier round, for a later staleness fold.
    Late(Box<TrainResult>),
    /// Close the open round and reply with a [`ShardReport`].
    Close {
        /// Staleness decay β (Eq. 3) for the fold.
        beta: f64,
        /// The folding round.
        now_round: u64,
        /// Dense-uplink parameter charge (`Method::dense_upload_params`).
        dense_params: usize,
    },
    /// End of run: drop state and exit the worker loop.
    Shutdown,
}

/// Worker-thread loop for one shard: drain [`ShardMsg`]s until `Shutdown`
/// (or the router hangs up), decrementing the shared `depth` gauge per
/// processed payload message so the router can observe queue backlog.
/// Reports travel back over `reports` keyed by shard id.
pub fn run_shard(
    id: usize,
    total: usize,
    kind: Aggregator,
    weights: Arc<Vec<f64>>,
    kidx: Arc<KindIndex>,
    rx: mpsc::Receiver<ShardMsg>,
    reports: mpsc::Sender<ShardReport>,
    depth: Arc<AtomicIsize>,
) {
    let mut shard = ShardAggregator::new(id, total, kind);
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Begin { n_s, seg_lo, seg_hi, .. } => shard.begin(n_s, seg_lo, seg_hi),
            ShardMsg::Add { slot, seg, w, payload } => {
                shard.add(slot, seg, w, payload, &kidx);
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            ShardMsg::Late(res) => {
                shard.add_late(*res);
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            ShardMsg::Close { beta, now_round, dense_params } => {
                let ctx = FoldCtx { weights: &weights, beta, now_round, dense_params };
                let report = shard.close(ctx, &kidx);
                if reports.send(report).is_err() {
                    return; // router is gone; nothing left to serve
                }
            }
            ShardMsg::Shutdown => return,
        }
    }
}

/// Remote-process counterpart of [`run_shard`]: serve one shard of the
/// aggregation plane over an already-joined coordinator connection
/// (`ecolora shard`). The loop speaks the protocol-v4 wire encoding of
/// the [`ShardMsg`] contract — `ShardBegin`/`ShardAdd`/`TrainResult`
/// (stragglers)/`ShardClose` in, `ShardReport` out — and runs the exact
/// same [`ShardAggregator`] code path as an in-process shard thread, so
/// the aggregate a remote plane produces is bitwise-identical to
/// `--shards N`. Report sends recycle their payload buffer through a
/// [`PayloadArena`] and a reused frame scratch: steady-state rounds
/// allocate nothing on the uplink side of the link.
///
/// Returns `Ok(())` on an orderly `Shutdown`; a dropped connection or a
/// malformed frame is an error (the coordinator decides whether to fall
/// back or abort — this process just exits loudly).
pub fn serve_shard_conn(
    id: usize,
    total: usize,
    kind: Aggregator,
    weights: &[f64],
    kidx: &KindIndex,
    conn: TcpConn,
) -> Result<()> {
    let (mut tx, mut rx) = conn.split_tcp()?;
    let mut shard = ShardAggregator::new(id, total, kind);
    let mut arena = PayloadArena::new(4);
    let mut frame = Vec::new();
    loop {
        let env = rx.recv().context("shard: receiving from coordinator")?;
        match Message::from_envelope(&env).context("shard: parsing coordinator frame")? {
            Message::ShardBegin { n_s, seg_lo, seg_hi, .. } => {
                shard.begin(n_s as usize, seg_lo as usize, seg_hi as usize);
            }
            Message::ShardAdd { slot, seg, w, payload } => {
                shard.add(slot, seg as usize, w, payload, kidx);
            }
            Message::TrainResult(res) => shard.add_late(res),
            Message::ShardClose { now_round, beta, dense_params } => {
                let ctx =
                    FoldCtx { weights, beta, now_round, dense_params: dense_params as usize };
                let report = shard.close(ctx, kidx);
                let env = Message::ShardReport(Box::new(report)).to_envelope_in(arena.take());
                tx.send_scratch(&env, &mut frame).context("shard: sending round report")?;
                arena.recycle(env.payload);
            }
            Message::Shutdown => return Ok(()),
            other => {
                bail!("shard {id}: unexpected {:?} from coordinator", other.kind())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LoraKind;

    fn kidx(n: usize) -> KindIndex {
        let kinds: Vec<LoraKind> = (0..n)
            .map(|i| if (i / 16) % 2 == 0 { LoraKind::A } else { LoraKind::B })
            .collect();
        KindIndex::new(&kinds)
    }

    fn dense_result(origin: u64, slot: u32, client: u32, n: usize) -> TrainResult {
        TrainResult {
            round: origin,
            slot,
            client,
            segment: 0,
            n_samples: 1,
            mean_loss: 0.0,
            k_a: 0.0,
            k_b: 0.0,
            exec_s: 0.0,
            stale_from_round: origin,
            up: UpPayload::DenseUpdate(vec![1.0; n]),
        }
    }

    #[test]
    fn late_buffer_byte_cap_evicts_instead_of_growing() {
        let mut buf = LateBuffer::new();
        // each dense entry costs 4·n bytes; size entries so two fit and
        // the third trips the cap
        let n = LATE_BUFFER_MAX_BYTES / 4 / 2;
        assert!(buf.push(dense_result(1, 0, 0, n)));
        assert!(buf.push(dense_result(1, 1, 1, n)));
        assert_eq!(buf.buffered_bytes(), LATE_BUFFER_MAX_BYTES);
        assert!(!buf.push(dense_result(1, 2, 2, n)), "cap rejects the overflow entry");
        assert_eq!(buf.evicted, 1);
        assert_eq!(buf.dropped, 0, "eviction is counted separately from dedup drops");
        assert_eq!(buf.len(), 2);
        // a tiny entry still fails once the budget is exhausted exactly
        assert!(!buf.push(dense_result(1, 3, 3, 1)));
        assert_eq!(buf.evicted, 2);
    }

    #[test]
    fn fold_resets_byte_meter() {
        let mut buf = LateBuffer::new();
        assert!(buf.push(dense_result(2, 0, 0, 8)));
        assert_eq!(buf.buffered_bytes(), 32);
        let mut agg = RobustAggregator::new(Aggregator::Mean, 8, 1);
        let mut stats = AggStats::default();
        let ctx = FoldCtx { weights: &[1.0], beta: 0.7, now_round: 3, dense_params: 8 };
        let folded = buf.fold_into(&mut agg, &kidx(8), ctx, &mut stats);
        assert_eq!(folded, vec![(2, 0)]);
        assert_eq!(stats.late_folds, 1);
        assert!(buf.is_empty());
        assert_eq!(buf.buffered_bytes(), 0);
    }

    #[test]
    fn shard_decodes_eagerly_but_accumulates_in_slot_order() {
        let n = 32;
        let kidx = kidx(n);
        let mut shard = ShardAggregator::new(0, n, Aggregator::Mean);
        shard.begin(1, 0, 1);
        // arrival order 1, 0 — close must fold 0 first (slot order)
        shard.add(1, 0, 1.0, Payload::Dense(vec![3.0; n]), &kidx);
        shard.add(0, 0, 3.0, Payload::Dense(vec![1.0; n]), &kidx);
        let ctx = FoldCtx { weights: &[1.0], beta: 0.7, now_round: 0, dense_params: n };
        let rep = shard.close(ctx, &kidx);
        assert!(rep.error.is_none());
        assert_eq!(rep.base, 0);
        assert_eq!(rep.covered, vec![true]);
        // (3·1 + 1·3)/4 = 1.5 either way — order shows up in the bits of
        // harder sums; here assert the bookkeeping
        assert_eq!(rep.delta, vec![1.5; n]);
        assert_eq!(rep.stats.up.params as usize, 2 * n);
    }

    #[test]
    fn shard_reports_decode_errors_at_close() {
        let n = 32;
        let kidx = kidx(n);
        let mut shard = ShardAggregator::new(2, n, Aggregator::Mean);
        shard.begin(2, 1, 2);
        shard.add(0, 0, 1.0, Payload::Wire(vec![0xFF; 10]), &kidx); // foreign segment
        let ctx = FoldCtx { weights: &[1.0], beta: 0.7, now_round: 0, dense_params: 0 };
        let rep = shard.close(ctx, &kidx);
        let msg = rep.error.expect("foreign segment must poison the round");
        assert!(msg.contains("not owned"), "{msg}");
    }
}
