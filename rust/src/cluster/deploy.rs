//! Multi-process deployment: the machinery that turns the in-process
//! cluster of PR 1–4 into separate `ecolora serve` / `ecolora worker`
//! binaries on real links.
//!
//! Three pieces:
//!
//! * [`WorkerPool`] — the coordinator's connection table. PR 1 assumed
//!   every connection exists, index-aligned, before round 0; the pool
//!   replaces that with a registration *state machine*: slots are
//!   (re)occupied by [`Event::Joined`] notices, a failed send or a
//!   reader hangup marks a slot dead, and each occupation carries a
//!   generation counter so notices from a replaced connection are
//!   ignored. The in-process path ([`crate::cluster::run`]) uses the
//!   same pool with all slots installed up front, so both deployments
//!   drive rounds through one loop.
//! * [`spawn_registry`] — the `serve` accept loop: polls the listener
//!   for the whole run, admits connections through the protocol-v3
//!   handshake ([`crate::cluster::handshake`]), and feeds admitted
//!   connections to the pool. A worker that drops and dials back in is
//!   re-admitted into its old slot — from the round state machine's
//!   point of view the drop was just a straggler burst, absorbed by the
//!   existing quorum/resample machinery.
//! * [`drive_rounds`] — the shared round loop (dispatch → collect →
//!   close), lifted out of `cluster::run` and hardened for dead
//!   workers: under [`RoundPolicy::Quorum`] a dead worker's slots
//!   expire at the wave timeout and resample to replacement clients;
//!   under [`RoundPolicy::Sync`] a death is fatal (sync rounds cannot
//!   resample, by definition). A round whose quorum can provably no
//!   longer arrive — every unfilled slot's dispatches went to
//!   connections that no longer exist and no re-dispatch wave remains —
//!   fails loudly instead of spinning.
//!
//! Bitwise parity: `serve` + N spawned `worker` processes over loopback
//! produce the same deterministic round metrics as the in-process mem
//! cluster (enforced by the gated end-to-end test in
//! `tests/integration_deploy.rs`), because worker slots host the same
//! logical clients (`client mod n_workers`) regardless of which OS
//! process holds the slot, and every result is a pure function of
//! (world, client state, task) — see `fed::world`.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::fed::FedConfig;
use crate::metrics::{RoundRecord, RunLog};
use crate::netsim::RoundTiming;
use crate::util::lock_unpoisoned;

use super::control::{ControlPlane, Phase, RoundPolicy};
use super::handshake::{self, Admission, AuthToken, HandshakeSpec, Rejected};
use super::journal::{self, Record};
use super::netshim::Meter;
use super::participant::{self, Participant};
use super::protocol::{Envelope, Message, MsgKind, RejectCode};
use super::router::Router;
use super::transport::{self, Conn, ConnRx as _, ConnTx as _, Listener};
use super::{ClusterOptions, ClusterOutcome, FaultSpec};

// ---- connection telemetry ---------------------------------------------------

/// Per-worker-slot connection lifecycle counters (the `metrics`
/// satellite of the multi-host deployment: who connected, how often the
/// link dropped, and how much protocol traffic the slot carried).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerConnStats {
    /// Worker slot id.
    pub worker: usize,
    /// Times a connection was installed into this slot (1 for a stable
    /// worker; each rejoin adds one).
    pub joins: usize,
    /// Times the slot's connection died (send failure or reader hangup).
    pub drops: usize,
    /// `TrainTask` messages dispatched to this slot.
    pub tasks_sent: usize,
    /// `TrainResult` messages received from this slot.
    pub results_received: usize,
}

// ---- worker pool ------------------------------------------------------------

/// Internal pool event, produced by reader threads and the registry.
pub(crate) enum Event {
    /// An envelope arrived from worker `worker`'s generation-`gen` conn.
    Msg {
        /// Worker slot the connection belongs to.
        worker: usize,
        /// Connection generation at spawn (stale generations are dropped).
        gen: u64,
        /// The received envelope.
        env: Envelope,
    },
    /// Worker `worker`'s generation-`gen` connection hung up.
    Down {
        /// Worker slot the connection belonged to.
        worker: usize,
        /// Connection generation at spawn.
        gen: u64,
    },
    /// The registry admitted a connection for slot `worker`.
    Joined {
        /// Worker slot the connection was admitted into.
        worker: usize,
        /// True when the slot had previously dropped (a rejoin).
        rejoin: bool,
        /// The admitted, post-handshake connection.
        conn: Box<dyn Conn>,
    },
}

/// What [`WorkerPool::next`] hands the drive loop.
pub(crate) enum PoolNotice {
    /// An envelope from a live worker connection.
    Msg(usize, Envelope),
    /// A worker's connection died (already marked dead in the pool).
    Down(usize),
    /// A worker (re)joined and is ready for dispatch.
    Joined(usize),
    /// The caller-supplied deadline passed with no event.
    Timeout,
}

/// The coordinator's worker-connection table (see module docs).
pub(crate) struct WorkerPool {
    txs: Vec<Option<Box<dyn transport::ConnTx>>>,
    alive: Vec<bool>,
    gen: Vec<u64>,
    events_tx: mpsc::Sender<Event>,
    events_rx: mpsc::Receiver<Event>,
    meter: Option<Meter>,
    /// Slot phases shared with the registry thread (None for the
    /// in-process pool, which has no registry).
    ledger: Option<Arc<Mutex<RegistryLedger>>>,
    stats: Vec<WorkerConnStats>,
    round_drops: usize,
    round_rejoins: usize,
    readers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Empty pool with `n` worker slots.
    pub(crate) fn new(
        n: usize,
        meter: Option<Meter>,
        ledger: Option<Arc<Mutex<RegistryLedger>>>,
    ) -> WorkerPool {
        let (events_tx, events_rx) = mpsc::channel();
        WorkerPool {
            txs: (0..n).map(|_| None).collect(),
            alive: vec![false; n],
            gen: vec![0; n],
            events_tx,
            events_rx,
            meter,
            ledger,
            stats: (0..n).map(|worker| WorkerConnStats { worker, ..Default::default() }).collect(),
            round_drops: 0,
            round_rejoins: 0,
            readers: Vec::new(),
        }
    }

    /// Sender half for the registry thread's `Joined` events.
    pub(crate) fn events_sender(&self) -> mpsc::Sender<Event> {
        self.events_tx.clone()
    }

    /// Worker slot count.
    pub(crate) fn n(&self) -> usize {
        self.txs.len()
    }

    /// Slots with a live connection.
    pub(crate) fn alive_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Whether slot `w` currently has a live connection.
    pub(crate) fn is_alive(&self, w: usize) -> bool {
        self.alive[w]
    }

    /// Current connection generation of slot `w` (bumps on every
    /// install; a dispatch records it so the drive loop can tell whether
    /// the connection that carried a task still exists).
    pub(crate) fn generation(&self, w: usize) -> u64 {
        self.gen[w]
    }

    /// The transport byte meter, when netsim is attached.
    pub(crate) fn meter(&self) -> Option<&Meter> {
        self.meter.as_ref()
    }

    /// Whether a registry is accepting joins for this pool (serve mode).
    /// When true, a dead worker may yet be replaced by a rejoin; when
    /// false (in-process pool) lost capacity is lost for good.
    pub(crate) fn has_registry(&self) -> bool {
        self.ledger.is_some()
    }

    /// Install a connection into slot `w`: bump the generation, split
    /// the conn, wrap the halves in the byte meter, spawn the reader
    /// thread, and mark the slot alive.
    pub(crate) fn install(&mut self, w: usize, rejoin: bool, conn: Box<dyn Conn>) -> Result<()> {
        ensure!(w < self.n(), "pool: install into unknown slot {w}");
        self.gen[w] += 1;
        let gen = self.gen[w];
        let (tx, rx) = conn.split()?;
        let (tx, mut rx) = match &self.meter {
            Some(m) => (m.wrap_tx(tx), m.wrap_rx(rx)),
            None => (tx, rx),
        };
        self.txs[w] = Some(tx);
        self.alive[w] = true;
        self.stats[w].joins += 1;
        if rejoin {
            self.round_rejoins += 1;
        }
        let fwd = self.events_tx.clone();
        let reader = std::thread::Builder::new()
            .name(format!("ecolora-reader-{w}"))
            .spawn(move || {
                while let Ok(env) = rx.recv() {
                    if fwd.send(Event::Msg { worker: w, gen, env }).is_err() {
                        return; // pool is gone
                    }
                }
                let _ = fwd.send(Event::Down { worker: w, gen });
            })
            .context("pool: spawn reader thread")?;
        self.readers.push(reader);
        Ok(())
    }

    fn mark_down(&mut self, w: usize) {
        if !self.alive[w] {
            return;
        }
        self.alive[w] = false;
        self.txs[w] = None;
        self.stats[w].drops += 1;
        self.round_drops += 1;
        if let Some(ledger) = &self.ledger {
            lock_unpoisoned(ledger).mark_dropped(w);
        }
    }

    /// Send `msg` to slot `w`. Returns false — marking the slot dead —
    /// when the slot has no live connection or the transport reports a
    /// send failure; the caller decides whether that is fatal
    /// (`RoundPolicy::Sync`) or absorbed (`Quorum` resampling).
    pub(crate) fn send(&mut self, w: usize, msg: &Message) -> bool {
        if !self.alive[w] {
            return false;
        }
        let env = msg.to_envelope();
        let ok = self
            .txs[w]
            .as_mut()
            .expect("alive slot has a tx")
            .send(&env)
            .is_ok();
        if ok {
            if env.kind == MsgKind::TrainTask {
                self.stats[w].tasks_sent += 1;
            }
        } else {
            self.mark_down(w);
        }
        ok
    }

    /// Block until the next pool event (or `deadline`). `Joined` events
    /// are installed before being surfaced; stale-generation events are
    /// swallowed.
    pub(crate) fn next(&mut self, deadline: Option<Instant>) -> Result<PoolNotice> {
        loop {
            let ev = match deadline {
                None => self
                    .events_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("pool: event channel closed"))?,
                Some(d) => {
                    let wait = d.saturating_duration_since(Instant::now());
                    match self.events_rx.recv_timeout(wait) {
                        Ok(ev) => ev,
                        Err(mpsc::RecvTimeoutError::Timeout) => return Ok(PoolNotice::Timeout),
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            bail!("pool: event channel closed")
                        }
                    }
                }
            };
            match ev {
                Event::Msg { worker, gen: _, env } => {
                    // deliver regardless of the slot's liveness or
                    // generation: an envelope the reader forwarded before
                    // its connection died (or was replaced) is finished,
                    // valid work — possibly the result that completes the
                    // quorum — and the control plane validates contents
                    // anyway. Only Down notices are generation-gated.
                    if env.kind == MsgKind::TrainResult {
                        self.stats[worker].results_received += 1;
                    }
                    return Ok(PoolNotice::Msg(worker, env));
                }
                Event::Down { worker, gen } => {
                    if gen != self.gen[worker] || !self.alive[worker] {
                        continue; // already replaced or already marked
                    }
                    self.mark_down(worker);
                    return Ok(PoolNotice::Down(worker));
                }
                Event::Joined { worker, rejoin, conn } => {
                    match self.install(worker, rejoin, conn) {
                        Ok(()) => return Ok(PoolNotice::Joined(worker)),
                        Err(e) => {
                            // fd/thread exhaustion while installing one
                            // admitted connection must not kill the run:
                            // drop the conn, roll the slot fully back
                            // (ledger included) so the worker can rejoin,
                            // and keep serving
                            eprintln!(
                                "[serve] installing worker {worker}'s connection \
                                 failed ({e:#}); slot reopened for rejoin"
                            );
                            self.alive[worker] = false;
                            self.txs[worker] = None;
                            if let Some(ledger) = &self.ledger {
                                lock_unpoisoned(ledger).mark_dropped(worker);
                            }
                            continue;
                        }
                    }
                }
            }
        }
    }

    /// Drain the per-round drop/rejoin counters (for `RoundRecord`).
    pub(crate) fn take_round_counters(&mut self) -> (usize, usize) {
        (std::mem::take(&mut self.round_drops), std::mem::take(&mut self.round_rejoins))
    }

    /// Send `Shutdown` to every live worker and drop all senders (so
    /// peers blocked on recv observe the hangup even if the `Shutdown`
    /// was lost). `join_readers` additionally joins the reader threads —
    /// right for in-process runs, where the workers are known to exit;
    /// a serve coordinator skips it so a wedged remote socket cannot
    /// block its own exit.
    pub(crate) fn shutdown(&mut self, join_readers: bool) {
        for w in 0..self.n() {
            if self.alive[w] {
                self.send(w, &Message::Shutdown);
            }
        }
        for tx in &mut self.txs {
            *tx = None;
        }
        if join_readers {
            for h in self.readers.drain(..) {
                let _ = h.join();
            }
        }
    }

    /// Consume the pool, returning the per-slot connection telemetry.
    pub(crate) fn into_stats(self) -> Vec<WorkerConnStats> {
        self.stats
    }
}

// ---- registry ---------------------------------------------------------------

/// Slot occupancy as the registry sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotPhase {
    /// Never occupied.
    Free,
    /// A live connection holds the slot.
    Connected,
    /// Previously occupied; the connection died. Re-assignable (rejoin).
    Dropped,
}

/// Worker-slot assignment state shared between the registry thread
/// (reserving slots for joiners) and the pool (releasing them on drops).
/// The same machine tracks remote aggregation-shard slots (`role =
/// "shard"`): ids are assigned the same way, only the reject wording
/// changes.
pub(crate) struct RegistryLedger {
    slots: Vec<SlotPhase>,
    role: &'static str,
}

impl RegistryLedger {
    /// All-free worker ledger with `n` slots.
    pub(crate) fn new(n: usize) -> RegistryLedger {
        RegistryLedger::for_role(n, "worker")
    }

    /// All-free ledger with `n` slots for an arbitrary peer role.
    pub(crate) fn for_role(n: usize, role: &'static str) -> RegistryLedger {
        RegistryLedger { slots: vec![SlotPhase::Free; n], role }
    }

    /// Reserve a slot for a joiner (the handshake's id-assignment
    /// policy): an explicit id must be in range and not currently
    /// connected; a wildcard takes the first free slot, else the first
    /// dropped one. Returns `(id, rejoin)`.
    pub(crate) fn reserve(
        &mut self,
        requested: Option<u32>,
    ) -> std::result::Result<(u32, bool), (RejectCode, String)> {
        let n = self.slots.len();
        let role = self.role;
        if n == 0 {
            // e.g. a ShardJoin against a coordinator whose aggregation
            // plane runs in-process (serve without --expect-shards)
            return Err((
                RejectCode::ClusterFull,
                format!("this coordinator has no {role} slots"),
            ));
        }
        match requested {
            Some(id) => {
                let i = id as usize;
                if i >= n {
                    return Err((
                        RejectCode::ClusterFull,
                        format!("{role} id {id} out of range (cluster has {n} {role} slots)"),
                    ));
                }
                match self.slots[i] {
                    SlotPhase::Connected => Err((
                        RejectCode::DuplicateWorker,
                        format!("{role} id {id} is already connected"),
                    )),
                    phase => {
                        self.slots[i] = SlotPhase::Connected;
                        Ok((id, phase == SlotPhase::Dropped))
                    }
                }
            }
            None => {
                if let Some(i) = self.slots.iter().position(|&p| p == SlotPhase::Free) {
                    self.slots[i] = SlotPhase::Connected;
                    Ok((i as u32, false))
                } else if let Some(i) =
                    self.slots.iter().position(|&p| p == SlotPhase::Dropped)
                {
                    self.slots[i] = SlotPhase::Connected;
                    Ok((i as u32, true))
                } else {
                    Err((
                        RejectCode::ClusterFull,
                        format!("all {n} {role} slots are connected"),
                    ))
                }
            }
        }
    }

    /// Roll back a reservation whose `Welcome` never arrived. The slot
    /// becomes `Dropped` (re-assignable either way; the distinction only
    /// feeds the rejoin counter).
    pub(crate) fn unreserve(&mut self, id: u32) {
        if let Some(p) = self.slots.get_mut(id as usize) {
            if *p == SlotPhase::Connected {
                *p = SlotPhase::Dropped;
            }
        }
    }

    /// The pool observed slot `w`'s connection die.
    pub(crate) fn mark_dropped(&mut self, w: usize) {
        if let Some(p) = self.slots.get_mut(w) {
            if *p == SlotPhase::Connected {
                *p = SlotPhase::Dropped;
            }
        }
    }
}

/// Handle to the background accept loop; stops (and joins) on drop.
pub(crate) struct Registry {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl Registry {
    /// Signal the accept loop to exit and wait for it.
    pub(crate) fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Spawn the serve-side accept loop: poll `listener`, run the v3
/// admission handshake on every connection, and forward admitted conns
/// to the pool as [`Event::Joined`]. Runs for the whole run so dropped
/// workers can rejoin mid-round.
///
/// Each admission runs on its own short-lived thread: a handshake can
/// legitimately take up to [`handshake::HANDSHAKE_TIMEOUT`] against a
/// silent peer, and serializing that on the accept loop would let one
/// garbage connection stall a legitimate rejoin past the drive loop's
/// grace window (the slot ledger is behind a mutex precisely so
/// admissions may race; id reservation stays atomic).
pub(crate) fn spawn_registry(
    listener: Listener,
    spec: HandshakeSpec,
    ledger: Arc<Mutex<RegistryLedger>>,
    shard_ledger: Arc<Mutex<RegistryLedger>>,
    events: mpsc::Sender<Event>,
    shard_conns: mpsc::Sender<(u32, transport::TcpConn)>,
    resume_round: Arc<AtomicU64>,
) -> Result<Registry> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = stop.clone();
    let spec = Arc::new(spec);
    let thread = std::thread::Builder::new()
        .name("ecolora-registry".into())
        .spawn(move || {
            while !stop_flag.load(Ordering::Relaxed) {
                match listener.try_accept() {
                    Ok(Some((conn, peer))) => {
                        let spec = spec.clone();
                        let ledger = ledger.clone();
                        let shard_ledger = shard_ledger.clone();
                        let events = events.clone();
                        let shard_conns = shard_conns.clone();
                        let resume_round = resume_round.clone();
                        let spawned = std::thread::Builder::new()
                            .name("ecolora-admit".into())
                            .spawn(move || {
                                admit_one(
                                    conn,
                                    peer,
                                    &spec,
                                    &ledger,
                                    &shard_ledger,
                                    &events,
                                    &shard_conns,
                                    &resume_round,
                                )
                            });
                        if let Err(e) = spawned {
                            eprintln!("[serve] could not spawn admission thread: {e}");
                        }
                    }
                    Ok(None) => std::thread::sleep(Duration::from_millis(25)),
                    Err(e) => {
                        eprintln!("[serve] listener error: {e:#}");
                        std::thread::sleep(Duration::from_millis(250));
                    }
                }
            }
        })
        .context("serve: spawn registry thread")?;
    Ok(Registry { stop, thread: Some(thread) })
}

/// One admission, run on its own thread (see [`spawn_registry`]).
fn admit_one(
    mut conn: transport::TcpConn,
    peer: std::net::SocketAddr,
    spec: &HandshakeSpec,
    ledger: &Arc<Mutex<RegistryLedger>>,
    shard_ledger: &Arc<Mutex<RegistryLedger>>,
    events: &mpsc::Sender<Event>,
    shard_conns: &mpsc::Sender<(u32, transport::TcpConn)>,
    resume_round: &AtomicU64,
) {
    let resume = resume_round.load(Ordering::Relaxed);
    let outcome = handshake::admit(
        &mut conn,
        spec,
        |requested| lock_unpoisoned(ledger).reserve(requested),
        |id| lock_unpoisoned(ledger).unreserve(id),
        |requested| lock_unpoisoned(shard_ledger).reserve(requested),
        |id| lock_unpoisoned(shard_ledger).unreserve(id),
        resume,
    );
    match outcome {
        Ok(Admission::Admitted { worker, rejoin }) => {
            eprintln!(
                "[serve] worker {worker} {} from {peer}",
                if rejoin { "rejoined" } else { "joined" }
            );
            let ev = Event::Joined { worker: worker as usize, rejoin, conn: Box::new(conn) };
            // a send failure means the pool is gone and the run is over
            let _ = events.send(ev);
        }
        Ok(Admission::AdmittedShard { shard, rejoin }) => {
            eprintln!(
                "[serve] shard {shard} {} from {peer}",
                if rejoin { "rejoined" } else { "joined" }
            );
            // a send failure means the serve loop is gone; drop the conn
            let _ = shard_conns.send((shard, conn));
        }
        Ok(Admission::Rejected(code)) => {
            eprintln!("[serve] rejected join from {peer}: {}", code.name());
        }
        Err(e) => {
            // silent peer, early disconnect, version skew, corrupt
            // frame: drop the socket and keep serving — an aborted
            // handshake must never poison the run
            eprintln!("[serve] handshake with {peer} aborted: {e:#}");
        }
    }
}

// ---- shared round-drive loop ------------------------------------------------

/// Consecutive no-progress wave timeouts a quorum round tolerates while
/// a registry is accepting rejoins, before concluding the quorum is
/// unreachable. The grace window is therefore `REJOIN_GRACE_WAVES ×
/// --slot-timeout` — enough for a `--reconnect` worker's backoff + dial
/// + handshake at any sane timeout, while still bounding how long a
/// fully-dead round can linger.
pub(crate) const REJOIN_GRACE_WAVES: usize = 4;

/// What [`drive_rounds`] produces (the control plane turns it into a
/// `FedOutcome`).
pub(crate) struct DriveOutcome {
    /// Per-round telemetry.
    pub(crate) log: RunLog,
    /// Round at which `target_acc` was reached, if it was.
    pub(crate) reached: Option<usize>,
    /// Simulated per-round timings (when netsim is attached).
    pub(crate) timings: Vec<RoundTiming>,
}

/// Durability controls for one [`drive_rounds`] invocation: the journal
/// writer (if any), where the live loop starts, and the state a
/// `--resume` replay already rebuilt. [`DriveCtl::fresh`] is the plain
/// journal-less run every in-process caller wants.
pub(crate) struct DriveCtl {
    /// Append-only round journal; `None` disables journaling.
    pub(crate) journal: Option<journal::JournalWriter>,
    /// First round the live loop dispatches (0 for a fresh run; the
    /// round after the last journaled close under `--resume`).
    pub(crate) start_round: usize,
    /// Round log rebuilt by journal replay (`None` for a fresh run).
    pub(crate) resumed_log: Option<RunLog>,
    /// Round at which `target_acc` was reached during replay, if it was
    /// (the live loop then has nothing left to do).
    pub(crate) reached: Option<usize>,
    /// Crash-test hook (`--hold-after-dispatch N`): after round N's
    /// initial dispatch is journaled and flushed, print a marker and
    /// hang forever — a deterministic SIGKILL target for the recovery
    /// integration tests.
    pub(crate) hold_after_dispatch: Option<u64>,
}

impl DriveCtl {
    /// A journal-less, non-resumed drive (in-process runs, plain serve).
    pub(crate) fn fresh() -> DriveCtl {
        DriveCtl {
            journal: None,
            start_round: 0,
            resumed_log: None,
            reached: None,
            hold_after_dispatch: None,
        }
    }
}

/// Drive every round of a run over `pool` (see module docs): the one
/// loop behind both the in-process cluster and the multi-process serve
/// path. `resume_round`, when given, is kept at the round currently
/// being dispatched so rejoin `Welcome`s can report it. `ctl` carries
/// the durability state: the journal writer appended at every round
/// state transition, and — under `serve --resume` — the replayed log
/// and the round the live loop picks up from.
pub(crate) fn drive_rounds(
    control: &mut ControlPlane,
    router: &mut Router,
    pool: &mut WorkerPool,
    opts: &ClusterOptions,
    resume_round: Option<&AtomicU64>,
    ctl: DriveCtl,
) -> Result<DriveOutcome> {
    let n_workers = pool.n();
    let n_shards = opts.shards.max(1);
    let sync = opts.policy.slot_timeout().is_none();
    // resolved by `cluster::run` (0 for the threads plane and for serve,
    // whose client plane lives in other processes)
    let mux_workers = opts.mux_workers.unwrap_or(0);
    let label = control.cfg.run_label();
    let mut jw = ctl.journal;
    let mut log = ctl.resumed_log.unwrap_or_else(|| RunLog::new(label.clone()));
    let mut reached: Option<usize> = ctl.reached;
    let mut timings = Vec::new();
    // a replay that already hit target_acc leaves nothing to drive
    let first = if reached.is_some() { control.cfg.rounds } else { ctl.start_round };

    for t in first..control.cfg.rounds {
        if let Some(r) = resume_round {
            r.store(t as u64, Ordering::Relaxed);
        }
        if sync {
            // Sync cannot resample, so every slot must be deliverable
            // before the round spends any downlink state
            ensure!(
                pool.alive_count() == n_workers,
                "cluster: {} of {n_workers} workers are disconnected and \
                 RoundPolicy::Sync cannot resample their slots; rerun with \
                 --round-policy quorum for fault tolerance",
                n_workers - pool.alive_count(),
            );
        }
        // Sampling + Broadcast. Slots whose owning worker is down get no
        // task (and crucially no stateful-downlink channel advance); the
        // quorum wave machinery re-dispatches them to live replacements.
        // `sched_ms` accumulates the coordinator's scheduling cost —
        // sampling, downlink build, dispatch, resample waves, round close
        // — the work that must stay O(active cohort), not O(population).
        let sched_t0 = Instant::now();
        let mut sched_ms = 0.0f64;
        // successful task dispatches this round (initial + resample waves)
        let mut active_cohort = 0usize;
        let alive_now: Vec<bool> = (0..n_workers).map(|w| pool.is_alive(w)).collect();
        // the RNG stream position is journaled BEFORE begin_round
        // advances it, so replay can prove it re-enters the round from
        // the exact same stream state
        if let Some(j) = jw.as_mut() {
            j.append(
                t as u64,
                &Record::RoundOpen { rng_state: control.rng_state(), alive: alive_now.clone() },
            )?;
        }
        let (mut rs, tasks) = control.begin_round(t as u64, n_workers, &alive_now)?;
        router.begin_round(t as u64, rs.n_s)?;
        // Which (worker, generation) each slot's task went to: a slot can
        // still report iff one of its dispatches sits on a connection
        // that is still that worker's live one.
        let mut inflight: Vec<Vec<(usize, u64)>> = vec![Vec::new(); rs.n_t];
        for (w, task) in tasks {
            let slot = task.slot as usize;
            let client = task.client;
            let down_seq = task.down_seq;
            let stateful = down_seq > 0;
            let gen = pool.generation(w);
            if pool.send(w, &Message::TrainTask(task)) {
                inflight[slot].push((w, gen));
                active_cohort += 1;
                if let Some(j) = jw.as_mut() {
                    j.append(
                        t as u64,
                        &Record::Dispatch { slot: slot as u32, client, worker: w as u32, down_seq },
                    )?;
                }
            } else if sync {
                bail!(
                    "cluster: worker {w} is down and RoundPolicy::Sync cannot resample \
                     slot {slot}; rerun with --round-policy quorum for fault tolerance"
                );
            } else {
                // quorum: the slot re-dispatches at the wave timeout —
                // but a stateful downlink that never left already
                // advanced the client's channel, which is unrecoverable
                if stateful {
                    eprintln!(
                        "[serve] client {client}'s sparse downlink was built but its \
                         worker died before the send; excluding the client for the \
                         rest of the run"
                    );
                    if let Some(j) = jw.as_mut() {
                        j.append(t as u64, &Record::DownlinkLost { client })?;
                    }
                    control.downlink_lost(client);
                }
            }
        }
        sched_ms += sched_t0.elapsed().as_secs_f64() * 1e3;
        // crash-test hook: everything above is journaled and flushed;
        // SIGKILL lands here with the round open but unclosed
        if ctl.hold_after_dispatch == Some(t as u64) {
            if let Some(j) = jw.as_mut() {
                j.commit_round()?;
            }
            eprintln!("[serve] crash-hold: round {t} dispatched; holding for SIGKILL");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        // Collect: every result is routed — current round into the round
        // state (closing it at quorum) with its payload forwarded to the
        // owning aggregation shard, earlier rounds into that shard's late
        // buffer. Worker deaths are fatal under Sync and absorbed by the
        // resample machinery under Quorum.
        let mut wave_deadline = opts.policy.slot_timeout().map(|d| Instant::now() + d);
        // consecutive no-progress wave timeouts (quorum liveness; reset
        // whenever a dispatch goes out or a worker rejoins)
        let mut idle_waves = 0usize;
        while rs.phase == Phase::Collect {
            if sync {
                // under Sync any disconnect bails below; an empty pool
                // here would otherwise block forever on the deadline-less
                // recv
                ensure!(
                    pool.alive_count() > 0,
                    "cluster: every worker is disconnected during round {t}"
                );
            }
            match pool.next(wave_deadline)? {
                PoolNotice::Msg(_w, env) => match Message::from_envelope(&env)? {
                    Message::TrainResult(res) => {
                        if res.round == rs.t {
                            // journaled BEFORE accept so replay re-takes
                            // the same accept/orphan/duplicate decision
                            if let Some(j) = jw.as_mut() {
                                j.append_uplink(rs.t, false, &env)?;
                            }
                            if let Some(add) = control.accept(&mut rs, res)? {
                                router.route(add)?;
                            }
                        } else if res.round < rs.t {
                            // straggler from a closed quorum round.
                            // Journaled only when it changes state —
                            // admitted to a late buffer, or evicted by
                            // the byte cap (a deterministic CSV column);
                            // an arrival the `filled` dedup drops (e.g.
                            // a resumed worker re-sending an
                            // already-folded result) leaves no record,
                            // keeping journal_bytes identical between
                            // interrupted and uninterrupted runs.
                            let evicted_before = control.late_evicted();
                            if let Some(fwd) = control.accept_late(res) {
                                if let Some(j) = jw.as_mut() {
                                    j.append_uplink(rs.t, true, &env)?;
                                }
                                router.route_late(fwd)?;
                            } else if control.late_evicted() > evicted_before {
                                if let Some(j) = jw.as_mut() {
                                    j.append_uplink(rs.t, true, &env)?;
                                }
                            }
                        } else {
                            bail!("cluster: result for future round {}", res.round);
                        }
                    }
                    Message::Error { text } => bail!("worker failed: {text}"),
                    other => bail!("cluster: expected TrainResult, got {:?}", other.kind()),
                },
                PoolNotice::Down(w) => {
                    if sync {
                        bail!(
                            "cluster: worker {w} disconnected during round {t} under \
                             RoundPolicy::Sync (its tasks cannot be resampled; rerun \
                             with --round-policy quorum for fault tolerance)"
                        );
                    }
                    // quorum: its slots expire at the wave deadline and
                    // resample to replacement clients
                }
                PoolNotice::Joined(_w) => {
                    // recovered capacity: grant the unfilled slots a
                    // fresh re-dispatch budget (waves already spent
                    // against dead connections must not starve the
                    // rejoined worker) and reset the liveness clock.
                    // Journaled: the wave-attempt counters feed the
                    // deterministic resample draws.
                    if let Some(j) = jw.as_mut() {
                        j.append(rs.t, &Record::ReopenWaves)?;
                    }
                    rs.reopen_waves();
                    idle_waves = 0;
                }
                PoolNotice::Timeout => {
                    // wave timeout: re-dispatch every outstanding slot to
                    // replacements hosted on currently-live workers
                    let wave_t0 = Instant::now();
                    let alive_now: Vec<bool> =
                        (0..n_workers).map(|w| pool.is_alive(w)).collect();
                    let mut dispatched = false;
                    for slot in rs.unfilled_slots() {
                        // journaled even when the draw yields no task:
                        // the attempt counter and assignee list advance
                        // either way, and replay must follow
                        if let Some(j) = jw.as_mut() {
                            j.append(
                                rs.t,
                                &Record::Resample { slot: slot as u32, alive: alive_now.clone() },
                            )?;
                        }
                        if let Some((w, task)) =
                            control.resample_slot(&mut rs, slot, n_workers, &alive_now)?
                        {
                            let client = task.client;
                            let down_seq = task.down_seq;
                            let stateful = down_seq > 0;
                            let gen = pool.generation(w);
                            if pool.send(w, &Message::TrainTask(task)) {
                                inflight[slot].push((w, gen));
                                dispatched = true;
                                active_cohort += 1;
                                if let Some(j) = jw.as_mut() {
                                    j.append(
                                        rs.t,
                                        &Record::Dispatch {
                                            slot: slot as u32,
                                            client,
                                            worker: w as u32,
                                            down_seq,
                                        },
                                    )?;
                                }
                            } else if stateful {
                                // the owner died since the snapshot: the
                                // wave is spent, and the built downlink
                                // already advanced this client's channel
                                eprintln!(
                                    "[serve] client {client}'s sparse downlink was \
                                     built but its worker died before the send; \
                                     excluding the client for the rest of the run"
                                );
                                if let Some(j) = jw.as_mut() {
                                    j.append(rs.t, &Record::DownlinkLost { client })?;
                                }
                                control.downlink_lost(client);
                            }
                        }
                    }
                    // Liveness: nothing new went out AND no unfilled slot
                    // has a dispatch on a still-live connection ⇒ the
                    // quorum cannot arrive from what exists right now.
                    // With a registry a rejoin could still save the round,
                    // so allow a bounded grace window before failing; an
                    // in-process pool has nobody to wait for.
                    let can_progress = dispatched
                        || rs.unfilled_slots().iter().any(|&slot| {
                            inflight[slot]
                                .iter()
                                .any(|&(w, g)| pool.is_alive(w) && pool.generation(w) == g)
                        });
                    if can_progress {
                        idle_waves = 0;
                    } else {
                        idle_waves += 1;
                        if !pool.has_registry() || idle_waves >= REJOIN_GRACE_WAVES {
                            bail!(
                                "cluster: round {t} can no longer reach quorum \
                                 ({} of {} results; every outstanding dispatch went to a \
                                 connection that no longer exists and no re-dispatch wave \
                                 or rejoin arrived)",
                                rs.received(),
                                rs.quorum,
                            );
                        }
                    }
                    let timeout = opts.policy.slot_timeout().expect("deadline implies timeout");
                    wave_deadline = Some(Instant::now() + timeout);
                    sched_ms += wave_t0.elapsed().as_secs_f64() * 1e3;
                }
            }
        }
        control.ensure_collected(&rs)?;
        let compute_by_slot = rs.exec_by_slot();
        let quorum = rs.quorum;
        // shards beyond the segment count own nothing and add no
        // parallelism — the netsim agg model must not credit them
        let agg_parallelism = n_shards.min(rs.n_s.max(1));
        // Aggregate: close the shards, gather the Eq. 2 delta, and let
        // the control plane finish.
        let close_t0 = Instant::now();
        let gathered = router.close_round(t as u64)?;
        let shard_digests = gathered.shard_digests.clone();
        let (mut rec, base_sync) = control.finish_round(rs, gathered)?;
        sched_ms += close_t0.elapsed().as_secs_f64() * 1e3;
        rec.population = control.cfg.n_clients;
        rec.active_cohort = active_cohort;
        rec.mux_workers = mux_workers;
        rec.sched_ms = sched_ms;
        if let Some(base) = base_sync {
            for w in 0..n_workers {
                // base sync only happens for restart methods, which the
                // control plane only admits under Sync — where a dead
                // worker is fatal
                if !pool.send(w, &Message::BaseSync { base: base.clone() }) {
                    bail!("cluster: worker {w} disconnected during base sync");
                }
            }
        }
        let (drops, rejoins) = pool.take_round_counters();
        rec.worker_drops = drops;
        rec.worker_rejoins = rejoins;
        if let Some(j) = jw.as_mut() {
            // round_bytes is captured BEFORE the close record so the
            // value inside the record equals the value replay reports
            let journal_bytes = j.round_bytes();
            j.append(
                t as u64,
                &Record::RoundClose {
                    active_cohort: active_cohort as u32,
                    mux_workers: mux_workers as u32,
                    worker_drops: drops as u32,
                    worker_rejoins: rejoins as u32,
                    journal_bytes,
                    global_digest: control.global_digest(),
                    shard_digests,
                },
            )?;
            let fsync_s = j.commit_round()?;
            rec.journal_bytes = journal_bytes;
            rec.journal_fsync_ms = fsync_s * 1e3;
        }
        if let (Some(m), Some(profile)) = (pool.meter(), &opts.netsim) {
            timings.push(
                m.round_timing(t as u64, &compute_by_slot, profile, quorum, agg_parallelism)?,
            );
        }
        if control.cfg.verbose {
            let acc = rec.eval_acc;
            eprintln!(
                "[{label}@{}x{n_workers}s{n_shards}] round {t}: loss {:.4} acc {} upM {:.3} downM {:.3} k=({:.2},{:.2}) stragglers {} late {} drops {} aggMs {:.2}",
                opts.mode.name(),
                rec.global_loss,
                acc.map_or("-".into(), |a| format!("{a:.3}")),
                rec.up.params_m(),
                rec.down.params_m(),
                rec.k_a,
                rec.k_b,
                rec.stragglers,
                rec.late_folds,
                rec.worker_drops,
                rec.shard_agg_ms_max,
            );
        }
        let acc = rec.eval_acc;
        log.push(rec);
        if let (Some(target), Some(a)) = (control.cfg.target_acc, acc) {
            if a >= target {
                reached = Some(t);
                break;
            }
        }
    }
    Ok(DriveOutcome { log, reached, timings })
}

// ---- journal replay ---------------------------------------------------------

/// What [`replay_journal`] rebuilt from a journal.
pub(crate) struct ReplayOutcome {
    /// Telemetry of every closed (replayed) round.
    pub(crate) log: RunLog,
    /// Round at which `target_acc` was reached during replay, if it was.
    pub(crate) reached: Option<usize>,
    /// First round the live loop must dispatch.
    pub(crate) next_round: u64,
}

/// The `Genesis` record a run with these parameters writes — and the
/// one `serve --resume` must find at the head of the journal (a resumed
/// invocation with different flags would deterministically diverge, so
/// it is refused up front).
pub(crate) fn genesis_record(
    config_digest: u64,
    n_workers: usize,
    n_shards: usize,
    policy: RoundPolicy,
) -> Record {
    let (policy_tag, quorum_bits, timeout_ms) = match policy {
        RoundPolicy::Sync => (0u8, 0u64, 0u64),
        RoundPolicy::Quorum { q, timeout } => (1, q.to_bits(), timeout.as_millis() as u64),
    };
    Record::Genesis {
        config_digest,
        n_workers: n_workers as u32,
        shards: n_shards as u32,
        policy_tag,
        quorum_bits,
        timeout_ms,
    }
}

/// Replay a round journal into a freshly-built control plane + router:
/// re-run every CLOSED round's state transitions in journal order
/// (replay IS re-execution — the control plane is deterministic, so
/// feeding it the journaled inputs rebuilds bitwise-identical state),
/// verifying the journaled RNG stream positions and aggregate digests
/// along the way. A torn trailing record and an unclosed final round
/// are NOT errors: both mean the coordinator died mid-round, and that
/// round simply re-runs live after the workers rejoin.
pub(crate) fn replay_journal(
    path: &Path,
    control: &mut ControlPlane,
    router: &mut Router,
    n_workers: usize,
    expect_genesis: &Record,
) -> Result<ReplayOutcome> {
    let (records, torn) = journal::read_journal(path)?;
    if torn > 0 {
        eprintln!("[serve] journal has a torn {torn}-byte tail (crash mid-append); dropping it");
    }
    let mut it = records.into_iter();
    match it.next() {
        Some((_, genesis)) => ensure!(
            &genesis == expect_genesis,
            "serve --resume: the journal's genesis does not match this invocation \
             (journal {genesis:?}, flags {expect_genesis:?}); a resumed run must use \
             the same config, --expect-workers, --shards, and --round-policy it \
             started with"
        ),
        None => {
            bail!("serve --resume: journal {} is empty (no genesis record)", path.display())
        }
    }

    let mut log = RunLog::new(control.cfg.run_label());
    let mut reached = None;
    let mut next_round = 0u64;
    let mut pending: Vec<(u64, Record)> = Vec::new();
    for (round, rec) in it {
        if matches!(rec, Record::Genesis { .. }) {
            bail!("journal {}: unexpected second genesis record", path.display());
        }
        let is_close = matches!(rec, Record::RoundClose { .. });
        if matches!(rec, Record::RoundOpen { .. }) {
            if let Some((t0, _)) = pending.first() {
                eprintln!(
                    "[serve] journal: round {t0} never closed ({} record(s) discarded); \
                     the round re-runs live",
                    pending.len()
                );
            }
            pending.clear();
        } else {
            ensure!(
                !pending.is_empty(),
                "journal {}: record for round {round} outside an open round",
                path.display()
            );
        }
        pending.push((round, rec));
        if is_close {
            let out = apply_replayed_round(control, router, n_workers, &pending)
                .with_context(|| format!("serve --resume: replaying journaled round {round}"))?;
            pending.clear();
            next_round = round + 1;
            let acc = out.eval_acc;
            log.push(out);
            if let (Some(target), Some(a)) = (control.cfg.target_acc, acc) {
                if a >= target {
                    reached = Some(round as usize);
                    break;
                }
            }
        }
    }
    if let Some((t0, _)) = pending.first() {
        eprintln!(
            "[serve] journal: round {t0} was open at the crash ({} record(s) discarded); \
             the round re-runs live",
            pending.len()
        );
    }
    Ok(ReplayOutcome { log, reached, next_round })
}

/// Re-execute one closed round from its journal slice (`RoundOpen ..=
/// RoundClose`): the control plane and router go through the same call
/// sequence as the live loop, so every deterministic CSV column comes
/// out bitwise identical. The journaled digests turn silent divergence
/// (config drift, a journal from another build) into a loud error.
fn apply_replayed_round(
    control: &mut ControlPlane,
    router: &mut Router,
    n_workers: usize,
    records: &[(u64, Record)],
) -> Result<RoundRecord> {
    let (t, alive) = match &records[0] {
        (t, Record::RoundOpen { rng_state, alive }) => {
            ensure!(
                alive.len() == n_workers,
                "round {t}: journaled alive bitmap covers {} workers, this run has \
                 {n_workers}",
                alive.len()
            );
            let live = control.rng_state();
            ensure!(
                live == *rng_state,
                "round {t}: RNG stream position diverged (journal {rng_state:016x?}, \
                 replay {live:016x?}); the journal does not match this configuration"
            );
            (*t, alive.clone())
        }
        _ => bail!("replay batch must start with RoundOpen"),
    };
    let (mut rs, _tasks) = control.begin_round(t, n_workers, &alive)?;
    router.begin_round(t, rs.n_s)?;
    for (_r, rec) in &records[1..records.len() - 1] {
        match rec {
            // audit trail only: replay rebuilds every task through
            // begin_round / resample_slot, and nothing is sent
            Record::Dispatch { .. } => {}
            Record::Uplink { envelope } => {
                let env = Envelope::decode(envelope)?;
                let Message::TrainResult(res) = Message::from_envelope(&env)? else {
                    bail!("round {t}: journaled on-time uplink is not a TrainResult");
                };
                ensure!(
                    res.round == t,
                    "round {t}: journaled on-time uplink belongs to round {}",
                    res.round
                );
                if let Some(add) = control.accept(&mut rs, res)? {
                    router.route(add)?;
                }
            }
            Record::LateUplink { envelope } => {
                let env = Envelope::decode(envelope)?;
                let Message::TrainResult(res) = Message::from_envelope(&env)? else {
                    bail!("round {t}: journaled late uplink is not a TrainResult");
                };
                if let Some(fwd) = control.accept_late(res) {
                    router.route_late(fwd)?;
                }
            }
            Record::Resample { slot, alive } => {
                // the draw and its side effects (attempt counters,
                // assignee list, possibly a downlink-channel advance)
                // replay; the task itself goes nowhere
                let _ = control.resample_slot(&mut rs, *slot as usize, n_workers, alive)?;
            }
            Record::DownlinkLost { client } => control.downlink_lost(*client),
            Record::ReopenWaves => rs.reopen_waves(),
            other => bail!("round {t}: unexpected mid-round record {other:?}"),
        }
    }
    control.ensure_collected(&rs)?;
    let gathered = router.close_round(t)?;
    let (
        _t,
        Record::RoundClose {
            active_cohort,
            mux_workers,
            worker_drops,
            worker_rejoins,
            journal_bytes,
            global_digest,
            shard_digests,
        },
    ) = records.last().expect("non-empty batch")
    else {
        bail!("replay batch must end with RoundClose");
    };
    ensure!(
        gathered.shard_digests == *shard_digests,
        "round {t}: shard aggregate digests diverged on replay (journal \
         {shard_digests:016x?}, replay {:016x?})",
        gathered.shard_digests
    );
    // base_sync (FLoRA) is dropped: workers that survived the crash
    // already applied it before the coordinator died, and replay has
    // nobody to send to
    let (mut rec, _base_sync) = control.finish_round(rs, gathered)?;
    let live_digest = control.global_digest();
    ensure!(
        live_digest == *global_digest,
        "round {t}: global model digest diverged on replay (journal \
         {global_digest:016x}, replay {live_digest:016x})"
    );
    rec.population = control.cfg.n_clients;
    rec.active_cohort = *active_cohort as usize;
    rec.mux_workers = *mux_workers as usize;
    rec.worker_drops = *worker_drops as usize;
    rec.worker_rejoins = *worker_rejoins as usize;
    rec.journal_bytes = *journal_bytes;
    // wall-clock columns are declared nondeterministic; zeros keep the
    // replayed rows honest
    rec.sched_ms = 0.0;
    rec.journal_fsync_ms = 0.0;
    Ok(rec)
}

// ---- serve / worker entry points --------------------------------------------

/// `--journal` configuration for [`serve`].
pub struct JournalOptions {
    /// Journal file path (created fresh, or replayed + appended under
    /// `resume`).
    pub path: PathBuf,
    /// Replay the existing journal and resume the crashed run
    /// (`--resume`).
    pub resume: bool,
    /// When journal appends reach the disk (`--journal-sync`).
    pub sync: journal::SyncPolicy,
}

/// `ecolora serve` configuration.
pub struct ServeOptions {
    /// Address to bind the coordinator listener on (e.g.
    /// `127.0.0.1:7878`, `0.0.0.0:7878`).
    pub listen: String,
    /// The deployment's shared secret.
    pub token: AuthToken,
    /// Worker slots; the run starts once this many workers have joined.
    pub expect_workers: usize,
    /// Remote aggregation-shard slots (`--expect-shards`): the round
    /// loop starts only after this many `ecolora shard` processes have
    /// joined, and the router fans uplink payloads out to them over
    /// framed TCP. 0 (the default) runs the aggregation plane
    /// in-process. When nonzero it must equal the `--shards` plane size
    /// — the remote tier replaces the in-process plane wholesale.
    pub expect_shards: usize,
    /// How long to wait for the initial worker wave before giving up.
    pub join_timeout: Duration,
    /// Durable round journal (`--journal`); `None` disables journaling.
    pub journal: Option<JournalOptions>,
    /// Crash-test hook (`--hold-after-dispatch N`): hang the
    /// coordinator right after round N's dispatch records are journaled
    /// and flushed — a deterministic SIGKILL target for the crash
    /// recovery tests. Requires `--journal`.
    pub hold_after_dispatch: Option<u64>,
    /// Round/shard/netsim options (the `mode` field is ignored — serve
    /// is TCP by construction; `workers` is superseded by
    /// `expect_workers`; `fault` belongs to the worker side).
    pub cluster: ClusterOptions,
}

/// Run a federated job as a multi-process coordinator: bind the
/// listener, admit `expect_workers` authenticated `ecolora worker`
/// processes through the protocol-v3 handshake, then drive the standard
/// round loop over their connections. Workers that drop mid-run are
/// stragglers (absorbed under `--round-policy quorum`, fatal under
/// sync), and may rejoin through the same listener at any time.
pub fn serve(cfg: FedConfig, opts: &ServeOptions) -> Result<ClusterOutcome> {
    let n_workers = opts.expect_workers;
    ensure!(n_workers >= 1, "serve: --expect-workers must be at least 1");
    ensure!(
        n_workers <= cfg.n_clients.max(1),
        "serve: --expect-workers {n_workers} exceeds the client population {}",
        cfg.n_clients
    );
    let digest = cfg.digest();
    ensure!(
        opts.hold_after_dispatch.is_none() || opts.journal.is_some(),
        "serve: --hold-after-dispatch is a journal crash hook; it requires --journal"
    );
    let n_shards = opts.cluster.shards.max(1);
    ensure!(
        opts.expect_shards == 0 || opts.expect_shards == n_shards,
        "serve: --expect-shards {} must equal --shards {n_shards} (the remote \
         aggregation tier replaces the in-process plane wholesale)",
        opts.expect_shards
    );
    ensure!(
        opts.expect_shards == 0 || opts.journal.as_ref().is_none_or(|j| !j.resume),
        "serve: --resume with a remote aggregation plane (--expect-shards) is not \
         supported — journal replay needs the plane before any shard can join; \
         resume with an in-process plane, then restart the distributed tier"
    );

    // Build the server world — and, under `--resume`, replay the
    // journal into it — BEFORE the listener exists: a rejoining
    // worker's Welcome must carry the resumed round, and replay must
    // never race live traffic. Workers dialing early see
    // connection-refused and retry within their dial window.
    let mut control = ControlPlane::new(cfg, opts.cluster.policy)?;
    let mut router = match opts.expect_shards {
        // in-process plane: shard worker threads, as before
        0 => Router::new(
            control.lora_total(),
            n_shards,
            control.client_weights(),
            control.kind_index(),
            control.fold_beta(),
            control.dense_upload_params(),
            control.aggregator(),
        )?,
        // remote plane: every slot starts Pending and is armed once its
        // `ecolora shard` process completes the join handshake
        _ => Router::new_remote(
            control.lora_total(),
            n_shards,
            control.client_weights(),
            control.kind_index(),
            control.fold_beta(),
            control.dense_upload_params(),
            control.aggregator(),
        )?,
    };

    let mut ctl = DriveCtl::fresh();
    ctl.hold_after_dispatch = opts.hold_after_dispatch;
    if let Some(jopts) = &opts.journal {
        let genesis = genesis_record(digest, n_workers, n_shards, opts.cluster.policy);
        if jopts.resume {
            let rep =
                replay_journal(&jopts.path, &mut control, &mut router, n_workers, &genesis)?;
            eprintln!(
                "[serve] resumed from journal {}: {} round(s) replayed, next round {}",
                jopts.path.display(),
                rep.log.rounds.len(),
                rep.next_round
            );
            ctl.start_round = rep.next_round as usize;
            ctl.resumed_log = Some(rep.log);
            ctl.reached = rep.reached;
            ctl.journal = Some(journal::JournalWriter::open_append(&jopts.path, jopts.sync)?);
        } else {
            ctl.journal = Some(journal::JournalWriter::create(&jopts.path, jopts.sync, &genesis)?);
        }
    }
    let start_round = ctl.start_round;

    let listener = Listener::bind(&opts.listen)?;
    let addr = listener.local_addr()?;
    eprintln!(
        "[serve] listening on {addr} ({n_workers} worker slot{}, config digest {digest:016x})",
        if n_workers == 1 { "" } else { "s" }
    );

    let ledger = Arc::new(Mutex::new(RegistryLedger::new(n_workers)));
    let shard_ledger =
        Arc::new(Mutex::new(RegistryLedger::for_role(opts.expect_shards, "shard")));
    let resume_round = Arc::new(AtomicU64::new(start_round as u64));
    let meter = opts.cluster.netsim.as_ref().map(|_| Meter::new());
    let mut pool = WorkerPool::new(n_workers, meter, Some(ledger.clone()));
    let spec = HandshakeSpec {
        token: opts.token.clone(),
        config_digest: digest,
        n_workers,
        n_shards: opts.expect_shards,
    };
    let (shard_conns_tx, shard_conns) = mpsc::channel();
    let mut registry = spawn_registry(
        listener,
        spec,
        ledger,
        shard_ledger,
        pool.events_sender(),
        shard_conns_tx,
        resume_round.clone(),
    )?;

    // Wait for the remote aggregation plane first (worker joins simply
    // queue in the pool meanwhile). A shard slot that never fills is a
    // deployment error, reported like a missing worker; shard deaths
    // AFTER this point are the router's fallback/abort policy.
    let deadline = Instant::now() + opts.join_timeout;
    while router.pending_shards() > 0 {
        let wait = deadline.saturating_duration_since(Instant::now());
        match shard_conns.recv_timeout(wait) {
            Ok((shard, conn)) => {
                router.install_remote(shard, conn)?;
                eprintln!(
                    "[serve] {}/{} shard processes connected",
                    opts.expect_shards - router.pending_shards(),
                    opts.expect_shards
                );
            }
            Err(mpsc::RecvTimeoutError::Timeout) => bail!(
                "serve: only {} of {} shard processes joined within {:?}; start the \
                 missing shards with `ecolora shard --connect {addr} --token-file …` \
                 and matching run flags",
                opts.expect_shards - router.pending_shards(),
                opts.expect_shards,
                opts.join_timeout,
            ),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                bail!("serve: registry stopped before the shard wave completed")
            }
        }
    }
    if opts.expect_shards > 0 {
        eprintln!("[serve] all {} shard processes connected", opts.expect_shards);
    }

    // Wait for the full first worker wave.
    while pool.alive_count() < n_workers {
        match pool.next(Some(deadline))? {
            PoolNotice::Joined(_w) => {
                eprintln!("[serve] {}/{} workers connected", pool.alive_count(), n_workers);
            }
            PoolNotice::Down(w) => {
                eprintln!("[serve] worker {w} dropped before the run started");
            }
            PoolNotice::Timeout => bail!(
                "serve: only {} of {n_workers} workers joined within {:?}; start the \
                 missing workers with `ecolora worker --connect {addr} --token-file …` \
                 and matching run flags",
                pool.alive_count(),
                opts.join_timeout,
            ),
            PoolNotice::Msg(w, _env) => {
                bail!("serve: unexpected protocol message from worker {w} before round 0")
            }
        }
    }
    // pre-run churn is not round telemetry
    let _ = pool.take_round_counters();
    eprintln!("[serve] all {n_workers} workers connected; starting round {start_round}");

    let out =
        drive_rounds(&mut control, &mut router, &mut pool, &opts.cluster, Some(&resume_round), ctl)?;
    let outcome = control.outcome(out.log, out.reached)?;
    pool.shutdown(false);
    registry.stop();
    router.shutdown()?;
    Ok(ClusterOutcome {
        fed: outcome,
        timings: out.timings,
        workers: n_workers,
        shards: n_shards,
        transport: "tcp",
        worker_conns: pool.into_stats(),
    })
}

/// `ecolora worker` configuration.
pub struct WorkerOptions {
    /// Coordinator address to dial (e.g. `coordinator.example:7878`).
    pub connect: String,
    /// The deployment's shared secret.
    pub token: AuthToken,
    /// Ask for a specific worker slot (`None` = let the coordinator
    /// assign one).
    pub requested_id: Option<u32>,
    /// Rejoin attempts after a lost connection (0 = die with the link).
    pub reconnect: u32,
    /// Per-dial window during which connection-refused is retried.
    pub dial_timeout: Duration,
    /// Deterministic straggler injection (tests, demos).
    pub fault: Option<FaultSpec>,
}

/// Run a federated participant as its own process: build the
/// deterministic world from the local configuration, dial the
/// coordinator, complete the protocol-v3 join handshake, and serve
/// tasks until `Shutdown`. On a lost connection the worker redials and
/// rejoins its old slot (up to `reconnect` times), keeping its client
/// state — the coordinator sees the outage as a straggler burst.
pub fn run_remote_worker(cfg: FedConfig, opts: &WorkerOptions) -> Result<()> {
    let digest = cfg.digest();
    eprintln!(
        "[worker] building world for {} (config digest {digest:016x})…",
        cfg.run_label()
    );
    let mut participant = Participant::new(cfg).context("worker: building world")?;
    participant.set_fault(opts.fault);
    let mut requested = opts.requested_id;
    let mut rejoins_left = opts.reconnect;
    loop {
        let mut conn = transport::dial(&opts.connect, opts.dial_timeout)?;
        let joined = match handshake::join(&mut conn, &opts.token, digest, requested) {
            Ok(j) => j,
            Err(e) => {
                // A rejoin can race the coordinator's own detection of
                // the dropped link: until the pool processes the old
                // connection's hangup, this worker's slot still reads as
                // connected and the coordinator answers DuplicateWorker.
                // That — and any transport-level handshake failure — is
                // transient and worth the remaining rejoin budget.
                // Deterministic refusals (bad token, config mismatch,
                // cluster full, malformed) stay immediately fatal:
                // retrying them can never succeed.
                let transient = match e.downcast_ref::<Rejected>() {
                    Some(r) => r.code == RejectCode::DuplicateWorker,
                    None => true,
                };
                if transient && rejoins_left > 0 {
                    rejoins_left -= 1;
                    eprintln!(
                        "[worker] join did not complete ({e:#}); retrying \
                         ({rejoins_left} attempts left)…"
                    );
                    std::thread::sleep(Duration::from_millis(500));
                    continue;
                }
                return Err(e);
            }
        };
        eprintln!(
            "[worker] joined {} as worker {} of {} (coordinator at round {})",
            opts.connect, joined.worker, joined.n_workers, joined.resume_round
        );
        // keep the same identity (and therefore client shard) on rejoin
        requested = Some(joined.worker);
        match participant::serve_conn(&mut participant, &mut conn, opts.fault, joined.resume_round)
        {
            Ok(()) => {
                eprintln!("[worker] run complete (coordinator sent Shutdown)");
                return Ok(());
            }
            Err(e) if rejoins_left > 0 => {
                rejoins_left -= 1;
                eprintln!(
                    "[worker] connection lost ({e:#}); rejoining as worker {} \
                     ({rejoins_left} attempts left)…",
                    joined.worker
                );
                std::thread::sleep(Duration::from_millis(500));
            }
            Err(e) => {
                return Err(e.context("worker: connection lost and no rejoin attempts remain"))
            }
        }
    }
}

/// `ecolora shard` configuration.
pub struct ShardOptions {
    /// Coordinator address to dial (e.g. `coordinator.example:7878`).
    pub connect: String,
    /// The deployment's shared secret.
    pub token: AuthToken,
    /// Ask for a specific shard slot (`None` = let the coordinator
    /// assign one).
    pub requested_id: Option<u32>,
    /// Per-dial window during which connection-refused is retried.
    pub dial_timeout: Duration,
}

/// Run one shard of the coordinator's aggregation plane as its own
/// process: derive the plane parameters from the local configuration,
/// dial the coordinator, complete the `ShardJoin` handshake, and serve
/// wire-encoded `ShardMsg` traffic until `Shutdown`
/// ([`super::shard::serve_shard_conn`]).
///
/// Unlike a worker there is no rejoin loop: a shard that loses its link
/// has lost its late-straggler buffer, so the coordinator immediately
/// replaces the slice with an in-process shard (or fails the open
/// round) and the slot never reopens for this run. A lost connection is
/// therefore a fatal error here — restart the run to redistribute.
pub fn run_remote_shard(cfg: FedConfig, opts: &ShardOptions) -> Result<()> {
    let digest = cfg.digest();
    eprintln!(
        "[shard] deriving aggregation plane for {} (config digest {digest:016x})…",
        cfg.run_label()
    );
    // Derive (vector length, client weights, kind index) exactly the
    // way the coordinator does: the handshake's config-digest check
    // guarantees both sides started from identical flags, so the
    // derived plane parameters are identical too — which is what makes
    // remote aggregation bitwise-equal to in-process `--shards N`.
    let (total, weights, kidx, aggregator) = {
        let control = ControlPlane::new(cfg, RoundPolicy::Sync)?;
        (
            control.lora_total(),
            control.client_weights(),
            control.kind_index(),
            control.aggregator(),
        )
    };
    let mut conn = transport::dial(&opts.connect, opts.dial_timeout)?;
    let joined = handshake::join_shard(&mut conn, &opts.token, digest, opts.requested_id)?;
    eprintln!(
        "[shard] joined {} as shard {} of {} (coordinator at round {})",
        opts.connect, joined.shard, joined.n_shards, joined.resume_round
    );
    super::shard::serve_shard_conn(joined.shard as usize, total, aggregator, &weights, &kidx, conn)?;
    eprintln!("[shard] run complete (coordinator sent Shutdown)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_assigns_frees_and_rejoins() {
        let mut l = RegistryLedger::new(3);
        assert_eq!(l.reserve(None), Ok((0, false)));
        assert_eq!(l.reserve(None), Ok((1, false)));
        assert_eq!(l.reserve(Some(2)), Ok((2, false)));
        // full cluster: wildcard and explicit both refused
        assert_eq!(l.reserve(None).unwrap_err().0, RejectCode::ClusterFull);
        assert_eq!(l.reserve(Some(1)).unwrap_err().0, RejectCode::DuplicateWorker);
        assert_eq!(l.reserve(Some(9)).unwrap_err().0, RejectCode::ClusterFull);
        // a drop frees the slot for a rejoin, flagged as such
        l.mark_dropped(1);
        assert_eq!(l.reserve(Some(1)), Ok((1, true)));
        l.mark_dropped(0);
        assert_eq!(l.reserve(None), Ok((0, true)), "wildcard takes the dropped slot");
    }

    #[test]
    fn ledger_unreserve_reopens_the_slot() {
        let mut l = RegistryLedger::new(1);
        assert_eq!(l.reserve(Some(0)), Ok((0, false)));
        l.unreserve(0);
        // the peer never completed its join; the slot must be usable
        assert!(l.reserve(Some(0)).is_ok());
        l.unreserve(9); // out of range: no-op, not a panic
    }

    #[test]
    fn pool_tracks_generations_and_round_counters() {
        // a mem pipe pair stands in for an admitted connection
        let (coord, mut workers) = transport::establish(super::super::ClusterMode::Mem, 1).unwrap();
        let worker_conn = workers.pop().unwrap();
        let mut pool = WorkerPool::new(1, None, None);
        assert_eq!(pool.alive_count(), 0);
        let mut coord = coord;
        pool.install(0, false, coord.pop().unwrap()).unwrap();
        assert_eq!(pool.alive_count(), 1);
        assert_eq!(pool.generation(0), 1);

        // peer answers one envelope then hangs up
        let peer = std::thread::spawn(move || {
            let mut conn = worker_conn;
            let env = conn.recv().unwrap();
            conn.send(&env).unwrap();
            // dropping the conn hangs up
        });
        assert!(pool.send(0, &Message::Shutdown));
        match pool.next(Some(Instant::now() + Duration::from_secs(5))).unwrap() {
            PoolNotice::Msg(0, env) => assert_eq!(env.kind, MsgKind::Shutdown),
            _ => panic!("expected the echoed message"),
        }
        peer.join().unwrap();
        match pool.next(Some(Instant::now() + Duration::from_secs(5))).unwrap() {
            PoolNotice::Down(0) => {}
            _ => panic!("expected the hangup notice"),
        }
        assert_eq!(pool.alive_count(), 0);
        assert!(!pool.send(0, &Message::Shutdown), "sends to a dead slot report failure");
        let (drops, rejoins) = pool.take_round_counters();
        assert_eq!((drops, rejoins), (1, 0));
        assert_eq!(pool.take_round_counters(), (0, 0), "counters drain");
        let stats = pool.into_stats();
        assert_eq!(stats[0].joins, 1);
        assert_eq!(stats[0].drops, 1);
    }
}
