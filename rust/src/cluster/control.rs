//! The round-control plane: the server-side round state machine, with the
//! aggregation math moved out to the sharded plane (`shard` + `router`).
//!
//! A round moves through four typed phases, each driven by protocol
//! messages rather than shared memory:
//!
//! ```text
//!   Sampling ──► Broadcast ──► Collect ──► Aggregate
//!   (fork RNG,   (downlink     (TrainResult (router closes the
//!    pick cohort) payload per   per slot,    shards; Eq. 2 delta
//!                 slot → tasks) any order,   gathers back; control
//!                               close at     folds scalars, advances
//!                               quorum)      the global, evaluates)
//! ```
//!
//! `begin_round` performs Sampling + Broadcast and returns the
//! slot-ordered `TrainTask`s; `accept` consumes `TrainResult`s in ANY
//! arrival order, handing each accepted payload back as a
//! [`RoutedAdd`](super::router::RoutedAdd) for the router to forward to
//! the shard owning its segment; `finish_round` consumes the router's
//! gathered aggregate and performs the strictly slot-ordered SCALAR pass
//! (loss/weight/exec/k telemetry, FLoRA module stacking) so the
//! floating-point reductions are identical to the monolithic `FedRunner`.
//! Per-task RNG streams and per-client compressor state on the
//! participants complete the bitwise-reproducibility story.
//!
//! The Collect barrier is a policy, not a law: under
//! [`RoundPolicy::Quorum`] the round closes as soon as `ceil(q·N_t)`
//! results arrive. Straggler uplinks that land after the close route to
//! the owning shard's `LateBuffer` and fold into the NEXT round's Eq. 2
//! aggregate with the Eq. 3 staleness discount
//! (`fed::staleness::stale_discount`), and slots that outlive the policy
//! timeout are resampled to a replacement client with a fully
//! deterministic re-dispatch stream (`fed::world::resample_rng`).
//! `Quorum { q: 1.0, .. }` with no timeouts firing is bitwise identical
//! to `Sync` — the parity tests in `tests/integration_cluster.rs` enforce
//! it, as they do `--shards N` ≡ `--shards 1`.
//!
//! The control plane owns the global model, the per-client downlink
//! channels (reference + error-feedback compressor), and the evaluation
//! stack; it never runs local training and never touches uplink payload
//! bytes — those flow router → shard.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::compress::{dense_bytes, KindIndex};
use crate::data::{corpus, preference};
use crate::eval::{DpoEvaluator, McEvaluator};
use crate::fed::downlink::{DownWire, DownlinkState};
use crate::fed::session::Session;
use crate::fed::world::{self, WorldSeed};
use crate::fed::{round_robin, EcoConfig, FedConfig, FedOutcome};
use crate::metrics::{sparsity_snapshot, RoundRecord, RunLog};
use crate::runtime::Engine;

use super::journal;
use super::protocol::{DownPayload, TrainResult, TrainTask, UpPayload};
use super::router::{GatheredAgg, RoutedAdd};
use super::shard::{self, Payload};

/// Upper bound on re-dispatches per slot: after this many replacement
/// waves the control plane stops spending downlink bandwidth on the slot
/// and simply waits for quorum from whatever is still in flight.
pub const MAX_REDISPATCH: u32 = 3;

/// How many rounds back the control plane remembers which (round, slot)
/// pairs already contributed to an aggregate, so a racer result arriving
/// after its slot was filled (original vs. replacement) cannot fold a
/// second time. Beyond this horizon the Eq. 3 discount `e^{−β·s}` is
/// below 1e-19 for any realistic β, so a theoretical double fold past it
/// is numerically nil.
pub const FILLED_HORIZON: u64 = 64;

/// When a round may close.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundPolicy {
    /// Block until every slot reports (the PR-1 collect barrier; the
    /// reference semantics shared with the monolithic `FedRunner`).
    Sync,
    /// K-of-N aggregation: close the round once `ceil(q · N_t)` results
    /// arrive; buffer stragglers for the next round's staleness-discounted
    /// fold, and resample slots that outlive `timeout` to a replacement
    /// client (deterministic re-dispatch, at most [`MAX_REDISPATCH`]
    /// waves per slot).
    Quorum {
        /// Quorum fraction q ∈ (0, 1].
        q: f64,
        /// Per-dispatch-wave slot timeout.
        timeout: Duration,
    },
}

impl RoundPolicy {
    /// Results required to close a round of `n_t` slots.
    pub fn quorum_of(&self, n_t: usize) -> usize {
        match self {
            RoundPolicy::Sync => n_t,
            RoundPolicy::Quorum { q, .. } => {
                if n_t == 0 {
                    0
                } else {
                    ((q * n_t as f64).ceil() as usize).clamp(1, n_t)
                }
            }
        }
    }

    /// Task deadline carried in the protocol header, ms (0 = no deadline).
    pub fn deadline_ms(&self) -> u64 {
        match self {
            RoundPolicy::Sync => 0,
            RoundPolicy::Quorum { timeout, .. } => timeout.as_millis() as u64,
        }
    }

    /// The wave timeout, when one exists.
    pub fn slot_timeout(&self) -> Option<Duration> {
        match self {
            RoundPolicy::Sync => None,
            RoundPolicy::Quorum { timeout, .. } => Some(*timeout),
        }
    }
}

/// Which lifecycle phase a `RoundState` is in (enforced at runtime so the
/// message-driven API cannot be called out of order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Tasks handed out, waiting for quorum (all slots, under `Sync`).
    Collect,
    /// Quorum reached; ready for `finish_round`.
    Aggregate,
}

/// The scalar residue of one accepted result: everything `finish_round`'s
/// slot-ordered pass needs AFTER the payload itself has been routed to
/// the aggregation plane.
struct SlotDone {
    n_samples: u32,
    mean_loss: f64,
    k_a: f64,
    k_b: f64,
    exec_s: f64,
    /// True for a sparse-wire upload (the k densities are meaningful).
    sparse: bool,
    /// FLoRA module upload (stacked by the control plane, never sharded —
    /// a restart module merges into the session base, not the Eq. 2 sum).
    module: Option<Vec<f32>>,
}

/// In-flight state of one round (created by `begin_round`).
pub struct RoundState {
    /// Round index.
    pub t: u64,
    /// Cohort size N_t (slots dispatched).
    pub n_t: usize,
    /// Round-robin segment count this round.
    pub n_s: usize,
    /// Collect/Aggregate lifecycle phase.
    pub phase: Phase,
    /// Results required before the round may close.
    pub quorum: usize,
    rec: RoundRecord,
    overhead: f64,
    flora_init: Option<Vec<f32>>,
    loss_signal: (f64, f64),
    done: Vec<Option<SlotDone>>,
    received: usize,
    /// Clients ever assigned to each slot (original first, then
    /// replacements) — the set of legitimate reporters for the slot.
    assignees: Vec<Vec<u32>>,
    /// Per-slot re-dispatch waves spent against the CURRENT worker
    /// capacity (reset by [`RoundState::reopen_waves`] when a worker
    /// rejoins mid-collect).
    attempts: Vec<u32>,
    /// Per-slot count of tasks actually BUILT (broadcast + successful
    /// resample draws) — the upper bound on legitimate reporters, which
    /// is what the duplicate-result guard must compare against
    /// (assignee draws whose owner was down never produced a task).
    tasks_built: Vec<u32>,
    /// Total re-dispatches this round, monotone across wave-budget
    /// resets (feeds the `resampled` metric).
    waves_spent: usize,
    orphaned: usize,
    started: Instant,
    quorum_wait_s: Option<f64>,
}

impl RoundState {
    /// Per-slot compiled-execution seconds (netsim shim input); slots that
    /// have not reported yet count as zero.
    pub fn exec_by_slot(&self) -> Vec<f64> {
        self.done
            .iter()
            .map(|r| r.as_ref().map_or(0.0, |r| r.exec_s))
            .collect()
    }

    /// Results accepted so far.
    pub fn received(&self) -> usize {
        self.received
    }

    /// Slots still waiting for a result.
    pub fn unfilled_slots(&self) -> Vec<usize> {
        (0..self.n_t).filter(|&s| self.done[s].is_none()).collect()
    }

    /// A worker (re)joined mid-collect: grant every unfilled slot a
    /// fresh re-dispatch wave budget so the recovered capacity can be
    /// used — without this, a slot whose [`MAX_REDISPATCH`] waves were
    /// all spent against dead connections could never be dispatched to
    /// the rejoined worker and the round would be stuck waiting on
    /// nothing. Replacement choice stays deterministic: the resample
    /// stream is keyed by `(seed, round, slot, attempt)` and previously
    /// assigned clients remain excluded via the assignee list.
    pub fn reopen_waves(&mut self) {
        for slot in 0..self.n_t {
            if self.done[slot].is_none() {
                self.attempts[slot] = 0;
            }
        }
    }
}

/// The server-side control agent: owns the global model, downlink
/// channels, the evaluation stack, and the round state machine. The
/// Eq. 2/Eq. 3 aggregation math lives in the sharded plane behind the
/// [`Router`](super::router::Router).
pub struct ControlPlane {
    /// Experiment configuration (shared with every participant).
    pub cfg: FedConfig,
    policy: RoundPolicy,
    /// Session-free world kernel (schema, corpus, partition, RNG stream).
    seed: WorldSeed,
    /// Compiled-compute session: `None` on the session-free scale path
    /// (`--preset synthetic`), where evaluation and FLoRA merges are
    /// structurally excluded by the `new()` guards.
    session: Option<Session>,
    dl: Option<DownlinkState>,
    evaluator: McEvaluator,
    dpo_eval: Option<DpoEvaluator>,
    weights: Arc<Vec<f64>>,
    global: Vec<f32>,
    /// (round, slot) pairs that already contributed to some aggregate —
    /// on time or via a late fold — kept for [`FILLED_HORIZON`] rounds so
    /// a racer result (original vs. replacement of a resampled slot)
    /// arriving after its round closed cannot fold a second time.
    filled: HashSet<(u64, u32)>,
    /// Per-client count of STATEFUL downlinks (sparse/f16 deltas) ever
    /// built — the `TrainTask::down_seq` the participant checks so a
    /// delta lost in transit fails loudly instead of silently
    /// desynchronizing the client's reference reconstruction.
    down_seq: Vec<u64>,
    /// Clients whose stateful downlink channel lost a delta in transit
    /// (a task was built — advancing the channel — but its send failed).
    /// Their reconstruction can never be trusted again this run, so they
    /// are excluded from every future dispatch instead of aborting the
    /// run rounds later on the participant's desync guard.
    lost_channel: HashSet<usize>,
    /// Straggler payload bytes admitted toward the aggregation plane's
    /// byte cap since the last round close (global meter — the admission
    /// decision must not depend on the shard map, so `--shards N` stays
    /// bitwise-identical to `--shards 1` even when the cap binds).
    late_bytes: usize,
    /// Stragglers evicted by the global byte cap since the last close.
    late_evicted: usize,
    l0: Option<f64>,
    l_prev: f64,
}

impl ControlPlane {
    /// Mirrors `FedRunner::new`'s RNG fork order exactly (see
    /// `fed::world` module docs). Rejects `Quorum` policies with an
    /// out-of-range fraction, a zero timeout, or a restart-based method
    /// (a late FLoRA module cannot merge into an already-advanced base).
    pub fn new(cfg: FedConfig, policy: RoundPolicy) -> Result<ControlPlane> {
        if let RoundPolicy::Quorum { q, timeout } = policy {
            ensure!(q > 0.0 && q <= 1.0, "quorum fraction must be in (0, 1], got {q}");
            ensure!(!timeout.is_zero(), "slot timeout must be positive");
            ensure!(
                !cfg.method.restarts_lora(),
                "round policy quorum is incompatible with restart-based method {}",
                cfg.method.name()
            );
        }
        if cfg.aggregator != crate::fed::robust::Aggregator::Mean {
            ensure!(
                !cfg.method.restarts_lora(),
                "--aggregator {} is incompatible with restart-based method {} \
                 (a robust statistic over restart modules is not the Eq. 2 path)",
                cfg.aggregator.name(),
                cfg.method.name()
            );
        }
        let synthetic = cfg.preset == "synthetic";
        if synthetic {
            // the session-free scale path has no compiled compute: every
            // code path that would need it must be unreachable by config
            ensure!(cfg.eval_every == 0, "--preset synthetic cannot evaluate (set eval_every 0)");
            ensure!(cfg.target_acc.is_none(), "--preset synthetic cannot evaluate a target");
            ensure!(
                !cfg.method.restarts_lora(),
                "--preset synthetic cannot merge FLoRA modules (method {})",
                cfg.method.name()
            );
            ensure!(!cfg.dpo, "--preset synthetic has no DPO artifacts");
        }
        let mut seed = WorldSeed::build(&cfg)?;
        let session = if synthetic {
            None
        } else {
            Some(Session::from_seed(Arc::new(Engine::new(&cfg.artifacts_dir)?), &seed)?)
        };
        let dl = cfg.eco.filter(|e| e.downlink_sparse).map(|e| {
            DownlinkState::new(
                cfg.n_clients,
                seed.lora_init.clone(),
                e.spars,
                e.encoding,
                seed.kinds.clone(),
                seed.kidx.clone(),
            )
        });
        let evaluator = McEvaluator::new(
            corpus::make_eval_set(&mut seed.rng.fork(5), cfg.eval_items, &seed.ccfg),
            seed.ccfg.seq_tokens,
        );
        let dpo_eval = cfg.dpo.then(|| {
            DpoEvaluator::new(preference::generate_pairs(&mut seed.rng.fork(6), 64, &seed.ccfg))
        });
        let weights = Arc::new(seed.client_weights());
        Ok(ControlPlane {
            global: seed.lora_init.clone(),
            seed,
            session,
            dl,
            evaluator,
            dpo_eval,
            weights,
            down_seq: vec![0; cfg.n_clients],
            lost_channel: HashSet::new(),
            cfg,
            policy,
            filled: HashSet::new(),
            late_bytes: 0,
            late_evicted: 0,
            l0: None,
            l_prev: f64::NAN,
        })
    }

    /// Current global LoRA vector.
    pub fn global_lora(&self) -> &[f32] {
        &self.global
    }

    /// Raw position of the root world RNG stream. Journaled at every
    /// round open so `serve --resume` can prove replay re-advanced the
    /// deterministic sampling/init/batch streams to the exact positions
    /// the crashed coordinator had.
    pub fn rng_state(&self) -> [u64; 4] {
        self.seed.rng.state()
    }

    /// FNV-1a-64 digest of the global LoRA bit pattern (journal
    /// round-close records; proves replay rebuilt the same model).
    pub fn global_digest(&self) -> u64 {
        journal::digest_f32(&self.global)
    }

    /// The round-close policy this control plane runs under.
    pub fn policy(&self) -> RoundPolicy {
        self.policy
    }

    /// Flat LoRA parameter count (router/shard geometry input).
    pub fn lora_total(&self) -> usize {
        self.seed.schema.lora_total
    }

    /// Per-client FedAvg weights, shared with the shard threads for the
    /// staleness-discounted late fold.
    pub fn client_weights(&self) -> Arc<Vec<f64>> {
        self.weights.clone()
    }

    /// Kind-wise index over the flat LoRA vector (shard decode input).
    pub fn kind_index(&self) -> Arc<KindIndex> {
        self.seed.kidx.clone()
    }

    /// Eq. 3 staleness decay β for late folds (EcoConfig's, or its
    /// default when running a non-eco baseline).
    pub fn fold_beta(&self) -> f64 {
        self.cfg.eco.map_or(EcoConfig::default().beta, |e| e.beta)
    }

    /// The parameter count a dense uplink is charged
    /// (`Method::dense_upload_params`).
    pub fn dense_upload_params(&self) -> usize {
        self.cfg.method.dense_upload_params(&self.seed.schema)
    }

    /// The robust statistic every shard of this plane runs
    /// (`FedConfig::aggregator`; router/shard construction input).
    pub fn aggregator(&self) -> crate::fed::robust::Aggregator {
        self.cfg.aggregator
    }

    /// Compress (or materialize) the downlink payload for `ci` and charge
    /// it to `rec.down` — shared by the initial broadcast and timed-out
    /// slot re-dispatch. Returns the payload plus its stateful-downlink
    /// sequence number (`TrainTask::down_seq`; 0 for stateless payloads).
    fn make_downlink(
        &mut self,
        ci: usize,
        n_t: usize,
        loss_signal: (f64, f64),
        flora_init: Option<&[f32]>,
        rec: &mut RoundRecord,
    ) -> Result<(DownPayload, u64)> {
        Ok(if let Some(init) = flora_init {
            // FLoRA re-distributes the stacked modules: accounted as
            // N_t × module even though the restart init itself travels.
            let p = self.cfg.method.dense_download_params(&self.seed.schema, n_t);
            rec.down.add(p, dense_bytes(p));
            (DownPayload::FloraInit(init.to_vec()), 0)
        } else if let Some(dl) = &mut self.dl {
            let b = dl.broadcast(ci, &self.global, loss_signal.0, loss_signal.1, true)?;
            rec.down.add(b.params, b.bytes);
            // the broadcast advanced the server-side reference for `ci`;
            // the sequence number lets the participant prove it applied
            // every predecessor before this delta
            self.down_seq[ci] += 1;
            let payload = match b.wire.expect("broadcast(want_wire=true) returns the message") {
                DownWire::Sparse(x) => DownPayload::SparseWire(x),
                DownWire::DenseF16(x) => DownPayload::DenseF16(x),
            };
            (payload, self.down_seq[ci])
        } else {
            let p = self.cfg.method.dense_download_params(&self.seed.schema, n_t);
            rec.down.add(p, dense_bytes(p));
            (DownPayload::DenseF32(self.global.clone()), 0)
        })
    }

    /// Phases 1+2 (Sampling + Broadcast): pick the cohort, compress each
    /// client's downlink, fork its batch-RNG stream, and emit slot-ordered
    /// `(owner_worker, TrainTask)` pairs. `n_workers` fixes the static
    /// client→worker ownership map (`client mod n_workers`); `alive[w]`
    /// says whether worker `w` currently has a live connection — a slot
    /// whose owner is down gets NO task (building one would advance the
    /// client's stateful downlink channel for bytes that can never be
    /// delivered, poisoning the client against a future rejoin); under
    /// `Quorum` the wave machinery resamples such slots, and a `Sync`
    /// caller must refuse to start the round instead.
    pub fn begin_round(
        &mut self,
        t: u64,
        n_workers: usize,
        alive: &[bool],
    ) -> Result<(RoundState, Vec<(usize, TrainTask)>)> {
        let n_t = self.cfg.clients_per_round.min(self.cfg.n_clients);
        let sampled = self.cfg.sampling.sample(
            self.cfg.n_clients,
            n_t,
            &self.weights,
            t,
            &mut self.seed.rng.fork(1000 + t),
        );
        let n_s = self.cfg.eco.map_or(1, |e| e.n_s.max(1)).min(n_t);

        let mut rec = RoundRecord { round: t as usize, ..Default::default() };
        let loss_signal = match self.l0 {
            Some(l0) => (l0, self.l_prev),
            None => (1.0, 1.0), // round 0: Eq. 4 sits at k_max
        };

        // FLoRA: fresh LoRA init shared by this round's cohort.
        let flora_init = self
            .cfg
            .method
            .restarts_lora()
            .then(|| self.seed.schema.init_lora(&mut self.seed.rng.fork(2000 + t)));

        let deadline_ms = self.policy.deadline_ms();
        let mut overhead = 0.0f64;
        let mut tasks = Vec::with_capacity(n_t);
        let mut tasks_built = vec![0u32; n_t];
        for (slot, &ci) in sampled.iter().enumerate() {
            let owner = ci % n_workers.max(1);
            if !alive.get(owner).copied().unwrap_or(true)
                || self.lost_channel.contains(&ci)
            {
                continue; // owner down or channel lost: no task, no
                          // stateful-downlink advance
            }
            tasks_built[slot] = 1;
            let t0 = Instant::now();
            let (down, down_seq) =
                self.make_downlink(ci, n_t, loss_signal, flora_init.as_deref(), &mut rec)?;
            overhead += t0.elapsed().as_secs_f64();

            let brng = self.seed.rng.fork(world::batch_salt(self.cfg.dpo, t, ci));
            let seg = round_robin::segment_for(slot, t as usize, n_s);
            tasks.push((
                ci % n_workers.max(1),
                TrainTask {
                    round: t,
                    slot: slot as u32,
                    client: ci as u32,
                    segment: seg as u32,
                    n_s: n_s as u32,
                    l0: loss_signal.0,
                    l_prev: loss_signal.1,
                    rng_state: brng.state(),
                    deadline_ms,
                    down_seq,
                    down,
                },
            ));
        }

        let rs = RoundState {
            t,
            n_t,
            n_s,
            // an empty cohort has nothing to collect
            phase: if n_t == 0 { Phase::Aggregate } else { Phase::Collect },
            quorum: self.policy.quorum_of(n_t),
            rec,
            overhead,
            flora_init,
            loss_signal,
            done: (0..n_t).map(|_| None).collect(),
            received: 0,
            assignees: sampled.iter().map(|&ci| vec![ci as u32]).collect(),
            attempts: vec![0; n_t],
            tasks_built,
            waves_spent: 0,
            orphaned: 0,
            started: Instant::now(),
            quorum_wait_s: None,
        };
        Ok((rs, tasks))
    }

    /// Phase 3 (Collect): feed one `TrainResult` for the CURRENT round
    /// (any arrival order). The scalar residue stays in the round state;
    /// the payload comes back as a [`RoutedAdd`] for the router to
    /// forward to the owning shard (`None` for FLoRA module uploads,
    /// which the control plane stacks itself, and for orphaned racers).
    /// The round may close — check `rs.phase` — once the quorum is
    /// reached. A second result for a resampled slot (the original
    /// assignee racing its replacement) is counted as orphaned and
    /// discarded; results for earlier rounds belong in
    /// [`ControlPlane::accept_late`] instead.
    pub fn accept(&mut self, rs: &mut RoundState, res: TrainResult) -> Result<Option<RoutedAdd>> {
        ensure!(rs.phase == Phase::Collect, "accept called outside Collect");
        ensure!(res.round == rs.t, "result for round {} during round {}", res.round, rs.t);
        let slot = res.slot as usize;
        ensure!(slot < rs.n_t, "result slot {slot} out of range");
        ensure!((res.segment as usize) < rs.n_s, "result segment {} out of range", res.segment);
        let ci = res.client as usize;
        ensure!(ci < self.cfg.n_clients, "result for unknown client {ci}");
        ensure!(
            rs.assignees[slot].contains(&res.client),
            "client {ci} was never assigned slot {slot}"
        );
        // the participant derived its world independently — its FedAvg
        // weight must agree with the control plane's partition
        ensure!(
            res.n_samples as f64 == self.weights[ci],
            "weight mismatch for client {ci}: worker says {}, partition says {}",
            res.n_samples,
            self.weights[ci]
        );
        if rs.done[slot].is_some() {
            // a resampled slot legitimately reports more than once: the
            // first arrival won the slot, the rest are orphans. Judged by
            // the count of tasks actually built for the slot — not the
            // wave counter (reset by `reopen_waves`) and not the assignee
            // list (which also records dead-owner draws that never became
            // a task) — so a second result from a slot's ONLY task is
            // still the protocol violation it always was
            ensure!(rs.tasks_built[slot] > 1, "duplicate result for slot {slot}");
            rs.orphaned += 1;
            return Ok(None);
        }

        let lora_total = self.seed.schema.lora_total;
        let weight = res.n_samples as f64;
        let (routed, module, sparse) = match res.up {
            UpPayload::SparseWire(bytes) => (
                Some(RoutedAdd {
                    slot: res.slot,
                    segment: res.segment as usize,
                    weight,
                    payload: Payload::Wire(bytes),
                }),
                None,
                true,
            ),
            UpPayload::DenseUpdate(v) => {
                ensure!(v.len() == lora_total, "dense update length");
                (
                    Some(RoutedAdd {
                        slot: res.slot,
                        segment: res.segment as usize,
                        weight,
                        payload: Payload::Dense(v),
                    }),
                    None,
                    false,
                )
            }
            UpPayload::DenseModule(m) => {
                ensure!(m.len() == lora_total, "dense module length");
                ensure!(
                    self.cfg.method.restarts_lora(),
                    "module upload from a non-restarting method"
                );
                (None, Some(m), false)
            }
        };
        rs.done[slot] = Some(SlotDone {
            n_samples: res.n_samples,
            mean_loss: res.mean_loss,
            k_a: res.k_a,
            k_b: res.k_b,
            exec_s: res.exec_s,
            sparse,
            module,
        });
        rs.received += 1;
        if rs.received >= rs.quorum {
            rs.phase = Phase::Aggregate;
            if rs.quorum_wait_s.is_none() {
                rs.quorum_wait_s = Some(rs.started.elapsed().as_secs_f64());
            }
        }
        Ok(routed)
    }

    /// A built task carrying a stateful downlink could not be handed to
    /// the transport (the owning worker died between the task build and
    /// the send): the server-side channel advanced for a delta that
    /// never left, so the client's reconstruction is unrecoverable this
    /// run. Excludes the client from all future dispatch — the run
    /// degrades by one client instead of aborting rounds later on the
    /// participant's desync guard. No-op for stateless downlink
    /// configurations (nothing server-side advanced).
    pub fn downlink_lost(&mut self, client: u32) {
        if self.dl.is_some() {
            self.lost_channel.insert(client as usize);
        }
    }

    /// Vet a straggler result from an ALREADY-CLOSED round. Returns the
    /// result for the router to buffer on the owning shard, or `None`
    /// when it must be discarded: unknown client, a slot that already
    /// contributed to an aggregate (e.g. the losing racer of a resampled
    /// slot), or an arrival past the global straggler byte cap
    /// (`shard::LATE_BUFFER_MAX_BYTES`) — metered HERE, before sharding,
    /// so the eviction decision is identical at every shard count. The
    /// meter counts vetted arrivals, a deterministic upper bound on what
    /// the shards actually keep (per-shard dedup may drop a few more).
    /// Buffer-level dedup stays with the shard's `LateBuffer`.
    pub fn accept_late(&mut self, res: TrainResult) -> Option<TrainResult> {
        let ci = res.client as usize;
        if ci >= self.cfg.n_clients || self.filled.contains(&(res.stale_from_round, res.slot)) {
            return None;
        }
        let cost = shard::late_payload_bytes(&res);
        if self.late_bytes + cost > shard::LATE_BUFFER_MAX_BYTES {
            self.late_evicted += 1;
            return None;
        }
        self.late_bytes += cost;
        Some(res)
    }

    /// Stragglers evicted by the global admission byte cap since the
    /// last round close (tested directly; surfaced per round in
    /// `RoundRecord::late_evicted`).
    pub fn late_evicted(&self) -> usize {
        self.late_evicted
    }

    /// Re-dispatch a timed-out slot to a deterministically-chosen
    /// replacement client: the replacement and its batch stream are drawn
    /// from `fed::world::resample_rng(seed, t, slot, attempt)`, which
    /// never touches the root RNG — a quorum run in which no slot ever
    /// times out therefore stays bitwise identical to the sync path.
    /// Returns `None` once the slot has exhausted [`MAX_REDISPATCH`]
    /// waves (the round then waits for quorum from what is in flight),
    /// and also when the drawn replacement's owning worker is down
    /// (`alive`, as in [`ControlPlane::begin_round`]) — the wave is
    /// spent, the client's channel stays untouched, and the next wave
    /// draws a different replacement.
    pub fn resample_slot(
        &mut self,
        rs: &mut RoundState,
        slot: usize,
        n_workers: usize,
        alive: &[bool],
    ) -> Result<Option<(usize, TrainTask)>> {
        ensure!(rs.phase == Phase::Collect, "resample outside Collect");
        ensure!(slot < rs.n_t, "resample slot {slot} out of range");
        ensure!(rs.done[slot].is_none(), "resample of a slot that already reported");
        if rs.attempts[slot] >= MAX_REDISPATCH {
            return Ok(None);
        }
        rs.attempts[slot] += 1;
        rs.waves_spent += 1;
        let mut rrng = world::resample_rng(self.cfg.seed, rs.t, slot as u32, rs.attempts[slot]);

        // candidates: clients not already tied to this round (sampled,
        // completed, or previously drawn as a replacement) whose
        // downlink channel is still intact. O(excluded log excluded),
        // NOT O(population): the historical code materialized the full
        // candidate list; since that list was exactly "ascending indices
        // minus the exclusion set", drawing its r-th element is the
        // r-th non-excluded index — same `below(count)` draw, same
        // client, at 10⁻⁵ of the cost when n_clients is 10⁵–10⁶.
        let mut excluded: Vec<u32> = self
            .lost_channel
            .iter()
            .map(|&c| c as u32)
            .chain(rs.assignees.iter().flatten().copied())
            .collect();
        excluded.sort_unstable();
        excluded.dedup();
        let n_candidates = self.cfg.n_clients - excluded.len();
        let ci = if n_candidates == 0 {
            // the whole population is in flight: re-dispatch the original
            rs.assignees[slot][0]
        } else {
            let mut v = rrng.below(n_candidates) as u32;
            for &e in &excluded {
                if e <= v {
                    v += 1;
                } else {
                    break;
                }
            }
            v
        } as usize;

        let owner = ci % n_workers.max(1);
        if !alive.get(owner).copied().unwrap_or(true) || self.lost_channel.contains(&ci) {
            // keep the draw in the exclusion list so the next wave moves
            // on, but never advance the client's downlink channel toward
            // a connection that does not exist (the lost-channel arm only
            // triggers via the all-assigned fallback above)
            rs.assignees[slot].push(ci as u32);
            return Ok(None);
        }

        let t0 = Instant::now();
        let (down, down_seq) = self.make_downlink(ci, rs.n_t, rs.loss_signal, None, &mut rs.rec)?;
        rs.overhead += t0.elapsed().as_secs_f64();

        let brng = rrng.fork(world::batch_salt(self.cfg.dpo, rs.t, ci));
        let seg = round_robin::segment_for(slot, rs.t as usize, rs.n_s);
        rs.tasks_built[slot] += 1;
        rs.assignees[slot].push(ci as u32);
        Ok(Some((
            ci % n_workers.max(1),
            TrainTask {
                round: rs.t,
                slot: slot as u32,
                client: ci as u32,
                segment: seg as u32,
                n_s: rs.n_s as u32,
                l0: rs.loss_signal.0,
                l_prev: rs.loss_signal.1,
                rng_state: brng.state(),
                deadline_ms: self.policy.deadline_ms(),
                down_seq,
                down,
            },
        )))
    }

    /// Phase 4 (Aggregate): consume the aggregation plane's gathered
    /// Eq. 2 delta, run the strictly slot-ordered scalar pass (loss,
    /// weights, k telemetry, FLoRA module stacking), advance the global
    /// model, record telemetry, and evaluate on schedule. Returns the
    /// round record plus — after a FLoRA merge — the new base every
    /// participant must sync to.
    pub fn finish_round(
        &mut self,
        mut rs: RoundState,
        agg: GatheredAgg,
    ) -> Result<(RoundRecord, Option<Vec<f32>>)> {
        ensure!(rs.phase == Phase::Aggregate, "finish_round before quorum reached");
        let t = rs.t;
        let lora_total = self.seed.schema.lora_total;
        ensure!(
            agg.delta.len() == lora_total,
            "gathered delta length {} != lora_total {lora_total}",
            agg.delta.len()
        );
        let mut rec = rs.rec;
        let mut flora_modules: Vec<(Vec<f32>, f64)> = Vec::new();
        let mut loss_acc = 0.0f64;
        let mut weight_acc = 0.0f64;
        let mut exec_total = 0.0f64;

        let t1 = Instant::now();
        for slot in 0..rs.n_t {
            let Some(done) = rs.done[slot].take() else {
                continue; // straggler: its uplink folds into a later round
            };
            self.filled.insert((t, slot as u32));
            let w = done.n_samples as f64;
            loss_acc += done.mean_loss * w;
            weight_acc += w;
            exec_total += done.exec_s;
            if done.sparse {
                rec.k_a = done.k_a;
                rec.k_b = done.k_b;
            }
            if let Some(module) = done.module {
                let p = self.cfg.method.dense_upload_params(&self.seed.schema);
                rec.up.add(p, dense_bytes(p));
                flora_modules.push((module, w));
            }
        }

        // ---- aggregation-plane tallies --------------------------------------
        rec.up.merge(&agg.stats.up);
        rec.late_folds = agg.stats.late_folds;
        rec.aggregator = self.cfg.aggregator.name();
        rec.clients_trimmed = agg.stats.robust.trimmed;
        rec.clip_applied = agg.stats.robust.clipped;
        self.filled.extend(agg.folded.iter().copied());
        // forget aggregates old enough that any racer would fold with a
        // numerically-nil discount anyway
        self.filled.retain(|&(r, _)| r + FILLED_HORIZON >= t);

        // ---- global advance (Eq. 2 delta came gathered from the shards) ----
        let mut base_sync = None;
        if self.cfg.method.restarts_lora() {
            // restart methods are rejected for --preset synthetic in new()
            let session = self
                .session
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("FLoRA merge requires a session"))?;
            if self.cfg.eco.is_some() {
                let mut module = rs.flora_init.take().expect("restart round has flora_init");
                for (m, d) in module.iter_mut().zip(&agg.delta) {
                    *m += *d;
                }
                session.merge_lora(&module, 1.0)?;
            } else {
                let w_total: f64 = flora_modules.iter().map(|(_, w)| w).sum();
                for (module, w) in &flora_modules {
                    session.merge_lora(module, (*w / w_total.max(1.0)) as f32)?;
                }
            }
            self.global = self.seed.lora_init.clone();
            // participants' frozen bases must follow the merge
            base_sync = Some(session.base_host().to_vec());
        } else {
            for (g, d) in self.global.iter_mut().zip(&agg.delta) {
                *g += *d;
            }
        }
        rs.overhead += t1.elapsed().as_secs_f64();

        // ---- telemetry ------------------------------------------------------
        let round_loss = loss_acc / weight_acc.max(1.0);
        if self.l0.is_none() {
            self.l0 = Some(round_loss);
        }
        self.l_prev = round_loss;
        rec.global_loss = round_loss;
        rec.overhead_s = rs.overhead;
        rec.compute_s = exec_total / rs.received.max(1) as f64;
        rec.cohort = rs.n_t;
        rec.stragglers = rs.n_t - rs.received;
        rec.resampled = rs.waves_spent;
        rec.orphaned += rs.orphaned + agg.stats.orphaned;
        rec.quorum_wait_s = rs.quorum_wait_s.unwrap_or(0.0);
        rec.shards = agg.shards;
        rec.shard_agg_ms_max = agg.shard_agg_s_max * 1e3;
        rec.router_queue_max = agg.queue_max;
        rec.shard_tx_bytes = agg.shard_tx_bytes;
        rec.shard_rx_bytes = agg.shard_rx_bytes;
        rec.shard_rtt_ms_max = agg.shard_rtt_ms_max;
        // the shards just drained their buffers (fold_into takes every
        // entry), so the global admission meter starts the next round at 0
        rec.late_evicted = std::mem::take(&mut self.late_evicted) + agg.late_evicted;
        self.late_bytes = 0;
        rec.seg_uncovered = agg.covered.iter().filter(|&&c| !c).count();
        let snap = sparsity_snapshot(&self.global, &self.seed.kinds);
        rec.gini_a = snap.gini_a;
        rec.gini_b = snap.gini_b;

        let eval_now = self.cfg.target_acc.is_some()
            || (self.cfg.eval_every > 0
                && (t as usize % self.cfg.eval_every == self.cfg.eval_every - 1
                    || t as usize + 1 == self.cfg.rounds));
        if eval_now {
            let session = self
                .session
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("evaluation requires a session"))?;
            rec.eval_acc = Some(self.evaluator.accuracy(session, &self.global)?);
        }
        Ok((rec, base_sync))
    }

    /// Final evaluation + outcome assembly (mirrors `FedRunner::run`'s
    /// tail). On the session-free synthetic path there is no compiled
    /// eval graph, so `final_acc` is NaN (the run's value is its scale
    /// and parity telemetry, not task accuracy).
    pub fn outcome(&self, log: RunLog, reached_target_at: Option<usize>) -> Result<FedOutcome> {
        let final_acc = match &self.session {
            Some(s) => self.evaluator.accuracy(s, &self.global)?,
            None => f64::NAN,
        };
        let final_margin = match (&self.dpo_eval, &self.session) {
            (Some(ev), Some(s)) => Some(ev.mean_margin(s, &self.global, self.cfg.dpo_beta)?),
            _ => None,
        };
        Ok(FedOutcome {
            final_lora: self.global.clone(),
            final_acc,
            final_margin,
            reached_target_at,
            log,
        })
    }

    /// Guard against mixed-phase misuse from the runner loop.
    pub fn ensure_collected(&self, rs: &RoundState) -> Result<()> {
        if rs.phase != Phase::Aggregate {
            bail!(
                "round {}: only {}/{} results collected (quorum {})",
                rs.t,
                rs.received,
                rs.n_t,
                rs.quorum
            );
        }
        Ok(())
    }
}
