//! The deployment handshake: how an externally-spawned `ecolora worker`
//! (protocol v3) or `ecolora shard` (protocol v4) process becomes a
//! registered peer of an `ecolora serve` coordinator.
//!
//! Sequence (normative wire spec: docs/PROTOCOL.md §Handshake):
//!
//! ```text
//!   worker / shard                 coordinator
//!     │ ── Join {token, digest, ──►  validate, in order:
//!     │    id?, build}                1. envelope version (framing layer)
//!     │    (or ShardJoin)             2. auth token (constant-time)
//!     │                               3. config digest
//!     │                               4. slot reservation (role-specific)
//!     │ ◄── Welcome {id, n, round} ─  … or Reject {code, reason} + close
//! ```
//!
//! Both roles share the token/digest validation and the `Welcome` /
//! `Reject` answers; only the reservation policy differs — [`admit`]
//! takes one reservation closure pair per role and dispatches on the
//! first message's kind. For a shard peer the `Welcome.n_workers` field
//! carries the SHARD count (each role only ever sees its own plane's
//! slot total).
//!
//! Version skew never reaches this module: a peer speaking a different
//! protocol version fails at `Envelope::decode` (the framing layer) with
//! a dedicated "protocol version mismatch" error, and the coordinator
//! closes the socket. Everything else — bad token, config divergence,
//! duplicate worker id, a full cluster, or a first message that is not a
//! `Join` — is answered with an explicit [`Reject`](Message::Reject)
//! before the close, so the operator on the worker side sees *why*.
//!
//! A failed or abandoned handshake must never poison coordinator round
//! state: [`admit`] touches nothing but the one connection and the
//! caller-supplied reservation closure, and the registry drops the
//! connection on any error — enforced by the reject-path tests in
//! `tests/integration_deploy.rs`.

use std::fmt;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::protocol::{Message, RejectCode, ANY_SHARD, ANY_WORKER};
use super::transport::{Conn, TcpConn};

/// Frame cap applied to a connection while its peer is unauthenticated:
/// a `Join` is a few hundred bytes, so anything bigger is garbage (and a
/// pre-auth allocation vector). Restored to the protocol default after
/// `Welcome`.
pub const JOIN_FRAME_CAP: usize = 64 * 1024;

/// How long the coordinator waits for each handshake message before
/// dropping a silent connection (a peer that connects and says nothing
/// must not stall the registry).
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Hard cap on the shared-secret length (sanity, not security).
pub const MAX_TOKEN_LEN: usize = 512;

/// The deployment's shared secret. Debug/Display never print the bytes.
#[derive(Clone)]
pub struct AuthToken(Vec<u8>);

impl fmt::Debug for AuthToken {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AuthToken(<{} bytes, redacted>)", self.0.len())
    }
}

impl AuthToken {
    /// Build from raw secret bytes (must be non-empty and at most
    /// [`MAX_TOKEN_LEN`] bytes after trimming ASCII whitespace).
    pub fn new(raw: impl AsRef<[u8]>) -> Result<AuthToken> {
        let trimmed: Vec<u8> = {
            let b = raw.as_ref();
            let start = b.iter().position(|c| !c.is_ascii_whitespace()).unwrap_or(b.len());
            let end = b.iter().rposition(|c| !c.is_ascii_whitespace()).map_or(start, |e| e + 1);
            b[start..end].to_vec()
        };
        if trimmed.is_empty() {
            bail!("auth token is empty (whitespace does not count)");
        }
        if trimmed.len() > MAX_TOKEN_LEN {
            bail!("auth token is {} bytes; the cap is {MAX_TOKEN_LEN}", trimmed.len());
        }
        Ok(AuthToken(trimmed))
    }

    /// Resolve the CLI spelling: `--token-file` (read + trim) wins over
    /// an inline `--token`; providing neither is an error — deployment
    /// auth is not optional.
    pub fn from_cli(inline: Option<&str>, file: Option<&str>) -> Result<AuthToken> {
        match (file, inline) {
            (Some(path), _) => {
                let raw = std::fs::read(path)
                    .with_context(|| format!("reading --token-file {path}"))?;
                AuthToken::new(raw).with_context(|| format!("--token-file {path}"))
            }
            (None, Some(tok)) => AuthToken::new(tok).context("--token"),
            (None, None) => bail!(
                "multi-host deployment requires a shared secret: pass --token-file <path> \
                 (preferred; keeps the secret out of `ps`) or --token <string>"
            ),
        }
    }

    /// The secret bytes (what `Join` carries on the wire).
    pub fn bytes(&self) -> &[u8] {
        &self.0
    }

    /// Constant-time-style comparison: the scan length depends only on
    /// the longer input, never on where the first mismatch sits.
    pub fn matches(&self, presented: &[u8]) -> bool {
        let a = &self.0;
        let n = a.len().max(presented.len());
        let mut acc = (a.len() != presented.len()) as u8;
        for i in 0..n {
            let x = a.get(i).copied().unwrap_or(0);
            let y = presented.get(i).copied().unwrap_or(0);
            acc |= x ^ y;
        }
        acc == 0
    }
}

/// What the coordinator requires of every joiner.
pub struct HandshakeSpec {
    /// The deployment's shared secret.
    pub token: AuthToken,
    /// `FedConfig::digest()` of the coordinator's run configuration.
    pub config_digest: u64,
    /// Total worker slots (echoed in a worker's `Welcome`).
    pub n_workers: usize,
    /// Remote aggregation-shard slots (echoed in a shard's `Welcome`);
    /// 0 when the aggregation plane runs in-process and shard joins are
    /// refused outright.
    pub n_shards: usize,
}

/// A `Join` the coordinator refused (the worker-side error: carries the
/// coordinator's `Reject`). `ecolora worker` maps this onto its own exit
/// code so scripts can tell "refused" from "crashed".
#[derive(Debug, Clone)]
pub struct Rejected {
    /// Machine-readable refusal category.
    pub code: RejectCode,
    /// Human-readable refusal detail from the coordinator.
    pub reason: String,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "coordinator rejected join ({}): {}", self.code.name(), self.reason)
    }
}

impl std::error::Error for Rejected {}

/// Outcome of one server-side admission attempt.
#[derive(Debug)]
pub enum Admission {
    /// Worker peer authenticated and reserved a slot; install its
    /// connection into the worker pool.
    Admitted {
        /// Assigned worker id.
        worker: u32,
        /// True when the slot belonged to a previously-dropped worker
        /// (this connection is a rejoin).
        rejoin: bool,
    },
    /// Shard peer authenticated and reserved an aggregation slot;
    /// install its connection into the router's remote fan-out.
    AdmittedShard {
        /// Assigned shard id.
        shard: u32,
        /// True when the slot belonged to a previously-dropped shard.
        rejoin: bool,
    },
    /// Peer was answered with a `Reject` and must be dropped.
    Rejected(RejectCode),
}

/// Deliver the `Welcome` and restore steady-state transport settings;
/// any failure in between means this connection is unusable, so the
/// caller must roll the reservation back either way (a peer that did
/// receive the Welcome will find its slot Dropped and simply rejoin).
fn deliver_welcome(conn: &mut TcpConn, id: u32, n_slots: u32, resume_round: u64) -> Result<()> {
    conn.send(&Message::Welcome { worker: id, n_workers: n_slots, resume_round }.to_envelope())
        .and_then(|()| {
            conn.clear_frame_cap();
            conn.set_read_timeout(None)
        })
}

/// Server side: run the admission protocol on a freshly-accepted
/// connection. `reserve` / `reserve_shard` are the registry's
/// id-assignment policies for the two peer roles — called only after
/// token and config checks pass, each either reserves a slot
/// (`Ok((id, rejoin))`) or names the refusal; `unreserve` /
/// `unreserve_shard` roll the reservation back if the `Welcome` cannot
/// be delivered (so a peer that dies mid-handshake never leaks a slot).
/// A coordinator whose aggregation plane runs in-process passes a
/// `reserve_shard` that refuses with [`RejectCode::ClusterFull`].
///
/// Returns `Err` only for connection-level failures (silent peer, early
/// disconnect, version skew, corrupt frame); the caller drops the
/// connection either way, but an `Err` never sent a `Reject`.
pub fn admit(
    conn: &mut TcpConn,
    spec: &HandshakeSpec,
    reserve: impl FnOnce(Option<u32>) -> std::result::Result<(u32, bool), (RejectCode, String)>,
    unreserve: impl FnOnce(u32),
    reserve_shard: impl FnOnce(Option<u32>) -> std::result::Result<(u32, bool), (RejectCode, String)>,
    unreserve_shard: impl FnOnce(u32),
    resume_round: u64,
) -> Result<Admission> {
    conn.set_frame_cap(JOIN_FRAME_CAP);
    conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let env = conn.recv().context("handshake: waiting for Join")?;
    let msg = Message::from_envelope(&env).context("handshake: parsing Join")?;
    let kind = msg.kind();
    let (token, config_digest, requested_raw, build, is_shard) = match msg {
        Message::Join { token, config_digest, requested_worker, build } => {
            (token, config_digest, requested_worker, build, false)
        }
        Message::ShardJoin { token, config_digest, requested_shard, build } => {
            (token, config_digest, requested_shard, build, true)
        }
        _ => {
            let code = RejectCode::Malformed;
            let reason = format!("expected Join or ShardJoin as the first message, got {kind:?}");
            let _ = conn.send(&Message::Reject { code, reason }.to_envelope());
            return Ok(Admission::Rejected(code));
        }
    };
    if !spec.token.matches(&token) {
        // never echo anything token-derived back to an unauthenticated peer
        let code = RejectCode::BadToken;
        let _ = conn.send(
            &Message::Reject { code, reason: "auth token mismatch".into() }.to_envelope(),
        );
        return Ok(Admission::Rejected(code));
    }
    if config_digest != spec.config_digest {
        let code = RejectCode::ConfigMismatch;
        let role = if is_shard { "shard" } else { "worker" };
        let reason = format!(
            "config digest {config_digest:016x} != coordinator's {:016x} \
             ({role} build {build:?}, coordinator build {:?}); launch both sides with \
             identical run flags — see docs/DEPLOYMENT.md",
            spec.config_digest,
            crate::version(),
        );
        let _ = conn.send(&Message::Reject { code, reason }.to_envelope());
        return Ok(Admission::Rejected(code));
    }
    // ANY_SHARD and ANY_WORKER share the wildcard bit pattern
    let requested = (requested_raw != ANY_WORKER).then_some(requested_raw);
    if is_shard {
        match reserve_shard(requested) {
            Ok((shard, rejoin)) => {
                if let Err(e) = deliver_welcome(conn, shard, spec.n_shards as u32, resume_round) {
                    unreserve_shard(shard);
                    return Err(e).context("handshake: completing shard admission");
                }
                Ok(Admission::AdmittedShard { shard, rejoin })
            }
            Err((code, reason)) => {
                let _ = conn.send(&Message::Reject { code, reason }.to_envelope());
                Ok(Admission::Rejected(code))
            }
        }
    } else {
        match reserve(requested) {
            Ok((worker, rejoin)) => {
                if let Err(e) = deliver_welcome(conn, worker, spec.n_workers as u32, resume_round)
                {
                    unreserve(worker);
                    return Err(e).context("handshake: completing admission");
                }
                Ok(Admission::Admitted { worker, rejoin })
            }
            Err((code, reason)) => {
                let _ = conn.send(&Message::Reject { code, reason }.to_envelope());
                Ok(Admission::Rejected(code))
            }
        }
    }
}

/// What a successful client-side join learns from the coordinator.
#[derive(Debug, Clone, Copy)]
pub struct Joined {
    /// Assigned worker id.
    pub worker: u32,
    /// Total worker slots in the deployment.
    pub n_workers: u32,
    /// Round the coordinator dispatches next (0 on a fresh run).
    pub resume_round: u64,
}

/// Client side: authenticate against a coordinator on a freshly-dialed
/// connection. A coordinator `Reject` surfaces as the typed
/// [`Rejected`] error (retrying is pointless); connection-level failures
/// surface as ordinary errors (retrying may help).
pub fn join(
    conn: &mut TcpConn,
    token: &AuthToken,
    config_digest: u64,
    requested_worker: Option<u32>,
) -> Result<Joined> {
    conn.set_frame_cap(JOIN_FRAME_CAP);
    conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    conn.send(
        &Message::Join {
            token: token.bytes().to_vec(),
            config_digest,
            requested_worker: requested_worker.unwrap_or(ANY_WORKER),
            build: crate::version().to_string(),
        }
        .to_envelope(),
    )
    .context("handshake: sending Join")?;
    let env = conn.recv().context("handshake: waiting for Welcome")?;
    match Message::from_envelope(&env).context("handshake: parsing Welcome")? {
        Message::Welcome { worker, n_workers, resume_round } => {
            conn.clear_frame_cap();
            conn.set_read_timeout(None)?;
            Ok(Joined { worker, n_workers, resume_round })
        }
        Message::Reject { code, reason } => Err(Rejected { code, reason }.into()),
        other => bail!("handshake: expected Welcome or Reject, got {:?}", other.kind()),
    }
}

/// What a successful client-side shard join learns from the coordinator.
#[derive(Debug, Clone, Copy)]
pub struct JoinedShard {
    /// Assigned shard id.
    pub shard: u32,
    /// Total remote aggregation-shard slots in the deployment.
    pub n_shards: u32,
    /// Round the coordinator dispatches next (0 on a fresh run).
    pub resume_round: u64,
}

/// Client side: authenticate an `ecolora shard` process against a
/// coordinator on a freshly-dialed connection. Mirrors [`join`] with a
/// `ShardJoin` first message; the `Welcome.n_workers` field carries the
/// shard count for this role.
pub fn join_shard(
    conn: &mut TcpConn,
    token: &AuthToken,
    config_digest: u64,
    requested_shard: Option<u32>,
) -> Result<JoinedShard> {
    conn.set_frame_cap(JOIN_FRAME_CAP);
    conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    conn.send(
        &Message::ShardJoin {
            token: token.bytes().to_vec(),
            config_digest,
            requested_shard: requested_shard.unwrap_or(ANY_SHARD),
            build: crate::version().to_string(),
        }
        .to_envelope(),
    )
    .context("handshake: sending ShardJoin")?;
    let env = conn.recv().context("handshake: waiting for Welcome")?;
    match Message::from_envelope(&env).context("handshake: parsing Welcome")? {
        Message::Welcome { worker, n_workers, resume_round } => {
            conn.clear_frame_cap();
            conn.set_read_timeout(None)?;
            Ok(JoinedShard { shard: worker, n_shards: n_workers, resume_round })
        }
        Message::Reject { code, reason } => Err(Rejected { code, reason }.into()),
        other => bail!("handshake: expected Welcome or Reject, got {:?}", other.kind()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_trims_and_validates() {
        let t = AuthToken::new("  hunter2\n").unwrap();
        assert_eq!(t.bytes(), b"hunter2");
        assert!(AuthToken::new("   \n\t ").is_err(), "whitespace-only is empty");
        assert!(AuthToken::new("").is_err());
        assert!(AuthToken::new(vec![b'x'; MAX_TOKEN_LEN + 1]).is_err());
        assert!(AuthToken::new(vec![b'x'; MAX_TOKEN_LEN]).is_ok());
    }

    #[test]
    fn token_matching_is_exact() {
        let t = AuthToken::new("correct horse").unwrap();
        assert!(t.matches(b"correct horse"));
        assert!(!t.matches(b"correct horsf"));
        assert!(!t.matches(b"correct hors"));
        assert!(!t.matches(b"correct horse "), "matching is post-trim exact bytes");
        assert!(!t.matches(b""));
    }

    #[test]
    fn token_debug_never_leaks_the_secret() {
        let t = AuthToken::new("super-secret-value").unwrap();
        let dbg = format!("{t:?}");
        assert!(!dbg.contains("super-secret-value"), "{dbg}");
        assert!(dbg.contains("redacted"), "{dbg}");
    }

    #[test]
    fn token_from_cli_prefers_file_and_requires_one_source() {
        let dir = std::env::temp_dir().join("ecolora-handshake-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("token.txt");
        std::fs::write(&path, "file-secret\n").unwrap();
        let p = path.to_str().unwrap();
        assert_eq!(AuthToken::from_cli(Some("inline"), Some(p)).unwrap().bytes(), b"file-secret");
        assert_eq!(AuthToken::from_cli(Some("inline"), None).unwrap().bytes(), b"inline");
        assert!(AuthToken::from_cli(None, None).is_err());
        assert!(AuthToken::from_cli(None, Some("/no/such/token/file")).is_err());
    }

    #[test]
    fn rejected_error_formats_the_code() {
        let r = Rejected { code: RejectCode::BadToken, reason: "auth token mismatch".into() };
        let s = r.to_string();
        assert!(s.contains("bad_token") && s.contains("auth token mismatch"), "{s}");
    }
}
