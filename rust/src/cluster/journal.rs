//! Append-only coordinator round journal (crash durability).
//!
//! The control plane appends one checksummed, length-framed record at
//! every state transition — round open (cohort draw + RNG stream
//! position), task dispatch, uplink accepted / late-buffered, resample,
//! downlink loss, quorum reopen, round close — so that a coordinator
//! killed mid-run can be restarted with `ecolora serve --journal <path>
//! --resume` and replay itself back to the exact control-plane state of
//! the crash, bit for bit (docs/PROTOCOL.md §8 is the normative on-disk
//! spec).
//!
//! The framing deliberately mirrors the frozen envelope discipline of
//! [`super::protocol`]: 2-byte magic, version byte, kind byte, FNV-1a-32
//! checksum over everything except the checksum field itself, explicit
//! little-endian payload length. Two properties fall out:
//!
//! * **A torn final record is dropped, not fatal.** A crash mid-append
//!   leaves a record whose frame extends past end-of-file; replay stops
//!   cleanly in front of it. Only a *complete* record with a bad
//!   checksum/magic/version is a typed [`JournalError`] naming the byte
//!   offset — that is disk corruption, not a crash artifact.
//! * **The write path stays off the aggregation hot path.** Appends go
//!   through one reusable scratch buffer into a [`std::io::BufWriter`]
//!   (zero heap allocations in steady state — the gated
//!   `alloc_discipline` suite proves it) and the fsync cadence is an
//!   operator policy ([`SyncPolicy`]), never per-record by default.
//!
//! Durability model: the journal is flushed (write(2)) at every round
//! close regardless of policy, so the OS page cache — which survives a
//! SIGKILL of the coordinator *process* — always holds every committed
//! round. fsync(2) only adds protection against whole-machine crashes;
//! `SyncPolicy::Round` (the default) pays one fsync per round close,
//! `Always` one per record, `Off` none.

use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use super::protocol::Envelope;

/// Journal file magic (first two bytes of every record).
pub const JOURNAL_MAGIC: [u8; 2] = [0xEC, 0x4A];

/// On-disk journal format version (bumped on any layout change).
pub const JOURNAL_VERSION: u8 = 1;

/// Fixed record header length: magic(2) + version(1) + kind(1) +
/// checksum(4) + round(8) + payload_len(4).
pub const RECORD_HEADER_LEN: usize = 20;

/// FNV-1a-32 over two byte ranges (header-before-checksum ++
/// header-after ++ payload) — the same checksum discipline as the wire
/// envelope, kept local so the journal layer stays self-contained.
fn fnv1a_parts(a: &[u8], b: &[u8]) -> u32 {
    let mut h = 0x811C_9DC5u32;
    for &x in a.iter().chain(b) {
        h ^= x as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// FNV-1a-64 over the bit patterns of an `f32` slice. Used for the
/// global-model and shard-slice digests embedded in [`Record::RoundClose`]
/// so replay can prove it rebuilt the exact aggregation state.
pub fn digest_f32(xs: &[f32]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// fsync cadence for journal appends (`--journal-sync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync after every record — survives whole-machine crashes at the
    /// cost of one disk round-trip per state transition.
    Always,
    /// fsync once per round close (the default): a machine crash can
    /// lose at most the open round, which replay re-runs anyway.
    Round,
    /// never fsync — the write(2) flush at round close still survives a
    /// coordinator SIGKILL (page cache), but not a machine crash.
    Off,
}

impl SyncPolicy {
    /// Parse the `--journal-sync` flag value.
    pub fn parse(s: &str) -> Option<SyncPolicy> {
        match s {
            "always" => Some(SyncPolicy::Always),
            "round" => Some(SyncPolicy::Round),
            "off" => Some(SyncPolicy::Off),
            _ => None,
        }
    }

    /// Stable flag-value name (logs, diagnostics).
    pub fn name(self) -> &'static str {
        match self {
            SyncPolicy::Always => "always",
            SyncPolicy::Round => "round",
            SyncPolicy::Off => "off",
        }
    }
}

/// Journal record discriminant (the kind byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum RecordKind {
    /// Run identity: config digest, fleet shape, round policy.
    Genesis = 1,
    /// Round open: root RNG stream position + cohort-draw alive set.
    RoundOpen = 2,
    /// One task dispatched (audit trail; replay regenerates tasks).
    Dispatch = 3,
    /// An on-time uplink arrived (raw envelope bytes, pre-accept).
    Uplink = 4,
    /// A late uplink was *admitted* to the late buffer (raw envelope).
    LateUplink = 5,
    /// A slot was resampled (the alive snapshot the draw used).
    Resample = 6,
    /// A client's downlink channel was declared lost.
    DownlinkLost = 7,
    /// A rejoin re-opened the re-dispatch wave budget.
    ReopenWaves = 8,
    /// Round committed: telemetry + state digests. The commit point.
    RoundClose = 9,
}

impl RecordKind {
    fn from_u8(x: u8) -> Option<RecordKind> {
        Some(match x {
            1 => RecordKind::Genesis,
            2 => RecordKind::RoundOpen,
            3 => RecordKind::Dispatch,
            4 => RecordKind::Uplink,
            5 => RecordKind::LateUplink,
            6 => RecordKind::Resample,
            7 => RecordKind::DownlinkLost,
            8 => RecordKind::ReopenWaves,
            9 => RecordKind::RoundClose,
            _ => return None,
        })
    }
}

/// One decoded journal record (see docs/PROTOCOL.md §8 for the byte
/// layout of each payload).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    /// Run identity, written once when the journal is created. `--resume`
    /// refuses a journal whose genesis does not match the relaunch flags.
    Genesis {
        /// `FedConfig::digest()` of the run.
        config_digest: u64,
        /// Worker slots (`--expect-workers`).
        n_workers: u32,
        /// Aggregation shards (`--shards`).
        shards: u32,
        /// Round policy tag: 0 = sync, 1 = quorum.
        policy_tag: u8,
        /// Quorum fraction as `f64::to_bits` (0 for sync).
        quorum_bits: u64,
        /// Slot timeout in milliseconds (0 for sync).
        timeout_ms: u64,
    },
    /// Round `t` opened: the root RNG position *before* the cohort draw
    /// and the worker-alive snapshot the draw saw.
    RoundOpen {
        /// `Rng::state()` of the root world stream at round open.
        rng_state: [u64; 4],
        /// Per-worker liveness at the draw (index = worker slot).
        alive: Vec<bool>,
    },
    /// One task dispatched (audit only — replay regenerates tasks from
    /// the deterministic state machine and ignores these).
    Dispatch {
        /// Cohort slot index.
        slot: u32,
        /// Client id the slot trains.
        client: u32,
        /// Worker slot the task was sent to.
        worker: u32,
        /// Per-client downlink sequence number carried by the task.
        down_seq: u64,
    },
    /// An on-time uplink arrived: the `TrainResult` envelope verbatim,
    /// journaled *before* the accept decision so duplicate/orphan
    /// handling replays exactly.
    Uplink {
        /// Encoded wire envelope (`Envelope::encode` bytes).
        envelope: Vec<u8>,
    },
    /// A late uplink was **admitted** to the late buffer (already-folded
    /// duplicates are filtered before journaling, so replay never
    /// double-folds a straggler that re-sent after a coordinator
    /// restart).
    LateUplink {
        /// Encoded wire envelope (`Envelope::encode` bytes).
        envelope: Vec<u8>,
    },
    /// Slot `slot` timed out and was re-dispatched; `alive` is the
    /// worker-liveness snapshot the replacement draw used.
    Resample {
        /// Cohort slot index that timed out.
        slot: u32,
        /// Per-worker liveness at the resample draw.
        alive: Vec<bool>,
    },
    /// Client `client`'s stateful downlink failed to send; the control
    /// plane excluded it from future cohorts.
    DownlinkLost {
        /// Excluded client id.
        client: u32,
    },
    /// A worker rejoin re-opened the re-dispatch wave budget.
    ReopenWaves,
    /// Round committed. Everything replay cannot recompute (wall-clock
    /// telemetry) plus digests proving it recomputed the rest.
    RoundClose {
        /// Live slots this round (CSV `active_cohort`).
        active_cohort: u32,
        /// CSV `mux_workers` as recorded by the live run.
        mux_workers: u32,
        /// CSV `worker_drops` as recorded by the live run.
        worker_drops: u32,
        /// CSV `worker_rejoins` as recorded by the live run.
        worker_rejoins: u32,
        /// Journal bytes appended this round (open..close, exclusive).
        journal_bytes: u64,
        /// [`digest_f32`] of the post-advance global model.
        global_digest: u64,
        /// [`digest_f32`] of each shard's delta slice, in shard order.
        shard_digests: Vec<u64>,
    },
}

impl Record {
    fn kind(&self) -> RecordKind {
        match self {
            Record::Genesis { .. } => RecordKind::Genesis,
            Record::RoundOpen { .. } => RecordKind::RoundOpen,
            Record::Dispatch { .. } => RecordKind::Dispatch,
            Record::Uplink { .. } => RecordKind::Uplink,
            Record::LateUplink { .. } => RecordKind::LateUplink,
            Record::Resample { .. } => RecordKind::Resample,
            Record::DownlinkLost { .. } => RecordKind::DownlinkLost,
            Record::ReopenWaves => RecordKind::ReopenWaves,
            Record::RoundClose { .. } => RecordKind::RoundClose,
        }
    }
}

/// A complete-but-invalid journal record: disk corruption (or a foreign
/// file), never a crash artifact — crashes tear the *tail*, which the
/// reader tolerates silently. Every variant names the byte offset of the
/// offending record so the operator can inspect it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JournalError {
    /// First two record bytes are not [`JOURNAL_MAGIC`].
    BadMagic {
        /// Byte offset of the record in the journal file.
        offset: usize,
    },
    /// Version byte differs from [`JOURNAL_VERSION`].
    BadVersion {
        /// Byte offset of the record in the journal file.
        offset: usize,
        /// The version byte found.
        got: u8,
    },
    /// Unknown record kind byte.
    BadKind {
        /// Byte offset of the record in the journal file.
        offset: usize,
        /// The kind byte found.
        got: u8,
    },
    /// FNV-1a-32 checksum mismatch over a complete record frame.
    ChecksumMismatch {
        /// Byte offset of the record in the journal file.
        offset: usize,
    },
    /// Checksum passed but the payload does not decode for its kind
    /// (a writer bug or version skew, not wire corruption).
    Malformed {
        /// Byte offset of the record in the journal file.
        offset: usize,
        /// What failed to decode.
        detail: String,
    },
}

impl fmt::Display for JournalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JournalError::BadMagic { offset } => {
                write!(f, "journal record at byte offset {offset}: bad magic")
            }
            JournalError::BadVersion { offset, got } => write!(
                f,
                "journal record at byte offset {offset}: version {got} (want {JOURNAL_VERSION})"
            ),
            JournalError::BadKind { offset, got } => {
                write!(f, "journal record at byte offset {offset}: unknown record kind {got}")
            }
            JournalError::ChecksumMismatch { offset } => write!(
                f,
                "journal record at byte offset {offset}: checksum mismatch (corrupt record)"
            ),
            JournalError::Malformed { offset, detail } => {
                write!(f, "journal record at byte offset {offset}: malformed payload ({detail})")
            }
        }
    }
}

impl std::error::Error for JournalError {}

// ---- frame encoding ---------------------------------------------------------

/// Append one framed record to `out`: reserve the header, let `build`
/// append the payload, backfill length + checksum. The only writer of
/// journal bytes — the writer methods and the in-memory tests both go
/// through here.
fn encode_frame(out: &mut Vec<u8>, round: u64, kind: RecordKind, build: impl FnOnce(&mut Vec<u8>)) {
    let start = out.len();
    out.extend_from_slice(&JOURNAL_MAGIC);
    out.push(JOURNAL_VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&[0u8; 4]); // checksum backfilled below
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&[0u8; 4]); // payload_len backfilled below
    build(out);
    let payload_len = (out.len() - start - RECORD_HEADER_LEN) as u32;
    out[start + 16..start + 20].copy_from_slice(&payload_len.to_le_bytes());
    let c = fnv1a_parts(&out[start..start + 4], &out[start + 8..]);
    out[start + 4..start + 8].copy_from_slice(&c.to_le_bytes());
}

fn put_alive(out: &mut Vec<u8>, alive: &[bool]) {
    out.extend_from_slice(&(alive.len() as u32).to_le_bytes());
    for &a in alive {
        out.push(a as u8);
    }
}

/// Append one framed `Record` to `out` (the in-memory twin of
/// [`JournalWriter::append`], shared with the property tests).
pub fn encode_record(out: &mut Vec<u8>, round: u64, rec: &Record) {
    encode_frame(out, round, rec.kind(), |buf| match rec {
        Record::Genesis { config_digest, n_workers, shards, policy_tag, quorum_bits, timeout_ms } => {
            buf.extend_from_slice(&config_digest.to_le_bytes());
            buf.extend_from_slice(&n_workers.to_le_bytes());
            buf.extend_from_slice(&shards.to_le_bytes());
            buf.push(*policy_tag);
            buf.extend_from_slice(&quorum_bits.to_le_bytes());
            buf.extend_from_slice(&timeout_ms.to_le_bytes());
        }
        Record::RoundOpen { rng_state, alive } => {
            for w in rng_state {
                buf.extend_from_slice(&w.to_le_bytes());
            }
            put_alive(buf, alive);
        }
        Record::Dispatch { slot, client, worker, down_seq } => {
            buf.extend_from_slice(&slot.to_le_bytes());
            buf.extend_from_slice(&client.to_le_bytes());
            buf.extend_from_slice(&worker.to_le_bytes());
            buf.extend_from_slice(&down_seq.to_le_bytes());
        }
        Record::Uplink { envelope } | Record::LateUplink { envelope } => {
            buf.extend_from_slice(envelope);
        }
        Record::Resample { slot, alive } => {
            buf.extend_from_slice(&slot.to_le_bytes());
            put_alive(buf, alive);
        }
        Record::DownlinkLost { client } => {
            buf.extend_from_slice(&client.to_le_bytes());
        }
        Record::ReopenWaves => {}
        Record::RoundClose {
            active_cohort,
            mux_workers,
            worker_drops,
            worker_rejoins,
            journal_bytes,
            global_digest,
            shard_digests,
        } => {
            buf.extend_from_slice(&active_cohort.to_le_bytes());
            buf.extend_from_slice(&mux_workers.to_le_bytes());
            buf.extend_from_slice(&worker_drops.to_le_bytes());
            buf.extend_from_slice(&worker_rejoins.to_le_bytes());
            buf.extend_from_slice(&journal_bytes.to_le_bytes());
            buf.extend_from_slice(&global_digest.to_le_bytes());
            buf.extend_from_slice(&(shard_digests.len() as u32).to_le_bytes());
            for d in shard_digests {
                buf.extend_from_slice(&d.to_le_bytes());
            }
        }
    });
}

// ---- payload decoding -------------------------------------------------------

/// Little-endian payload cursor with static error strings.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], &'static str> {
        if self.p + n > self.b.len() {
            return Err("payload truncated");
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, &'static str> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, &'static str> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, &'static str> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn alive(&mut self) -> Result<Vec<bool>, &'static str> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        Ok(raw.iter().map(|&b| b != 0).collect())
    }

    fn done(&self) -> Result<(), &'static str> {
        if self.p == self.b.len() {
            Ok(())
        } else {
            Err("trailing payload bytes")
        }
    }
}

fn decode_payload(kind: RecordKind, payload: &[u8]) -> Result<Record, &'static str> {
    let mut c = Cur { b: payload, p: 0 };
    let rec = match kind {
        RecordKind::Genesis => Record::Genesis {
            config_digest: c.u64()?,
            n_workers: c.u32()?,
            shards: c.u32()?,
            policy_tag: c.u8()?,
            quorum_bits: c.u64()?,
            timeout_ms: c.u64()?,
        },
        RecordKind::RoundOpen => {
            let mut rng_state = [0u64; 4];
            for w in &mut rng_state {
                *w = c.u64()?;
            }
            Record::RoundOpen { rng_state, alive: c.alive()? }
        }
        RecordKind::Dispatch => Record::Dispatch {
            slot: c.u32()?,
            client: c.u32()?,
            worker: c.u32()?,
            down_seq: c.u64()?,
        },
        RecordKind::Uplink => Record::Uplink { envelope: payload.to_vec() },
        RecordKind::LateUplink => Record::LateUplink { envelope: payload.to_vec() },
        RecordKind::Resample => Record::Resample { slot: c.u32()?, alive: c.alive()? },
        RecordKind::DownlinkLost => Record::DownlinkLost { client: c.u32()? },
        RecordKind::ReopenWaves => Record::ReopenWaves,
        RecordKind::RoundClose => {
            let active_cohort = c.u32()?;
            let mux_workers = c.u32()?;
            let worker_drops = c.u32()?;
            let worker_rejoins = c.u32()?;
            let journal_bytes = c.u64()?;
            let global_digest = c.u64()?;
            let n = c.u32()? as usize;
            let mut shard_digests = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                shard_digests.push(c.u64()?);
            }
            Record::RoundClose {
                active_cohort,
                mux_workers,
                worker_drops,
                worker_rejoins,
                journal_bytes,
                global_digest,
                shard_digests,
            }
        }
    };
    // the envelope kinds consume the payload wholesale; everything else
    // must account for every byte
    if !matches!(kind, RecordKind::Uplink | RecordKind::LateUplink) {
        c.done()?;
    }
    Ok(rec)
}

// ---- reader -----------------------------------------------------------------

/// Sequential journal decoder over an in-memory byte image of the file.
///
/// [`JournalReader::next_record`] yields `(round, record)` pairs until a
/// clean end-of-file, a torn tail (tolerated: `Ok(None)` with
/// [`JournalReader::torn_bytes`] > 0), or a corrupt complete record
/// (a typed [`JournalError`]).
pub struct JournalReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    torn: usize,
}

impl<'a> JournalReader<'a> {
    /// Start decoding at byte 0 of `bytes`.
    pub fn new(bytes: &'a [u8]) -> JournalReader<'a> {
        JournalReader { bytes, pos: 0, torn: 0 }
    }

    /// Byte offset the next record would be read from.
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Bytes of torn (incomplete) final record dropped at the tail;
    /// 0 until the reader has stopped, and on a clean end-of-file.
    pub fn torn_bytes(&self) -> usize {
        self.torn
    }

    /// Decode the next record, `Ok(None)` at end-of-file (clean or torn
    /// tail), `Err` on a complete-but-corrupt record.
    pub fn next_record(&mut self) -> Result<Option<(u64, Record)>, JournalError> {
        let o = self.pos;
        let rest = &self.bytes[o..];
        if rest.len() < RECORD_HEADER_LEN {
            // clean EOF (0 bytes) or a header torn by a crash
            self.torn = rest.len();
            return Ok(None);
        }
        if rest[0..2] != JOURNAL_MAGIC {
            return Err(JournalError::BadMagic { offset: o });
        }
        if rest[2] != JOURNAL_VERSION {
            return Err(JournalError::BadVersion { offset: o, got: rest[2] });
        }
        let kind = RecordKind::from_u8(rest[3])
            .ok_or(JournalError::BadKind { offset: o, got: rest[3] })?;
        let payload_len = u32::from_le_bytes(rest[16..20].try_into().unwrap()) as usize;
        let frame_len = RECORD_HEADER_LEN + payload_len;
        if rest.len() < frame_len {
            // the crash tore this record mid-payload: drop it
            self.torn = rest.len();
            return Ok(None);
        }
        let frame = &rest[..frame_len];
        let checksum = u32::from_le_bytes(frame[4..8].try_into().unwrap());
        if fnv1a_parts(&frame[0..4], &frame[8..]) != checksum {
            return Err(JournalError::ChecksumMismatch { offset: o });
        }
        let round = u64::from_le_bytes(frame[8..16].try_into().unwrap());
        let rec = decode_payload(kind, &frame[RECORD_HEADER_LEN..])
            .map_err(|detail| JournalError::Malformed { offset: o, detail: detail.into() })?;
        self.pos += frame_len;
        Ok(Some((round, rec)))
    }
}

/// Read and decode a whole journal file, tolerating a torn tail.
/// Returns the records and the count of torn tail bytes dropped.
pub fn read_journal(path: &Path) -> Result<(Vec<(u64, Record)>, usize)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let mut reader = JournalReader::new(&bytes);
    let mut records = Vec::new();
    loop {
        match reader.next_record() {
            Ok(Some(item)) => records.push(item),
            Ok(None) => break,
            Err(e) => {
                return Err(anyhow::Error::new(e)
                    .context(format!("journal {} is corrupt", path.display())))
            }
        }
    }
    Ok((records, reader.torn_bytes()))
}

// ---- writer -----------------------------------------------------------------

/// Buffered append-only journal writer.
///
/// All appends encode into one reusable scratch buffer and go through a
/// [`BufWriter`], so the steady-state uplink path performs zero heap
/// allocations. [`JournalWriter::commit_round`] flushes unconditionally
/// (SIGKILL durability via the page cache) and fsyncs per [`SyncPolicy`].
pub struct JournalWriter {
    out: BufWriter<File>,
    scratch: Vec<u8>,
    sync: SyncPolicy,
    round_bytes: u64,
    path: PathBuf,
}

impl JournalWriter {
    /// Create (truncate) a journal and durably write its genesis record.
    pub fn create(path: &Path, sync: SyncPolicy, genesis: &Record) -> Result<JournalWriter> {
        let file = File::create(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        let mut w = JournalWriter {
            out: BufWriter::new(file),
            scratch: Vec::with_capacity(4096),
            sync,
            round_bytes: 0,
            path: path.to_path_buf(),
        };
        w.append(0, genesis)?;
        // genesis is durable regardless of policy: it is one record, once
        w.flush_data(true)?;
        Ok(w)
    }

    /// Open an existing journal for appending (the `--resume` path).
    pub fn open_append(path: &Path, sync: SyncPolicy) -> Result<JournalWriter> {
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {} for append", path.display()))?;
        Ok(JournalWriter {
            out: BufWriter::new(file),
            scratch: Vec::with_capacity(4096),
            sync,
            round_bytes: 0,
            path: path.to_path_buf(),
        })
    }

    /// Journal bytes appended since the last [`Record::RoundOpen`]
    /// (which resets the counter), including the open record itself.
    pub fn round_bytes(&self) -> u64 {
        self.round_bytes
    }

    fn write_scratch(&mut self) -> Result<()> {
        self.out
            .write_all(&self.scratch)
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        self.round_bytes += self.scratch.len() as u64;
        if self.sync == SyncPolicy::Always {
            self.flush_data(true)?;
        }
        Ok(())
    }

    /// Append one record. A [`Record::RoundOpen`] resets the per-round
    /// byte counter before counting itself.
    pub fn append(&mut self, round: u64, rec: &Record) -> Result<()> {
        if matches!(rec, Record::RoundOpen { .. }) {
            self.round_bytes = 0;
        }
        self.scratch.clear();
        encode_record(&mut self.scratch, round, rec);
        self.write_scratch()
    }

    /// Append an uplink record straight from the received envelope
    /// (no intermediate payload `Vec` — the accept hot path).
    pub fn append_uplink(&mut self, round: u64, late: bool, env: &Envelope) -> Result<()> {
        let kind = if late { RecordKind::LateUplink } else { RecordKind::Uplink };
        self.scratch.clear();
        encode_frame(&mut self.scratch, round, kind, |buf| env.encode_into(buf));
        self.write_scratch()
    }

    fn flush_data(&mut self, fsync: bool) -> Result<()> {
        self.out
            .flush()
            .with_context(|| format!("flushing journal {}", self.path.display()))?;
        if fsync {
            self.out
                .get_ref()
                .sync_data()
                .with_context(|| format!("fsyncing journal {}", self.path.display()))?;
        }
        Ok(())
    }

    /// Commit a round: flush unconditionally (a SIGKILLed coordinator
    /// leaves the round in the page cache), fsync per policy. Returns
    /// the seconds spent in fsync (0 when the policy skipped it).
    pub fn commit_round(&mut self) -> Result<f64> {
        match self.sync {
            SyncPolicy::Off => {
                self.flush_data(false)?;
                Ok(0.0)
            }
            SyncPolicy::Round | SyncPolicy::Always => {
                self.out
                    .flush()
                    .with_context(|| format!("flushing journal {}", self.path.display()))?;
                let t0 = Instant::now();
                self.out
                    .get_ref()
                    .sync_data()
                    .with_context(|| format!("fsyncing journal {}", self.path.display()))?;
                Ok(t0.elapsed().as_secs_f64())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_records(rng: &mut Rng) -> Vec<(u64, Record)> {
        let n = 1 + rng.below(10);
        (0..n)
            .map(|_| {
                let round = rng.below(1 << 20) as u64;
                let rec = match rng.below(9) {
                    0 => Record::Genesis {
                        config_digest: rng.next_u64(),
                        n_workers: rng.below(64) as u32,
                        shards: 1 + rng.below(8) as u32,
                        policy_tag: rng.below(2) as u8,
                        quorum_bits: rng.next_u64(),
                        timeout_ms: rng.below(100_000) as u64,
                    },
                    1 => Record::RoundOpen {
                        rng_state: [
                            rng.next_u64(),
                            rng.next_u64(),
                            rng.next_u64(),
                            rng.next_u64(),
                        ],
                        alive: (0..rng.below(9)).map(|_| rng.below(2) == 1).collect(),
                    },
                    2 => Record::Dispatch {
                        slot: rng.below(64) as u32,
                        client: rng.below(1 << 20) as u32,
                        worker: rng.below(64) as u32,
                        down_seq: rng.below(1 << 30) as u64,
                    },
                    3 => Record::Uplink {
                        envelope: (0..rng.below(200)).map(|_| rng.below(256) as u8).collect(),
                    },
                    4 => Record::LateUplink {
                        envelope: (0..rng.below(200)).map(|_| rng.below(256) as u8).collect(),
                    },
                    5 => Record::Resample {
                        slot: rng.below(64) as u32,
                        alive: (0..rng.below(9)).map(|_| rng.below(2) == 1).collect(),
                    },
                    6 => Record::DownlinkLost { client: rng.below(1 << 20) as u32 },
                    7 => Record::ReopenWaves,
                    _ => Record::RoundClose {
                        active_cohort: rng.below(64) as u32,
                        mux_workers: rng.below(64) as u32,
                        worker_drops: rng.below(8) as u32,
                        worker_rejoins: rng.below(8) as u32,
                        journal_bytes: rng.below(1 << 40) as u64,
                        global_digest: rng.next_u64(),
                        shard_digests: (0..rng.below(5)).map(|_| rng.next_u64()).collect(),
                    },
                };
                (round, rec)
            })
            .collect()
    }

    fn encode_all(records: &[(u64, Record)]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for (round, rec) in records {
            encode_record(&mut bytes, *round, rec);
        }
        bytes
    }

    fn decode_all(bytes: &[u8]) -> (Vec<(u64, Record)>, usize) {
        let mut reader = JournalReader::new(bytes);
        let mut out = Vec::new();
        while let Some(item) = reader.next_record().unwrap() {
            out.push(item);
        }
        (out, reader.torn_bytes())
    }

    #[test]
    fn arbitrary_record_sequences_round_trip() {
        let mut rng = Rng::new(0x70_51);
        for _ in 0..300 {
            let records = sample_records(&mut rng);
            let bytes = encode_all(&records);
            let (decoded, torn) = decode_all(&bytes);
            assert_eq!(decoded, records);
            assert_eq!(torn, 0, "a complete stream has no torn tail");
        }
    }

    #[test]
    fn torn_tail_at_every_cut_drops_only_the_final_record() {
        let mut rng = Rng::new(0x70_52);
        let records = sample_records(&mut rng);
        let bytes = encode_all(&records);
        // record start offsets, so each cut point maps to an expected
        // count of fully-contained records
        let mut starts = Vec::new();
        {
            let mut reader = JournalReader::new(&bytes);
            loop {
                starts.push(reader.offset());
                if reader.next_record().unwrap().is_none() {
                    break;
                }
            }
        }
        for cut in 0..=bytes.len() {
            let want = starts.iter().filter(|&&s| s < cut).count().min(records.len());
            // a cut strictly inside record i keeps records 0..i
            let complete = starts.iter().take_while(|&&s| s <= cut).count() - 1;
            let want = want.min(complete);
            let (decoded, torn) = decode_all(&bytes[..cut]);
            assert_eq!(decoded.len(), want, "cut at byte {cut}");
            assert_eq!(decoded[..], records[..want], "cut at byte {cut}");
            let expected_torn = cut - starts[want];
            assert_eq!(torn, expected_torn, "cut at byte {cut}");
        }
    }

    #[test]
    fn corrupt_complete_records_fail_typed_with_the_offset() {
        let first = vec![(3, Record::DownlinkLost { client: 9 })];
        let second = vec![(3, Record::ReopenWaves)];
        let mut bytes = encode_all(&first);
        let first_len = bytes.len();
        bytes.extend_from_slice(&encode_all(&second));

        let fail_at = |bytes: &[u8], want_offset: usize| -> JournalError {
            let mut reader = JournalReader::new(bytes);
            loop {
                match reader.next_record() {
                    Ok(Some(_)) => continue,
                    Ok(None) => panic!("corruption was silently tolerated"),
                    Err(e) => {
                        let msg = e.to_string();
                        assert!(
                            msg.contains(&format!("offset {want_offset}")),
                            "error must name the offset: {msg}"
                        );
                        return e;
                    }
                }
            }
        };

        // payload byte of record 0
        let mut b = bytes.clone();
        b[RECORD_HEADER_LEN] ^= 0xFF;
        assert!(matches!(fail_at(&b, 0), JournalError::ChecksumMismatch { offset: 0 }));

        // checksum field of record 1
        let mut b = bytes.clone();
        b[first_len + 4] ^= 0x01;
        assert!(matches!(
            fail_at(&b, first_len),
            JournalError::ChecksumMismatch { .. }
        ));

        // magic byte
        let mut b = bytes.clone();
        b[0] = 0x00;
        assert!(matches!(fail_at(&b, 0), JournalError::BadMagic { offset: 0 }));

        // version byte
        let mut b = bytes.clone();
        b[2] = JOURNAL_VERSION + 1;
        assert!(matches!(fail_at(&b, 0), JournalError::BadVersion { offset: 0, .. }));

        // kind byte (an out-of-range discriminant)
        let mut b = bytes;
        b[3] = 0xEE;
        assert!(matches!(fail_at(&b, 0), JournalError::BadKind { offset: 0, got: 0xEE }));
    }

    #[test]
    fn writer_appends_survive_reopen_and_report_round_bytes() {
        let dir = std::env::temp_dir()
            .join(format!("ecolora-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.journal");

        let genesis = Record::Genesis {
            config_digest: 0xDEAD_BEEF,
            n_workers: 2,
            shards: 1,
            policy_tag: 0,
            quorum_bits: 0,
            timeout_ms: 0,
        };
        let open = Record::RoundOpen { rng_state: [1, 2, 3, 4], alive: vec![true, false] };
        let up = Record::Uplink { envelope: vec![7u8; 33] };
        {
            let mut w = JournalWriter::create(&path, SyncPolicy::Round, &genesis).unwrap();
            w.append(0, &open).unwrap();
            w.append(0, &up).unwrap();
            let rb = w.round_bytes();
            let mut expect = Vec::new();
            encode_record(&mut expect, 0, &open);
            encode_record(&mut expect, 0, &up);
            assert_eq!(rb, expect.len() as u64, "round_bytes counts open..now");
            w.append(
                0,
                &Record::RoundClose {
                    active_cohort: 1,
                    mux_workers: 0,
                    worker_drops: 0,
                    worker_rejoins: 0,
                    journal_bytes: rb,
                    global_digest: 5,
                    shard_digests: vec![6],
                },
            )
            .unwrap();
            w.commit_round().unwrap();
        }
        {
            // reopen in append mode, as --resume does
            let mut w = JournalWriter::open_append(&path, SyncPolicy::Off).unwrap();
            w.append(1, &Record::RoundOpen { rng_state: [9, 9, 9, 9], alive: vec![true] })
                .unwrap();
            w.commit_round().unwrap();
        }
        let (records, torn) = read_journal(&path).unwrap();
        assert_eq!(torn, 0);
        assert_eq!(records.len(), 5);
        assert_eq!(records[0], (0, genesis));
        assert_eq!(records[1], (0, open));
        assert_eq!(records[2], (0, up));
        assert!(matches!(records[3], (0, Record::RoundClose { .. })));
        assert!(matches!(records[4], (1, Record::RoundOpen { .. })));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_uplink_frames_the_envelope_verbatim() {
        use crate::cluster::protocol::{Envelope, MsgKind};
        let dir = std::env::temp_dir()
            .join(format!("ecolora-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("uplink.journal");
        let env = Envelope::new(MsgKind::TrainResult, 4, 2, 11, vec![1, 2, 3, 4, 5]);
        let genesis = Record::Genesis {
            config_digest: 1,
            n_workers: 1,
            shards: 1,
            policy_tag: 0,
            quorum_bits: 0,
            timeout_ms: 0,
        };
        {
            let mut w = JournalWriter::create(&path, SyncPolicy::Off, &genesis).unwrap();
            w.append_uplink(4, false, &env).unwrap();
            w.append_uplink(5, true, &env).unwrap();
            w.commit_round().unwrap();
        }
        let (records, _) = read_journal(&path).unwrap();
        match &records[1] {
            (4, Record::Uplink { envelope }) => assert_eq!(*envelope, env.encode()),
            other => panic!("expected the on-time uplink, got {other:?}"),
        }
        match &records[2] {
            (5, Record::LateUplink { envelope }) => {
                assert_eq!(Envelope::decode(envelope).unwrap(), env);
            }
            other => panic!("expected the late uplink, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn digest_f32_is_order_and_bit_sensitive() {
        let a = digest_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(a, digest_f32(&[1.0, 2.0, 3.0]), "deterministic");
        assert_ne!(a, digest_f32(&[3.0, 2.0, 1.0]), "order-sensitive");
        assert_ne!(a, digest_f32(&[1.0, 2.0, 3.0 + f32::EPSILON]), "bit-sensitive");
        // -0.0 and 0.0 differ in bits, so they must differ in digest
        assert_ne!(digest_f32(&[0.0]), digest_f32(&[-0.0]));
    }
}
