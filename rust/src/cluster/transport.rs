//! Pluggable byte transports connecting the coordinator to participants.
//!
//! Two implementations of the same duplex [`Conn`] contract:
//!
//! * [`ClusterMode::Mem`] — std::sync::mpsc channel pairs. Deterministic,
//!   zero-config; the default CLI path and the parity tests run on it.
//!   Envelopes are still byte-encoded through the full codec so the mem
//!   path exercises exactly the bytes TCP would carry.
//! * [`ClusterMode::Tcp`] — loopback (or real) TCP with length-prefixed
//!   framing: `u32 le frame length` + envelope bytes.
//!
//! A `Conn` can be [`Conn::split`] into independently-owned send/receive
//! halves so the coordinator can drain results on reader threads while it
//! is still dispatching tasks — that split is what makes the dispatch
//! phase deadlock-free regardless of kernel socket buffer sizes.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

use anyhow::{anyhow, ensure, Context, Result};

use super::protocol::{Envelope, HEADER_LEN, MAX_PAYLOAD};

/// Sending half of a connection.
pub trait ConnTx: Send {
    /// Transmit one envelope (blocking until handed to the transport).
    fn send(&mut self, env: &Envelope) -> Result<()>;
}

/// Receiving half of a connection (blocking).
pub trait ConnRx: Send {
    /// Receive the next envelope (blocking; errors when the peer is gone).
    fn recv(&mut self) -> Result<Envelope>;
}

/// One reliable, ordered duplex message pipe.
pub trait Conn: Send {
    /// Transmit one envelope.
    fn send(&mut self, env: &Envelope) -> Result<()>;
    /// Receive the next envelope (blocking).
    fn recv(&mut self) -> Result<Envelope>;
    /// Split into independently-owned halves (thread-per-direction use).
    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)>;
}

/// Which transport carries the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// In-process `std::sync::mpsc` channel pairs (deterministic default).
    Mem,
    /// Length-prefix-framed TCP (loopback by default).
    Tcp,
}

impl ClusterMode {
    /// Parse a CLI spelling ("mem"/"memory"/"channel", "tcp"/"loopback").
    pub fn parse(s: &str) -> Option<ClusterMode> {
        match s.to_ascii_lowercase().as_str() {
            "mem" | "memory" | "channel" => Some(ClusterMode::Mem),
            "tcp" | "loopback" => Some(ClusterMode::Tcp),
            _ => None,
        }
    }

    /// Canonical short name ("mem" or "tcp").
    pub fn name(self) -> &'static str {
        match self {
            ClusterMode::Mem => "mem",
            ClusterMode::Tcp => "tcp",
        }
    }
}

// ---- in-memory channel transport -------------------------------------------

/// Sending half of an in-memory connection.
pub struct MemTx {
    tx: mpsc::Sender<Vec<u8>>,
}

/// Receiving half of an in-memory connection.
pub struct MemRx {
    rx: mpsc::Receiver<Vec<u8>>,
}

impl ConnTx for MemTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.tx
            .send(env.encode())
            .map_err(|_| anyhow!("mem transport: peer hung up on send"))
    }
}

impl ConnRx for MemRx {
    fn recv(&mut self) -> Result<Envelope> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| anyhow!("mem transport: peer hung up on recv"))?;
        Envelope::decode(&bytes)
    }
}

/// Duplex in-memory channel connection (see [`ClusterMode::Mem`]).
pub struct MemConn {
    tx: MemTx,
    rx: MemRx,
}

impl Conn for MemConn {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.tx.send(env)
    }

    fn recv(&mut self) -> Result<Envelope> {
        self.rx.recv()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        Ok((Box::new(self.tx), Box::new(self.rx)))
    }
}

// ---- TCP transport ----------------------------------------------------------

fn tcp_send(stream: &mut TcpStream, env: &Envelope) -> Result<()> {
    let bytes = env.encode();
    stream
        .write_all(&(bytes.len() as u32).to_le_bytes())
        .context("tcp send: frame length")?;
    stream.write_all(&bytes).context("tcp send: frame body")?;
    stream.flush().context("tcp send: flush")?;
    Ok(())
}

fn tcp_recv(stream: &mut TcpStream) -> Result<Envelope> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("tcp recv: frame length")?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(
        (HEADER_LEN..=HEADER_LEN + MAX_PAYLOAD).contains(&len),
        "tcp recv: implausible frame length {len}"
    );
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf).context("tcp recv: frame body")?;
    Envelope::decode(&buf)
}

/// Sending half of a TCP connection.
pub struct TcpTx {
    stream: TcpStream,
}

/// Receiving half of a TCP connection (a cloned stream handle).
pub struct TcpRx {
    stream: TcpStream,
}

impl ConnTx for TcpTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        tcp_send(&mut self.stream, env)
    }
}

impl ConnRx for TcpRx {
    fn recv(&mut self) -> Result<Envelope> {
        tcp_recv(&mut self.stream)
    }
}

/// Duplex framed-TCP connection (see [`ClusterMode::Tcp`]).
pub struct TcpConn {
    stream: TcpStream,
}

impl TcpConn {
    /// Wrap an already-connected stream (external deployments).
    pub fn from_stream(stream: TcpStream) -> TcpConn {
        stream.set_nodelay(true).ok();
        TcpConn { stream }
    }
}

impl Conn for TcpConn {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        tcp_send(&mut self.stream, env)
    }

    fn recv(&mut self) -> Result<Envelope> {
        tcp_recv(&mut self.stream)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        let reader = self.stream.try_clone().context("tcp split: clone stream")?;
        Ok((Box::new(TcpTx { stream: self.stream }), Box::new(TcpRx { stream: reader })))
    }
}

/// Build `n` connected coordinator↔worker pipes. Returns
/// (coordinator-side conns, worker-side conns), index-aligned.
pub fn establish(mode: ClusterMode, n: usize) -> Result<(Vec<Box<dyn Conn>>, Vec<Box<dyn Conn>>)> {
    let mut coord: Vec<Box<dyn Conn>> = Vec::with_capacity(n);
    let mut work: Vec<Box<dyn Conn>> = Vec::with_capacity(n);
    match mode {
        ClusterMode::Mem => {
            for _ in 0..n {
                let (to_worker_tx, to_worker_rx) = mpsc::channel();
                let (to_coord_tx, to_coord_rx) = mpsc::channel();
                coord.push(Box::new(MemConn {
                    tx: MemTx { tx: to_worker_tx },
                    rx: MemRx { rx: to_coord_rx },
                }));
                work.push(Box::new(MemConn {
                    tx: MemTx { tx: to_coord_tx },
                    rx: MemRx { rx: to_worker_rx },
                }));
            }
        }
        ClusterMode::Tcp => {
            let listener =
                TcpListener::bind(("127.0.0.1", 0)).context("tcp transport: bind loopback")?;
            let addr = listener.local_addr().context("tcp transport: local addr")?;
            for _ in 0..n {
                // connect-then-accept one at a time keeps pairing aligned;
                // the Hello handshake re-checks identity on top anyway.
                let worker_side =
                    TcpStream::connect(addr).context("tcp transport: connect loopback")?;
                let (coord_side, _peer) = listener.accept().context("tcp transport: accept")?;
                coord.push(Box::new(TcpConn::from_stream(coord_side)));
                work.push(Box::new(TcpConn::from_stream(worker_side)));
            }
        }
    }
    Ok((coord, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::protocol::{Message, MsgKind};

    fn echo_roundtrip(mode: ClusterMode) {
        let (mut coord, work) = establish(mode, 2).unwrap();
        let mut handles = Vec::new();
        for (w, mut conn) in work.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                // identify, then echo everything until Shutdown
                conn.send(&Message::Hello { worker: w as u32 }.to_envelope()).unwrap();
                loop {
                    let env = conn.recv().unwrap();
                    if env.kind == MsgKind::Shutdown {
                        return;
                    }
                    conn.send(&env).unwrap();
                }
            }));
        }
        for (i, conn) in coord.iter_mut().enumerate() {
            let hello = conn.recv().unwrap();
            match Message::from_envelope(&hello).unwrap() {
                Message::Hello { worker } => assert_eq!(worker as usize, i),
                other => panic!("expected hello, got {other:?}"),
            }
            let msg = Message::BaseSync { base: vec![1.5; 1000 + i] };
            let env = msg.to_envelope();
            conn.send(&env).unwrap();
            let back = conn.recv().unwrap();
            assert_eq!(back, env);
            conn.send(&Message::Shutdown.to_envelope()).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn mem_transport_roundtrips() {
        echo_roundtrip(ClusterMode::Mem);
    }

    #[test]
    fn tcp_transport_roundtrips_on_loopback() {
        echo_roundtrip(ClusterMode::Tcp);
    }

    #[test]
    fn split_halves_work_from_separate_threads() {
        for mode in [ClusterMode::Mem, ClusterMode::Tcp] {
            let (coord, work) = establish(mode, 1).unwrap();
            let mut worker_conn = work.into_iter().next().unwrap();
            let peer = std::thread::spawn(move || {
                for _ in 0..3 {
                    let env = worker_conn.recv().unwrap();
                    worker_conn.send(&env).unwrap();
                }
            });
            let (mut tx, mut rx) = coord.into_iter().next().unwrap().split().unwrap();
            let reader = std::thread::spawn(move || {
                let mut seen = Vec::new();
                for _ in 0..3 {
                    seen.push(rx.recv().unwrap().round);
                }
                seen
            });
            for round in 0..3u64 {
                let env = crate::cluster::protocol::Envelope::new(
                    MsgKind::TrainTask,
                    round,
                    0,
                    0,
                    vec![7; 64],
                );
                tx.send(&env).unwrap();
            }
            assert_eq!(reader.join().unwrap(), vec![0, 1, 2]);
            peer.join().unwrap();
        }
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(ClusterMode::parse("mem"), Some(ClusterMode::Mem));
        assert_eq!(ClusterMode::parse("TCP"), Some(ClusterMode::Tcp));
        assert_eq!(ClusterMode::parse("carrier-pigeon"), None);
        assert_eq!(ClusterMode::Mem.name(), "mem");
    }
}
