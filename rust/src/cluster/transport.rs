//! Pluggable byte transports connecting the coordinator to participants.
//!
//! Two implementations of the same duplex [`Conn`] contract:
//!
//! * [`ClusterMode::Mem`] — std::sync::mpsc channel pairs. Deterministic,
//!   zero-config; the default CLI path and the parity tests run on it.
//!   Envelopes are still byte-encoded through the full codec so the mem
//!   path exercises exactly the bytes TCP would carry.
//! * [`ClusterMode::Tcp`] — loopback (or real) TCP with length-prefixed
//!   framing: `u32 le frame length` + envelope bytes.
//!
//! A `Conn` can be [`Conn::split`] into independently-owned send/receive
//! halves so the coordinator can drain results on reader threads while it
//! is still dispatching tasks — that split is what makes the dispatch
//! phase deadlock-free regardless of kernel socket buffer sizes.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::protocol::{Envelope, HEADER_LEN, MAX_PAYLOAD};

/// Sending half of a connection.
pub trait ConnTx: Send {
    /// Transmit one envelope (blocking until handed to the transport).
    fn send(&mut self, env: &Envelope) -> Result<()>;
}

/// Receiving half of a connection (blocking).
pub trait ConnRx: Send {
    /// Receive the next envelope (blocking; errors when the peer is gone).
    fn recv(&mut self) -> Result<Envelope>;
}

/// One reliable, ordered duplex message pipe.
pub trait Conn: Send {
    /// Transmit one envelope.
    fn send(&mut self, env: &Envelope) -> Result<()>;
    /// Receive the next envelope (blocking).
    fn recv(&mut self) -> Result<Envelope>;
    /// Split into independently-owned halves (thread-per-direction use).
    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)>;
}

/// Which transport carries the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// In-process `std::sync::mpsc` channel pairs (deterministic default).
    Mem,
    /// Length-prefix-framed TCP (loopback by default).
    Tcp,
}

impl ClusterMode {
    /// Parse a CLI spelling ("mem"/"memory"/"channel", "tcp"/"loopback").
    pub fn parse(s: &str) -> Option<ClusterMode> {
        match s.to_ascii_lowercase().as_str() {
            "mem" | "memory" | "channel" => Some(ClusterMode::Mem),
            "tcp" | "loopback" => Some(ClusterMode::Tcp),
            _ => None,
        }
    }

    /// Canonical short name ("mem" or "tcp").
    pub fn name(self) -> &'static str {
        match self {
            ClusterMode::Mem => "mem",
            ClusterMode::Tcp => "tcp",
        }
    }
}

// ---- in-memory channel transport -------------------------------------------

/// Sending half of an in-memory connection.
pub struct MemTx {
    tx: mpsc::Sender<Vec<u8>>,
}

/// Receiving half of an in-memory connection.
pub struct MemRx {
    rx: mpsc::Receiver<Vec<u8>>,
}

impl ConnTx for MemTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.tx
            .send(env.encode())
            .map_err(|_| anyhow!("mem transport: peer hung up on send"))
    }
}

impl ConnRx for MemRx {
    fn recv(&mut self) -> Result<Envelope> {
        let bytes = self
            .rx
            .recv()
            .map_err(|_| anyhow!("mem transport: peer hung up on recv"))?;
        Envelope::decode(&bytes)
    }
}

/// Duplex in-memory channel connection (see [`ClusterMode::Mem`]).
pub struct MemConn {
    tx: MemTx,
    rx: MemRx,
}

impl Conn for MemConn {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.tx.send(env)
    }

    fn recv(&mut self) -> Result<Envelope> {
        self.rx.recv()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        Ok((Box::new(self.tx), Box::new(self.rx)))
    }
}

// ---- TCP transport ----------------------------------------------------------

fn tcp_send(stream: &mut TcpStream, env: &Envelope) -> Result<()> {
    let bytes = env.encode();
    stream
        .write_all(&(bytes.len() as u32).to_le_bytes())
        .context("tcp send: frame length")?;
    stream.write_all(&bytes).context("tcp send: frame body")?;
    stream.flush().context("tcp send: flush")?;
    Ok(())
}

/// Frame `env` into `scratch` (`[u32 le length][envelope bytes]` — the
/// identical bytes [`tcp_send`] produces) and write it with one syscall.
/// `scratch` is cleared first and keeps its capacity, so a warm caller
/// never allocates (§Perf: the router's remote shard fan-out).
fn tcp_send_scratch(stream: &mut TcpStream, env: &Envelope, scratch: &mut Vec<u8>) -> Result<()> {
    scratch.clear();
    scratch.extend_from_slice(&[0u8; 4]); // length backfilled below
    env.encode_into(scratch);
    let len = (scratch.len() - 4) as u32;
    scratch[..4].copy_from_slice(&len.to_le_bytes());
    stream.write_all(scratch).context("tcp send: frame")?;
    stream.flush().context("tcp send: flush")?;
    Ok(())
}

fn tcp_recv(stream: &mut TcpStream, frame_cap: usize) -> Result<Envelope> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("tcp recv: frame length")?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(
        (HEADER_LEN..=HEADER_LEN + MAX_PAYLOAD).contains(&len),
        "tcp recv: implausible frame length {len}"
    );
    ensure!(
        len <= frame_cap,
        "tcp recv: frame length {len} over the connection cap {frame_cap}"
    );
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf).context("tcp recv: frame body")?;
    Envelope::decode(&buf)
}

/// Default per-frame byte cap: anything the protocol can legally carry.
const FRAME_CAP_DEFAULT: usize = HEADER_LEN + MAX_PAYLOAD;

/// Sending half of a TCP connection.
pub struct TcpTx {
    stream: TcpStream,
}

/// Receiving half of a TCP connection (a cloned stream handle).
pub struct TcpRx {
    stream: TcpStream,
    frame_cap: usize,
}

impl TcpTx {
    /// Send through a caller-owned scratch buffer: same bytes as
    /// [`ConnTx::send`], zero allocations once the buffer is warm.
    pub fn send_scratch(&mut self, env: &Envelope, scratch: &mut Vec<u8>) -> Result<()> {
        tcp_send_scratch(&mut self.stream, env, scratch)
    }
}

impl ConnTx for TcpTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        tcp_send(&mut self.stream, env)
    }
}

impl ConnRx for TcpRx {
    fn recv(&mut self) -> Result<Envelope> {
        tcp_recv(&mut self.stream, self.frame_cap)
    }
}

/// Duplex framed-TCP connection (see [`ClusterMode::Tcp`]).
pub struct TcpConn {
    stream: TcpStream,
    frame_cap: usize,
}

impl TcpConn {
    /// Wrap an already-connected stream (external deployments).
    pub fn from_stream(stream: TcpStream) -> TcpConn {
        stream.set_nodelay(true).ok();
        TcpConn { stream, frame_cap: FRAME_CAP_DEFAULT }
    }

    /// Cap the length any incoming frame may claim before its body is
    /// allocated. The deployment handshake lowers this to 64 KiB while
    /// the peer is still unauthenticated (a giant pre-auth frame is a
    /// memory-exhaustion vector), then restores the protocol-wide default
    /// after `Welcome`.
    pub fn set_frame_cap(&mut self, cap: usize) {
        self.frame_cap = cap.clamp(HEADER_LEN, FRAME_CAP_DEFAULT);
    }

    /// Restore the protocol-wide default frame cap.
    pub fn clear_frame_cap(&mut self) {
        self.frame_cap = FRAME_CAP_DEFAULT;
    }

    /// Bound how long a blocking [`Conn::recv`] may wait (`None` = wait
    /// forever). The deployment handshake sets a bound so a peer that
    /// connects and then goes silent cannot stall the coordinator's
    /// registry; steady-state connections run unbounded.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .context("tcp: set read timeout")
    }

    /// Remote peer address (log lines).
    pub fn peer_addr(&self) -> Result<SocketAddr> {
        self.stream.peer_addr().context("tcp: peer addr")
    }

    /// Split into concretely-typed TCP halves. The router's remote shard
    /// links need the typed [`TcpTx`] (its scratch-send path is not part
    /// of the object-safe [`ConnTx`] contract); everything else can use
    /// the trait-object [`Conn::split`], which delegates here.
    pub fn split_tcp(self) -> Result<(TcpTx, TcpRx)> {
        let reader = self.stream.try_clone().context("tcp split: clone stream")?;
        // read timeouts are a handshake-phase tool; the split steady-state
        // halves always block indefinitely (the reader thread owns recv)
        reader.set_read_timeout(None).context("tcp split: clear read timeout")?;
        Ok((
            TcpTx { stream: self.stream },
            TcpRx { stream: reader, frame_cap: self.frame_cap },
        ))
    }
}

impl Conn for TcpConn {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        tcp_send(&mut self.stream, env)
    }

    fn recv(&mut self) -> Result<Envelope> {
        tcp_recv(&mut self.stream, self.frame_cap)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        let (tx, rx) = (*self).split_tcp()?;
        Ok((Box::new(tx), Box::new(rx)))
    }
}

/// A bound coordinator listener accepting external worker connections
/// (the `ecolora serve` front door).
pub struct Listener {
    inner: TcpListener,
}

impl Listener {
    /// Bind `addr` (e.g. `127.0.0.1:7878` or `0.0.0.0:7878`). The
    /// listener is non-blocking: poll it with [`Listener::try_accept`].
    pub fn bind(addr: &str) -> Result<Listener> {
        let inner = TcpListener::bind(addr)
            .with_context(|| format!("serve: bind listener on {addr}"))?;
        inner.set_nonblocking(true).context("serve: set listener non-blocking")?;
        Ok(Listener { inner })
    }

    /// The bound local address (port 0 resolves to the real port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.inner.local_addr().context("serve: listener local addr")
    }

    /// Accept one pending connection, or `None` when nobody is waiting.
    pub fn try_accept(&self) -> Result<Option<(TcpConn, SocketAddr)>> {
        match self.inner.accept() {
            Ok((stream, peer)) => {
                stream.set_nonblocking(false).context("serve: accepted stream blocking mode")?;
                Ok(Some((TcpConn::from_stream(stream), peer)))
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e).context("serve: accept"),
        }
    }
}

/// Dial a coordinator, retrying until `timeout` elapses (an `ecolora
/// worker` may legitimately start before its `serve` peer has bound the
/// listener; connection-refused within the window is not an error).
/// Every single attempt is bounded by `connect_timeout` too, so a
/// blackholed address cannot hold one attempt open past the window the
/// operator configured.
pub fn dial(addr: &str, timeout: Duration) -> Result<TcpConn> {
    let deadline = Instant::now() + timeout;
    let mut last_err: Option<std::io::Error> = None;
    loop {
        // re-resolve each attempt: DNS may converge while we wait
        match addr.to_socket_addrs() {
            Ok(addrs) => {
                for a in addrs {
                    // re-derive the budget per address so a multi-record
                    // name cannot stack attempts past the deadline; 5 s
                    // caps any one attempt within a long window
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match TcpStream::connect_timeout(&a, remaining.min(Duration::from_secs(5)))
                    {
                        Ok(stream) => return Ok(TcpConn::from_stream(stream)),
                        Err(e) => last_err = Some(e),
                    }
                }
            }
            Err(e) => last_err = Some(e),
        }
        if Instant::now() >= deadline {
            bail!(
                "worker: could not reach coordinator at {addr} within {:.0?}: {}",
                timeout,
                last_err.map_or_else(|| "no error recorded".into(), |e| e.to_string())
            );
        }
        std::thread::sleep(Duration::from_millis(250));
    }
}

/// Build `n` connected coordinator↔worker pipes. Returns
/// (coordinator-side conns, worker-side conns), index-aligned.
pub fn establish(mode: ClusterMode, n: usize) -> Result<(Vec<Box<dyn Conn>>, Vec<Box<dyn Conn>>)> {
    let mut coord: Vec<Box<dyn Conn>> = Vec::with_capacity(n);
    let mut work: Vec<Box<dyn Conn>> = Vec::with_capacity(n);
    match mode {
        ClusterMode::Mem => {
            for _ in 0..n {
                let (to_worker_tx, to_worker_rx) = mpsc::channel();
                let (to_coord_tx, to_coord_rx) = mpsc::channel();
                coord.push(Box::new(MemConn {
                    tx: MemTx { tx: to_worker_tx },
                    rx: MemRx { rx: to_coord_rx },
                }));
                work.push(Box::new(MemConn {
                    tx: MemTx { tx: to_coord_tx },
                    rx: MemRx { rx: to_worker_rx },
                }));
            }
        }
        ClusterMode::Tcp => {
            let listener =
                TcpListener::bind(("127.0.0.1", 0)).context("tcp transport: bind loopback")?;
            let addr = listener.local_addr().context("tcp transport: local addr")?;
            for _ in 0..n {
                // connect-then-accept one at a time keeps pairing aligned;
                // the Hello handshake re-checks identity on top anyway.
                let worker_side =
                    TcpStream::connect(addr).context("tcp transport: connect loopback")?;
                let (coord_side, _peer) = listener.accept().context("tcp transport: accept")?;
                coord.push(Box::new(TcpConn::from_stream(coord_side)));
                work.push(Box::new(TcpConn::from_stream(worker_side)));
            }
        }
    }
    Ok((coord, work))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::protocol::{Message, MsgKind};

    fn echo_roundtrip(mode: ClusterMode) {
        let (mut coord, work) = establish(mode, 2).unwrap();
        let mut handles = Vec::new();
        for (w, mut conn) in work.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                // identify, then echo everything until Shutdown
                conn.send(&Message::Hello { worker: w as u32 }.to_envelope()).unwrap();
                loop {
                    let env = conn.recv().unwrap();
                    if env.kind == MsgKind::Shutdown {
                        return;
                    }
                    conn.send(&env).unwrap();
                }
            }));
        }
        for (i, conn) in coord.iter_mut().enumerate() {
            let hello = conn.recv().unwrap();
            match Message::from_envelope(&hello).unwrap() {
                Message::Hello { worker } => assert_eq!(worker as usize, i),
                other => panic!("expected hello, got {other:?}"),
            }
            let msg = Message::BaseSync { base: vec![1.5; 1000 + i] };
            let env = msg.to_envelope();
            conn.send(&env).unwrap();
            let back = conn.recv().unwrap();
            assert_eq!(back, env);
            conn.send(&Message::Shutdown.to_envelope()).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn mem_transport_roundtrips() {
        echo_roundtrip(ClusterMode::Mem);
    }

    #[test]
    fn tcp_transport_roundtrips_on_loopback() {
        echo_roundtrip(ClusterMode::Tcp);
    }

    #[test]
    fn split_halves_work_from_separate_threads() {
        for mode in [ClusterMode::Mem, ClusterMode::Tcp] {
            let (coord, work) = establish(mode, 1).unwrap();
            let mut worker_conn = work.into_iter().next().unwrap();
            let peer = std::thread::spawn(move || {
                for _ in 0..3 {
                    let env = worker_conn.recv().unwrap();
                    worker_conn.send(&env).unwrap();
                }
            });
            let (mut tx, mut rx) = coord.into_iter().next().unwrap().split().unwrap();
            let reader = std::thread::spawn(move || {
                let mut seen = Vec::new();
                for _ in 0..3 {
                    seen.push(rx.recv().unwrap().round);
                }
                seen
            });
            for round in 0..3u64 {
                let env = crate::cluster::protocol::Envelope::new(
                    MsgKind::TrainTask,
                    round,
                    0,
                    0,
                    vec![7; 64],
                );
                tx.send(&env).unwrap();
            }
            assert_eq!(reader.join().unwrap(), vec![0, 1, 2]);
            peer.join().unwrap();
        }
    }

    #[test]
    fn listener_accepts_dialed_connections() {
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        assert!(listener.try_accept().unwrap().is_none(), "nobody connected yet");
        let addr = listener.local_addr().unwrap().to_string();
        let mut worker_side = dial(&addr, Duration::from_secs(5)).unwrap();
        // the non-blocking accept needs a beat for the connection to land
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut coord_side = loop {
            if let Some((conn, _peer)) = listener.try_accept().unwrap() {
                break conn;
            }
            assert!(Instant::now() < deadline, "accept never saw the dialed connection");
            std::thread::sleep(Duration::from_millis(5));
        };
        let env = Message::Hello { worker: 9 }.to_envelope();
        worker_side.send(&env).unwrap();
        assert_eq!(coord_side.recv().unwrap(), env);
    }

    #[test]
    fn dial_times_out_against_a_dead_address() {
        // bind-then-drop guarantees an unoccupied port
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = dial(&format!("127.0.0.1:{port}"), Duration::from_millis(300)).unwrap_err();
        assert!(format!("{err:#}").contains("could not reach coordinator"), "{err:#}");
    }

    #[test]
    fn frame_cap_rejects_oversized_frames_before_allocation() {
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut worker_side = dial(&addr, Duration::from_secs(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut coord_side = loop {
            if let Some((conn, _)) = listener.try_accept().unwrap() {
                break conn;
            }
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        };
        coord_side.set_frame_cap(256);
        // under the cap: passes
        let small = Message::Hello { worker: 1 }.to_envelope();
        worker_side.send(&small).unwrap();
        assert_eq!(coord_side.recv().unwrap(), small);
        // over the cap: rejected with the cap named
        let big = Message::BaseSync { base: vec![1.0; 4096] }.to_envelope();
        worker_side.send(&big).unwrap();
        let err = coord_side.recv().unwrap_err();
        assert!(format!("{err:#}").contains("over the connection cap"), "{err:#}");
        // restoring the default admits big frames again (fresh stream —
        // the oversized frame body is still in flight on the old one)
        coord_side.clear_frame_cap();
    }

    #[test]
    fn scratch_send_produces_identical_frames() {
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker_side = dial(&addr, Duration::from_secs(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut coord_side = loop {
            if let Some((conn, _)) = listener.try_accept().unwrap() {
                break conn;
            }
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        };
        let (mut tx, _rx) = worker_side.split_tcp().unwrap();
        let env = Message::BaseSync { base: vec![2.5; 777] }.to_envelope();
        let mut scratch = Vec::new();
        tx.send_scratch(&env, &mut scratch).unwrap();
        assert_eq!(coord_side.recv().unwrap(), env);
        // a warm resend reuses the buffer (no reallocation) and still
        // produces a frame the standard receive path decodes identically
        let cap = scratch.capacity();
        tx.send_scratch(&env, &mut scratch).unwrap();
        assert_eq!(scratch.capacity(), cap, "warm scratch must not regrow");
        assert_eq!(coord_side.recv().unwrap(), env);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(ClusterMode::parse("mem"), Some(ClusterMode::Mem));
        assert_eq!(ClusterMode::parse("TCP"), Some(ClusterMode::Tcp));
        assert_eq!(ClusterMode::parse("carrier-pigeon"), None);
        assert_eq!(ClusterMode::Mem.name(), "mem");
    }
}
