//! Participant agent: owns its own `Session` (PJRT engine, compiled
//! artifacts, device-resident base) plus the local state of the logical
//! clients it hosts (client id mod worker count), and serves `TrainTask`s
//! until `Shutdown`.
//!
//! A participant reconstructs everything it needs deterministically from
//! the `FedConfig` (see `fed::world`); only wire payloads cross the
//! transport. Per-task batch-RNG streams arrive inside the task, so the
//! result of a task is a pure function of (world, client state, task) —
//! independent of worker count and scheduling order. That is what lets
//! participants run concurrently while staying bitwise-parity with the
//! monolithic `FedRunner`.
//!
//! Participants are oblivious to server-side aggregation sharding: the
//! segment id they echo into the result header (`TrainTask::segment`) is
//! all the router needs to pick a shard, so `--shards N` never changes
//! anything on this side of the transport.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::compress::{wire, Compressed, PayloadArena};
use crate::fed::downlink;
use crate::fed::world::{self, ClientState, World};
use crate::fed::{staleness, FedConfig};
use crate::model::segment_ranges;
use crate::util::rng::Rng;
use crate::xla::PjRtBuffer;

use super::control::FILLED_HORIZON;
use super::protocol::{DownPayload, Message, TrainResult, TrainTask, UpPayload};
use super::transport::Conn;
use super::{Attack, FaultSpec};

/// One worker process's state.
pub struct Participant {
    cfg: FedConfig,
    /// Malicious-client membership mask (empty without attacker
    /// injection) and the corruption those clients apply — see
    /// [`Participant::set_fault`].
    malicious: Vec<bool>,
    attack: Option<Attack>,
    /// The worker's deterministic world (own session, corpus, partition).
    pub world: World,
    mask: PjRtBuffer,
    /// Hosted clients, materialized lazily on first task.
    clients: HashMap<usize, ClientState>,
    /// Per-client downlink reference (mirror of the server's channel).
    refs: HashMap<usize, Vec<f32>>,
    /// Per-client count of stateful downlinks applied, checked against
    /// `TrainTask::down_seq`: a delta lost in transit (dead connection,
    /// worker restart) would silently desynchronize the reference
    /// reconstruction, so the gap fails loudly here instead.
    applied_seq: HashMap<usize, u64>,
    /// Codec scratch reused across tasks (§Perf, codec hot path): the
    /// downlink wire decoder + decoded delta, the uplink update vector,
    /// and the compression output.
    dec: wire::Decoder,
    down_sv: wire::SparseVec,
    update: Vec<f32>,
    comp_out: Compressed,
    /// Pooled, high-water-marked uplink payload buffers: every payload a
    /// task emits is taken from here and recycled once the message has
    /// been sent (or evicted from the result cache), so the steady state
    /// allocates nothing per task — including the payload itself (see
    /// docs/ARCHITECTURE.md §Codec hot path).
    arena: PayloadArena,
    /// Scratch for cache eviction (keys pruned per round).
    prune_keys: Vec<(u64, u32, u32, u64)>,
    /// Results already computed, keyed by task identity `(round, slot,
    /// client, down_seq)`. A resumed coordinator re-dispatches its
    /// crashed round bitwise-identically; answering from the cache keeps
    /// the participant's client state exactly-once while the wire stays
    /// at-least-once. Pruned past [`FILLED_HORIZON`] (the coordinator
    /// can no longer fold anything older).
    done: HashMap<(u64, u32, u32, u64), TrainResult>,
    /// Highest round seen by the cache pruner.
    done_round: u64,
}

impl Participant {
    /// Build a worker's world from the config alone (no host state ever
    /// crosses the transport).
    pub fn new(cfg: FedConfig) -> Result<Participant> {
        let world = World::build(&cfg).context("participant: world build")?;
        let mask_host = cfg.method.grad_mask(&world.session.schema);
        let mask = world.session.upload_mask(&mask_host)?;
        Ok(Participant {
            cfg,
            world,
            mask,
            malicious: Vec::new(),
            attack: None,
            clients: HashMap::new(),
            refs: HashMap::new(),
            applied_seq: HashMap::new(),
            dec: wire::Decoder::new(),
            down_sv: wire::SparseVec::default(),
            update: Vec::new(),
            comp_out: Compressed::default(),
            arena: PayloadArena::default(),
            prune_keys: Vec::new(),
            done: HashMap::new(),
            done_round: 0,
        })
    }

    /// Replace the frozen base (FLoRA merge sync from the coordinator).
    pub fn sync_base(&mut self, base: Vec<f32>) -> Result<()> {
        self.world.session.set_base(base)
    }

    /// Arm attacker injection: the malicious cohort is drawn from its
    /// dedicated salted stream (so honest-client sampling is untouched)
    /// and every update those clients upload is corrupted in `handle`.
    pub fn set_fault(&mut self, fault: Option<FaultSpec>) {
        if let Some(m) = fault.and_then(|f| f.malicious) {
            self.malicious = m.mask(self.cfg.seed, self.cfg.n_clients);
            self.attack = Some(m.attack);
        }
    }

    /// Execute one task: reconstruct the downlink, mix/restart, train
    /// locally, compress the uplink. Mirrors `FedRunner::round`'s
    /// per-client block exactly — keep the two in sync.
    pub fn handle(&mut self, task: &TrainTask) -> Result<TrainResult> {
        let ci = task.client as usize;
        ensure!(ci < self.cfg.n_clients, "task for unknown client {ci}");
        // Exactly-once execution under at-least-once delivery: a task
        // this participant already completed (a resumed coordinator
        // re-dispatching its crashed round) is answered from the cache
        // without touching any client state.
        let key = (task.round, task.slot, task.client, task.down_seq);
        if let Some(hit) = self.done.get(&key) {
            return Ok(clone_result_arena(hit, &mut self.arena));
        }
        let lora_total = self.world.session.schema.lora_total;
        let exec_before = self.world.session.exec_seconds.get();

        // ---- downlink reconstruction ---------------------------------------
        let start_global: Option<Vec<f32>> = match &task.down {
            DownPayload::FloraInit(_) => None,
            DownPayload::DenseF32(g) => {
                ensure!(g.len() == lora_total, "downlink dense f32 length");
                Some(g.clone())
            }
            DownPayload::SparseWire(_) | DownPayload::DenseF16(_) => {
                // every stateful delta builds on the previous one —
                // prove none was lost before mutating the reference. A
                // delta this participant ALREADY applied (a resumed
                // coordinator redelivering its crashed round's task,
                // bitwise-identical by construction) is tolerated: the
                // reference is already at the task's state, so skip the
                // apply and reuse it.
                let applied = self.applied_seq.entry(ci).or_insert(0);
                let duplicate = *applied > 0 && task.down_seq == *applied;
                if !duplicate {
                    ensure!(
                        task.down_seq == *applied + 1,
                        "downlink reference desync for client {ci}: task carries stateful \
                         downlink #{}, this participant has applied {} (a delta was lost in \
                         transit — a restarted or disconnected worker cannot resume this \
                         client's channel; restart the run)",
                        task.down_seq,
                        *applied
                    );
                    *applied += 1;
                }
                let reference = self
                    .refs
                    .entry(ci)
                    .or_insert_with(|| self.world.lora_init.clone());
                // apply straight off the task's payload bytes, reusing the
                // worker's decoder scratch (no payload clone, no per-task
                // SparseVec)
                if !duplicate {
                    match &task.down {
                        DownPayload::SparseWire(b) => {
                            downlink::apply_sparse_down(
                                b,
                                reference,
                                &self.world.kidx,
                                &mut self.dec,
                                &mut self.down_sv,
                            )?;
                        }
                        DownPayload::DenseF16(b) => {
                            downlink::apply_dense_f16(b, reference)?;
                        }
                        _ => unreachable!(),
                    }
                }
                Some(reference.clone())
            }
        };

        if !self.clients.contains_key(&ci) {
            let st = self.world.client_state(&self.cfg, ci);
            self.clients.insert(ci, st);
        }
        let client = self.clients.get_mut(&ci).unwrap();

        // ---- local init: FLoRA restart or Eq. 3 mixing ----------------------
        let (base_point, local): (Vec<f32>, Vec<f32>) = match (&task.down, &start_global) {
            (DownPayload::FloraInit(init), _) => {
                ensure!(init.len() == lora_total, "flora init length");
                (init.clone(), init.clone())
            }
            (_, Some(g)) => {
                let local = if let Some(eco) = self.cfg.eco {
                    let staleness = (task.round.saturating_sub(client.tau)).max(1);
                    let mut mixed = client.lora.clone();
                    staleness::mix_into_local(eco.beta, staleness, g, &mut mixed);
                    mixed
                } else {
                    g.clone()
                };
                (g.clone(), local)
            }
            _ => unreachable!("start_global is Some for every non-restart payload"),
        };

        // ---- local training (code shared with the monolithic runner) -------
        let mut brng = Rng::from_state(task.rng_state);
        let (local, mean_loss) = world::local_train(
            &self.world.session,
            &self.cfg,
            &self.world.ds,
            &self.world.pairs,
            client,
            local,
            &mut brng,
            &self.mask,
        )?;

        // ---- uplink ---------------------------------------------------------
        let update = &mut self.update;
        update.clear();
        update.reserve(lora_total);
        update.extend(local.iter().zip(&base_point).map(|(l, b)| l - b));
        // malicious clients corrupt the delta HERE — before sparsification
        // and encoding — so the poisoned uplink is indistinguishable from
        // an honest one on the wire, and the exactly-once result cache
        // below stores the attacked payload
        if let Some(attack) = self.attack {
            if self.malicious.get(ci).copied().unwrap_or(false) {
                attack.apply(update, self.cfg.seed, task.round, ci);
            }
        }
        let (up, k) = match (&mut client.comp, self.cfg.eco) {
            (Some(comp), Some(_eco)) => {
                // compress + encode through the worker's reusable scratch;
                // the payload Vec itself must be owned by the message, so
                // it is the ONE buffer allocated per task (presized from
                // the high-water mark of earlier rounds)
                comp.compress_into(update, task.l0, task.l_prev, &mut self.comp_out);
                let ranges = segment_ranges(lora_total, (task.n_s as usize).max(1));
                let seg = task.segment as usize;
                ensure!(seg < ranges.len(), "segment {seg} out of range");
                let range = ranges[seg].clone();
                let bytes = comp.encode_range_arena(&self.comp_out, &range, &mut self.arena)?;
                (UpPayload::SparseWire(bytes), self.comp_out.k)
            }
            _ => {
                if self.cfg.method.restarts_lora() {
                    (UpPayload::DenseModule(local.clone()), (0.0, 0.0))
                } else {
                    (UpPayload::DenseUpdate(update.clone()), (0.0, 0.0))
                }
            }
        };

        // ---- persist client state ------------------------------------------
        client.lora = local;
        client.tau = task.round;

        let res = TrainResult {
            round: task.round,
            slot: task.slot,
            client: task.client,
            segment: task.segment,
            n_samples: client.n_samples as u32,
            mean_loss,
            k_a: k.0,
            k_b: k.1,
            exec_s: self.world.session.exec_seconds.get() - exec_before,
            // the update was computed against this round's downlink; the
            // coordinator derives the staleness discount of a late
            // arrival from this field (protocol v2)
            stale_from_round: task.round,
            up,
        };
        if task.round > self.done_round {
            self.done_round = task.round;
            // evict-and-recycle: expired cache entries hand their payload
            // buffers back to the arena instead of dropping them
            let mut prune = std::mem::take(&mut self.prune_keys);
            prune.clear();
            prune.extend(
                self.done.keys().copied().filter(|&(r, ..)| r + FILLED_HORIZON < task.round),
            );
            for k in prune.drain(..) {
                if let Some(old) = self.done.remove(&k) {
                    if let UpPayload::SparseWire(b) = old.up {
                        self.arena.recycle(b);
                    }
                }
            }
            self.prune_keys = prune;
        }
        self.done.insert(key, clone_result_arena(&res, &mut self.arena));
        Ok(res)
    }

    /// Hand a sent (or otherwise finished) result's payload buffer back
    /// to the participant's arena. The steady-state uplink cycle is
    /// take → encode → send → recycle; callers that skip the recycle only
    /// lose pooling, never correctness.
    pub fn recycle_result(&mut self, res: TrainResult) {
        if let UpPayload::SparseWire(b) = res.up {
            self.arena.recycle(b);
        }
    }

    /// Re-send every cached result a resumed coordinator could still
    /// fold: rounds before `resume_round` but within the coordinator's
    /// [`FILLED_HORIZON`] dedup window, in (round, slot) order. Covers
    /// the in-flight straggler whose uplink died with the crashed
    /// coordinator's socket; anything the journal already folded is
    /// dropped server-side by the `filled` dedup.
    pub fn resend_cached(&mut self, conn: &mut dyn Conn, resume_round: u64) -> Result<()> {
        let mut keys: Vec<_> = self
            .done
            .keys()
            .copied()
            .filter(|&(r, ..)| r < resume_round && r + FILLED_HORIZON >= resume_round)
            .collect();
        keys.sort_unstable();
        for key in keys {
            let res = clone_result_arena(&self.done[&key], &mut self.arena);
            let msg = Message::TrainResult(res);
            conn.send(&msg.to_envelope())?;
            if let Message::TrainResult(res) = msg {
                self.recycle_result(res);
            }
        }
        Ok(())
    }
}

/// Clone a cached result for the wire, drawing the payload copy from the
/// arena pool instead of a fresh heap allocation (warm after the first
/// few rounds; the explicit field list keeps this in sync with
/// `TrainResult` by compile error).
fn clone_result_arena(res: &TrainResult, arena: &mut PayloadArena) -> TrainResult {
    let up = match &res.up {
        UpPayload::SparseWire(b) => {
            let mut copy = arena.take();
            copy.extend_from_slice(b);
            UpPayload::SparseWire(copy)
        }
        other => other.clone(),
    };
    TrainResult {
        round: res.round,
        slot: res.slot,
        client: res.client,
        segment: res.segment,
        n_samples: res.n_samples,
        mean_loss: res.mean_loss,
        k_a: res.k_a,
        k_b: res.k_b,
        exec_s: res.exec_s,
        stale_from_round: res.stale_from_round,
        up,
    }
}

/// Serve one worker connection: handshake, then tasks until `Shutdown`.
/// Fatal errors are reported to the coordinator as `Error` messages before
/// the thread exits, so the run fails loudly instead of hanging.
///
/// `fault` injects deterministic misbehaviour: a slow client (every task
/// for the named client sleeps for the configured delay AFTER local
/// training and BEFORE the result is sent — a slow uplink, from the
/// coordinator's point of view) and/or malicious clients (updates
/// corrupted inside `handle`, see [`Participant::set_fault`]) — the hooks
/// behind the dropout/quorum/robustness integration tests and the
/// `--inject-slow` / `--inject-malicious` CLI flags. The participant
/// itself never looks at
/// `TrainTask::deadline_ms`: a worker has no clock reference for the
/// coordinator's dispatch instant, so deadline enforcement (and slot
/// resampling) is entirely server-side.
pub fn run_worker(
    cfg: FedConfig,
    worker_id: u32,
    mut conn: Box<dyn Conn>,
    fault: Option<FaultSpec>,
) -> Result<()> {
    conn.send(&Message::Hello { worker: worker_id }.to_envelope())?;
    let mut participant = match Participant::new(cfg) {
        Ok(p) => p,
        Err(e) => {
            let _ = conn.send(&Message::Error { text: format!("{e:#}") }.to_envelope());
            return Err(e);
        }
    };
    participant.set_fault(fault);
    serve_conn(&mut participant, conn.as_mut(), fault, 0)
}

/// Serve one already-identified connection until `Shutdown`: the task
/// loop shared by in-process workers (after their `Hello`) and remote
/// `ecolora worker` processes (after their protocol-v3 join handshake —
/// see `cluster::deploy::run_remote_worker`, which calls this once per
/// connection so a rejoining worker keeps its participant state).
///
/// `resume_round` is the round the coordinator reported in its
/// `Welcome` (0 for in-process workers and fresh runs): when non-zero,
/// the first `TrainTask` of the connection triggers a re-send of every
/// still-foldable cached result (see [`Participant::resend_cached`]) —
/// a coordinator that crashed and replayed its journal may have lost
/// in-flight uplinks with its socket. Deferred to the first task so the
/// coordinator is provably past its join-wave barrier (which treats
/// early protocol messages as errors).
pub fn serve_conn(
    participant: &mut Participant,
    conn: &mut dyn Conn,
    fault: Option<FaultSpec>,
    resume_round: u64,
) -> Result<()> {
    let mut resend_pending = resume_round > 0;
    loop {
        let env = conn.recv()?;
        let msg = Message::from_envelope(&env)?;
        let step: Result<()> = match msg {
            Message::TrainTask(task) => {
                let resent: Result<()> = if std::mem::take(&mut resend_pending) {
                    participant.resend_cached(conn, resume_round)
                } else {
                    Ok(())
                };
                resent.and_then(|()| participant.handle(&task)).and_then(|res| {
                    if let Some(d) =
                        fault.as_ref().and_then(|f| f.slow_delay(task.client as usize))
                    {
                        std::thread::sleep(d);
                    }
                    let msg = Message::TrainResult(res);
                    conn.send(&msg.to_envelope())?;
                    // sent: the payload buffer goes back to the arena pool
                    if let Message::TrainResult(res) = msg {
                        participant.recycle_result(res);
                    }
                    Ok(())
                })
            }
            Message::BaseSync { base } => participant.sync_base(base),
            Message::Shutdown => return Ok(()),
            other => bail!("participant: unexpected {:?} message", other.kind()),
        };
        if let Err(e) = step {
            let _ = conn.send(&Message::Error { text: format!("{e:#}") }.to_envelope());
            return Err(e);
        }
    }
}
