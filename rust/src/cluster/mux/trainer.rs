//! Per-task training backends for the mux plane.
//!
//! A mux lane runs the SAME downlink/mixing/uplink pipeline as the
//! thread-per-client participant; only the local-training step in the
//! middle is pluggable:
//!
//! * [`Backend::Pjrt`] — real compiled compute through the shared
//!   [`EngineCache`]: a session is leased per task, so steady state holds
//!   `mux_workers` sessions no matter how many clients the host simulates.
//! * [`Backend::Synthetic`] — deterministic host-side arithmetic for the
//!   `--preset synthetic` scale path (10⁴–10⁶ clients, no PJRT, no
//!   artifacts). It consumes the task's forked batch-RNG stream exactly
//!   once per touch, so a result is a pure function of
//!   (config, client state, task) just like the real trainer — the
//!   property every parity and scheduling invariant rests on.

use anyhow::Result;

use crate::fed::world::{self, ClientState, WorldSeed};
use crate::fed::FedConfig;
use crate::util::rng::Rng;

use super::engine_cache::EngineCache;

/// Sparse touches per synthetic local step (keeps the cost of one task
/// O(touches), independent of `lora_total`, so a 10⁶-client smoke run
/// spends its time in scheduling and wire codecs — the paths under test —
/// not in fake math).
const SYNTH_TOUCHES: usize = 64;

/// The training substrate behind a mux plane.
pub enum Backend {
    /// Compiled compute over the shared engine cache.
    Pjrt(EngineCache),
    /// Host-math trainer for artifact-free scale runs. Holds the method's
    /// grad mask so frozen coordinates stay frozen, same as on device.
    Synthetic {
        /// `Method::grad_mask` over the synthetic schema.
        mask: Vec<f32>,
    },
}

impl Backend {
    /// Pick the backend the config calls for: `--preset synthetic` never
    /// touches PJRT; everything else shares one engine via the cache.
    pub fn new(cfg: &FedConfig, seed: std::sync::Arc<WorldSeed>) -> Result<Backend> {
        if cfg.preset == "synthetic" {
            Ok(Backend::Synthetic { mask: cfg.method.grad_mask(&seed.schema) })
        } else {
            Ok(Backend::Pjrt(EngineCache::new(cfg, seed)?))
        }
    }

    /// Run one client's local training. Returns (trained lora, mean local
    /// loss, seconds spent in compiled execution — 0 for synthetic).
    pub fn train(
        &self,
        cfg: &FedConfig,
        seed: &WorldSeed,
        client: &mut ClientState,
        local: Vec<f32>,
        rng: &mut Rng,
    ) -> Result<(Vec<f32>, f64, f64)> {
        match self {
            Backend::Pjrt(cache) => {
                let lease = cache.checkout()?;
                let exec_before = lease.session.exec_seconds.get();
                let (local, mean_loss) = world::local_train(
                    &lease.session,
                    cfg,
                    &seed.ds,
                    &seed.pairs,
                    client,
                    local,
                    rng,
                    &lease.mask,
                )?;
                let exec_s = lease.session.exec_seconds.get() - exec_before;
                Ok((local, mean_loss, exec_s))
            }
            Backend::Synthetic { mask } => {
                let (local, mean_loss) = synthetic_local_train(cfg, mask, local, rng);
                Ok((local, mean_loss, 0.0))
            }
        }
    }

    /// Install a merged base (FLoRA `BaseSync`). The synthetic trainer has
    /// no base model, so the message is a no-op there (the control plane
    /// refuses FLoRA under `--preset synthetic` anyway).
    pub fn sync_base(&self, base: Vec<f32>) -> Result<()> {
        match self {
            Backend::Pjrt(cache) => {
                cache.sync_base(base);
                Ok(())
            }
            Backend::Synthetic { .. } => Ok(()),
        }
    }
}

/// Deterministic stand-in for `world::local_train`: `local_steps` rounds
/// of `SYNTH_TOUCHES` masked sparse perturbations drawn from the task's
/// forked batch stream. Nonzero updates flow through the real compressor,
/// wire codec, and aggregation planes; the pseudo-loss keeps the Eq. 4
/// adaptive-sparsity signal live.
pub fn synthetic_local_train(
    cfg: &FedConfig,
    mask: &[f32],
    mut local: Vec<f32>,
    rng: &mut Rng,
) -> (Vec<f32>, f64) {
    let steps = cfg.local_steps.max(1);
    let scale = cfg.lr * 0.01;
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        let mut grad_sq = 0.0f64;
        for _ in 0..SYNTH_TOUCHES {
            let i = rng.below(local.len());
            let g = rng.normal();
            grad_sq += g * g;
            if mask[i] != 0.0 {
                local[i] -= scale * g as f32;
            }
        }
        loss_sum += 1.0 + 0.1 * (grad_sq / SYNTH_TOUCHES as f64);
    }
    (local, loss_sum / steps as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FedConfig {
        FedConfig::test_profile("synthetic")
    }

    #[test]
    fn synthetic_train_is_a_pure_function_of_rng_state() {
        let cfg = cfg();
        let n = 512;
        let mask = vec![1.0f32; n];
        let start: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        let state = Rng::new(99).fork(7).state();
        let mut r1 = Rng::from_state(state);
        let mut r2 = Rng::from_state(state);
        let (a, la) = synthetic_local_train(&cfg, &mask, start.clone(), &mut r1);
        let (b, lb) = synthetic_local_train(&cfg, &mask, start, &mut r2);
        assert_eq!(a, b, "identical rng state must give bitwise-identical lora");
        assert_eq!(la.to_bits(), lb.to_bits());
        assert_eq!(r1.state(), r2.state(), "both runs consume the same draws");
    }

    #[test]
    fn synthetic_train_changes_only_unmasked_coordinates() {
        let cfg = cfg();
        let n = 256;
        // freeze the upper half
        let mask: Vec<f32> = (0..n).map(|i| if i < n / 2 { 1.0 } else { 0.0 }).collect();
        let start = vec![1.0f32; n];
        let mut rng = Rng::new(5).fork(3);
        let (out, loss) = synthetic_local_train(&cfg, &mask, start.clone(), &mut rng);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(out[..n / 2] != start[..n / 2], "unmasked half must move");
        assert_eq!(out[n / 2..], start[n / 2..], "masked half must stay frozen");
    }

    #[test]
    fn synthetic_train_rng_consumption_is_mask_independent() {
        // masking must not change the draw count, or two methods with
        // different masks would desynchronize downstream streams
        let cfg = cfg();
        let n = 128;
        let state = Rng::new(11).fork(2).state();
        let mut open = Rng::from_state(state);
        let mut frozen = Rng::from_state(state);
        let all_open = vec![1.0; n];
        let all_frozen = vec![0.0; n];
        synthetic_local_train(&cfg, &all_open, vec![0.0; n], &mut open);
        synthetic_local_train(&cfg, &all_frozen, vec![0.0; n], &mut frozen);
        assert_eq!(open.state(), frozen.state());
    }
}
