//! Shared-engine session pool for the mux client plane.
//!
//! Thread-per-client gave every logical client its own `Session` — its
//! own PJRT engine, its own compiled-executable cache, its own device
//! copy of the frozen base — which is what capped a host at N≈32. The
//! mux plane inverts the ownership: ONE [`Engine`] per process (compiled
//! executables are keyed by artifact file inside it, so same-config
//! clients compile once), and a small pool of [`PooledSession`]s checked
//! out per task by whichever compute worker runs the task. Steady state
//! holds at most `mux_workers` sessions, independent of the client
//! population.
//!
//! The pooling substrate ([`Pool`]) is generic and session-free so its
//! concurrency contract — hit/miss accounting, poison-on-panic — is
//! unit-testable without PJRT; [`EngineCache`] layers the session
//! construction, grad-mask upload, and FLoRA base-generation sync on top.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::fed::session::Session;
use crate::fed::world::WorldSeed;
use crate::fed::FedConfig;
use crate::runtime::Engine;
use crate::util::lock_unpoisoned;
use crate::xla::PjRtBuffer;

/// Checkout/return counters a pool accumulates over its lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Checkouts served from the pool (no construction ran).
    pub hits: u64,
    /// Checkouts that had to construct a fresh item.
    pub misses: u64,
    /// Leases discarded because the holding thread panicked — the item
    /// is dropped, never returned to the pool.
    pub poisoned: u64,
}

/// A generic checkout/return pool with poison-on-panic semantics.
///
/// Invariants:
/// * an item is owned by exactly one lease at a time;
/// * a lease dropped during a panic DISCARDS its item (a session mid-
///   panic may hold device state in an unknown condition) and counts it
///   in `poisoned`;
/// * a lease dropped normally returns the item for the next checkout.
pub struct Pool<T> {
    items: Mutex<Vec<T>>,
    hits: AtomicU64,
    misses: AtomicU64,
    poisoned: AtomicU64,
}

impl<T> Default for Pool<T> {
    fn default() -> Self {
        Pool {
            items: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            poisoned: AtomicU64::new(0),
        }
    }
}

impl<T> Pool<T> {
    /// Check an item out, constructing one with `make` only on a miss.
    pub fn checkout_with(&self, make: impl FnOnce() -> Result<T>) -> Result<Lease<'_, T>> {
        let popped = lock_unpoisoned(&self.items).pop();
        let item = match popped {
            Some(item) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                item
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                make()?
            }
        };
        Ok(Lease { pool: self, item: Some(item) })
    }

    /// Items currently idle in the pool.
    pub fn idle(&self) -> usize {
        lock_unpoisoned(&self.items).len()
    }

    /// Lifetime checkout/return counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::Relaxed),
        }
    }
}

/// RAII lease over one pooled item (returns it on drop; discards it when
/// the drop happens during a panic).
pub struct Lease<'a, T> {
    pool: &'a Pool<T>,
    item: Option<T>,
}

impl<T> std::ops::Deref for Lease<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.item.as_ref().expect("lease holds its item until drop")
    }
}

impl<T> std::ops::DerefMut for Lease<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("lease holds its item until drop")
    }
}

impl<T> Drop for Lease<'_, T> {
    fn drop(&mut self) {
        let Some(item) = self.item.take() else { return };
        if std::thread::panicking() {
            // the holder died mid-task: the item's state is suspect
            self.pool.poisoned.fetch_add(1, Ordering::Relaxed);
            drop(item);
        } else {
            lock_unpoisoned(&self.pool.items).push(item);
        }
    }
}

/// One pooled session: compiled artifacts + device base + the uploaded
/// grad mask, plus the FLoRA base generation it last synced to.
pub struct PooledSession {
    /// The PJRT session (engine shared with every other pooled session).
    pub session: Session,
    /// The method's grad mask, device-resident (reused across steps).
    pub mask: PjRtBuffer,
    base_gen: u64,
}

/// The mux plane's shared compiled-compute cache: one engine, a session
/// pool, and the FLoRA base-sync generation.
pub struct EngineCache {
    engine: Arc<Engine>,
    seed: Arc<WorldSeed>,
    mask_host: Vec<f32>,
    pool: Pool<PooledSession>,
    /// Current base weights (updated by `BaseSync`; sessions re-upload
    /// lazily on checkout when their generation is stale).
    base: Mutex<Arc<Vec<f32>>>,
    base_gen: AtomicU64,
}

impl EngineCache {
    /// One engine for the whole plane; sessions materialize lazily on
    /// first checkout per compute worker.
    pub fn new(cfg: &FedConfig, seed: Arc<WorldSeed>) -> Result<EngineCache> {
        let engine = Arc::new(Engine::new(&cfg.artifacts_dir)?);
        let mask_host = cfg.method.grad_mask(&seed.schema);
        let base = Arc::new(seed.base_host.clone());
        Ok(EngineCache {
            engine,
            seed,
            mask_host,
            pool: Pool::default(),
            base: Mutex::new(base),
            base_gen: AtomicU64::new(0),
        })
    }

    /// Check a session out for one task. A cache miss builds a fresh
    /// session over the SHARED engine — compiled executables are reused
    /// across sessions, so the miss costs an upload, not a compile. A
    /// stale base generation (a FLoRA merge landed since this session
    /// last ran) re-uploads the current base before the task sees it.
    pub fn checkout(&self) -> Result<Lease<'_, PooledSession>> {
        let mut lease = self.pool.checkout_with(|| {
            let session = Session::from_seed(self.engine.clone(), &self.seed)?;
            let mask = session.upload_mask(&self.mask_host)?;
            Ok(PooledSession { session, mask, base_gen: 0 })
        })?;
        let gen = self.base_gen.load(Ordering::Acquire);
        if lease.base_gen != gen {
            let base = lock_unpoisoned(&self.base).clone();
            lease.session.set_base((*base).clone())?;
            lease.base_gen = gen;
        }
        Ok(lease)
    }

    /// Install a new frozen base (FLoRA merge sync). Generation-stamped:
    /// pooled sessions re-upload on their next checkout, not eagerly.
    pub fn sync_base(&self, base: Vec<f32>) {
        *lock_unpoisoned(&self.base) = Arc::new(base);
        self.base_gen.fetch_add(1, Ordering::Release);
    }

    /// Lifetime checkout/return counters.
    pub fn stats(&self) -> CacheStats {
        self.pool.stats()
    }

    /// Sessions currently idle in the pool.
    pub fn idle_sessions(&self) -> usize {
        self.pool.idle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_hits_after_first_return() {
        let pool: Pool<u32> = Pool::default();
        {
            let lease = pool.checkout_with(|| Ok(7)).unwrap();
            assert_eq!(*lease, 7);
        }
        assert_eq!(pool.idle(), 1);
        {
            let lease = pool.checkout_with(|| Ok(99)).unwrap();
            assert_eq!(*lease, 7, "second checkout reuses the returned item");
        }
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.poisoned), (1, 1, 0));
    }

    #[test]
    fn panicking_holder_poisons_instead_of_returning() {
        let pool: Pool<u32> = Pool::default();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _lease = pool.checkout_with(|| Ok(1)).unwrap();
            panic!("task died mid-lease");
        }));
        assert!(r.is_err());
        assert_eq!(pool.idle(), 0, "a poisoned item never re-enters the pool");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.poisoned), (0, 1, 1));
        // the pool itself stays usable
        let lease = pool.checkout_with(|| Ok(2)).unwrap();
        assert_eq!(*lease, 2);
    }

    #[test]
    fn concurrent_checkout_return_under_poison_keeps_counters_consistent() {
        let pool: Arc<Pool<usize>> = Arc::new(Pool::default());
        let threads = 8;
        let iters = 200;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    for i in 0..iters {
                        if (t + i) % 17 == 0 {
                            // a deliberately panicking holder
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    let _lease = pool.checkout_with(|| Ok(t)).unwrap();
                                    panic!("poison");
                                }),
                            );
                        } else {
                            let lease = pool.checkout_with(|| Ok(t)).unwrap();
                            assert!(*lease < threads);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, (threads * iters) as u64, "every checkout counted once");
        assert!(s.poisoned > 0, "the panicking holders must have poisoned some leases");
        // conservation: items constructed = items idle + items poisoned
        assert_eq!(s.misses, pool.idle() as u64 + s.poisoned);
    }
}
