//! Event-driven client multiplexer: the massive-scale in-process client
//! plane.
//!
//! The thread-per-client plane (`cluster::participant`) spawns one OS
//! thread per worker connection and builds one full `World` — its own
//! PJRT engine, corpus, partition — per thread. That caps a host at tens
//! of simulated clients. This module replaces it with an event-driven
//! plane that simulates 10⁴–10⁶ logical clients on a fixed number of OS
//! threads:
//!
//! * **Lanes** — one per worker connection, exactly as many as the
//!   coordinator's `n_workers`. Client ownership stays `ci % n_workers`,
//!   so lane assignment is bitwise-identical to the threads plane and the
//!   coordinator cannot tell the two apart.
//! * **RX pumps** — one lightweight thread per lane that only decodes
//!   envelopes and feeds the shared ready queue. Pumps never compute.
//! * **Compute pool** — `mux_workers` threads (default: CPU cores) that
//!   pop ready lanes and drive each lane's per-client state machines
//!   (Idle → Tasked → Training → Uploading). At most one message per
//!   lane is in flight at a time, so per-lane FIFO order — the order the
//!   stateful downlink protocol requires — is preserved while different
//!   lanes train concurrently.
//! * **Shared world** — ONE [`WorldSeed`](crate::fed::world::WorldSeed)
//!   for the whole plane (the threads plane builds one per worker) and
//!   one training [`Backend`]: either the shared
//!   [`EngineCache`](engine_cache::EngineCache) session pool or the
//!   artifact-free synthetic trainer.
//!
//! Per-client cost is O(active cohort): lane client state, downlink
//! references, and sessions all materialize lazily on first task, so an
//! inactive population of a million clients costs nothing but the
//! coordinator's (also lazy) bookkeeping.
//!
//! Parity: a task result is a pure function of (world, client state,
//! task) — the per-task RNG stream arrives inside the task — and the
//! lane pipeline below mirrors `Participant::handle` statement for
//! statement. Scheduling order across lanes only affects arrival order,
//! which the aggregation plane already sorts out (shards order pending
//! results by slot; `finish_round` walks slots in order).

pub mod engine_cache;
pub mod trainer;

pub use engine_cache::{CacheStats, EngineCache};
pub use trainer::Backend;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::compress::{wire, Compressed, PayloadArena};
use crate::fed::downlink;
use crate::fed::world::{ClientState, WorldSeed};
use crate::fed::{staleness, FedConfig};
use crate::model::segment_ranges;
use crate::util::lock_unpoisoned;
use crate::util::rng::Rng;

use super::protocol::{DownPayload, Message, TrainResult, TrainTask, UpPayload};
use super::transport::{Conn, ConnRx, ConnTx};
use super::{Attack, FaultSpec};

/// Tuning knobs for one mux plane.
#[derive(Debug, Clone)]
pub struct MuxOptions {
    /// Compute-pool size (threads actually training). The CLI defaults
    /// this to the host's core count.
    pub workers: usize,
    /// Deterministic fault injection (same semantics as the threads
    /// plane: a slow client's uplink sleeps before sending; malicious
    /// clients corrupt their update deltas inside `handle_task`).
    pub fault: Option<FaultSpec>,
}

/// Lifecycle of one lane's current unit of work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum LaneState {
    /// Nothing queued, nothing running.
    Idle = 0,
    /// Work is queued (the lane sits in the ready queue, unclaimed).
    Tasked = 1,
    /// A compute worker is running local training for this lane.
    Training = 2,
    /// The result is being serialized/sent (including any injected
    /// straggler delay).
    Uploading = 3,
}

impl LaneState {
    fn from_u8(x: u8) -> LaneState {
        match x {
            0 => LaneState::Idle,
            1 => LaneState::Tasked,
            2 => LaneState::Training,
            _ => LaneState::Uploading,
        }
    }
}

/// The legal lane transitions. Control messages (`BaseSync`, `Shutdown`)
/// travel Tasked → Idle/Tasked without a Training phase; a finished
/// upload re-arms straight to Tasked when more work is already queued.
pub fn lane_step_ok(from: LaneState, to: LaneState) -> bool {
    use LaneState::*;
    matches!(
        (from, to),
        (Idle, Tasked)
            | (Tasked, Training)
            | (Tasked, Tasked)
            | (Tasked, Idle)
            | (Training, Uploading)
            | (Uploading, Idle)
            | (Uploading, Tasked)
    )
}

/// Per-lane inbox: FIFO of decoded messages plus the claim flag that
/// guarantees at most one in-flight message per lane.
struct Inbox {
    queue: VecDeque<Message>,
    /// True while the lane is in the ready queue or being processed.
    in_flight: bool,
    /// False once the lane saw `Shutdown` (or failed); late messages are
    /// dropped instead of queued.
    live: bool,
}

/// Per-lane client state and codec scratch — the exact fields
/// `cluster::participant::Participant` keeps, minus the world and session
/// (shared plane-wide here). Locked only by the lane's single in-flight
/// task, so the mutex is uncontended by construction.
#[derive(Default)]
struct LaneCore {
    /// Hosted clients, materialized lazily on first task.
    clients: HashMap<usize, ClientState>,
    /// Per-client downlink reference (mirror of the server's channel).
    refs: HashMap<usize, Vec<f32>>,
    /// Per-client stateful-downlink count, checked against
    /// `TrainTask::down_seq` (lost-delta detection).
    applied_seq: HashMap<usize, u64>,
    dec: wire::Decoder,
    down_sv: wire::SparseVec,
    update: Vec<f32>,
    comp_out: Compressed,
    /// Pooled uplink payload buffers (take → encode → send → recycle);
    /// per-lane, so pool traffic needs no extra synchronization beyond
    /// the lane's own core mutex.
    arena: PayloadArena,
}

struct Lane {
    inbox: Mutex<Inbox>,
    core: Mutex<LaneCore>,
    tx: Mutex<Box<dyn ConnTx>>,
    state: AtomicU8,
}

impl Lane {
    fn advance(&self, to: LaneState) {
        let from = LaneState::from_u8(self.state.swap(to as u8, Ordering::Relaxed));
        debug_assert!(lane_step_ok(from, to), "illegal lane transition {from:?} -> {to:?}");
    }
}

/// Ready queue + liveness shared by pumps and the compute pool. The lane
/// count and the condvar share the ready mutex's critical section so a
/// final `Shutdown` can never slip between a worker's emptiness check and
/// its wait (lost-wakeup hazard).
struct Scheduler {
    ready: Mutex<VecDeque<usize>>,
    cv: Condvar,
    live_lanes: AtomicUsize,
    failure: Mutex<Option<String>>,
}

struct Plane {
    cfg: FedConfig,
    seed: Arc<WorldSeed>,
    backend: Backend,
    lanes: Vec<Lane>,
    sched: Scheduler,
    fault: Option<FaultSpec>,
    /// Malicious-client membership mask (empty without attacker
    /// injection) and the corruption those clients apply, precomputed
    /// once from the fault spec's dedicated RNG stream (honest sampling
    /// is bitwise-unaffected) and shared read-only by every lane.
    malicious: Vec<bool>,
    attack: Option<Attack>,
    /// Straggler helper threads (joined before the plane returns).
    helpers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Run the whole client plane over the given worker connections. This is
/// the mux-mode replacement for spawning one `participant::run_worker`
/// thread per connection: ONE call, `conns.len()` lanes, `opts.workers`
/// compute threads, one shared world.
///
/// Mirrors `run_worker`'s contract per lane: sends `Hello` for every lane
/// before the (slow) world build, reports build failures as `Error`
/// messages on every lane, serves tasks until each lane's `Shutdown`.
pub fn run_plane(cfg: FedConfig, conns: Vec<Box<dyn Conn>>, opts: MuxOptions) -> Result<()> {
    let n_lanes = conns.len();
    ensure!(n_lanes > 0, "mux plane needs at least one lane");
    // split + Hello first so the coordinator's install loop proceeds
    // while the world builds
    let mut txs = Vec::with_capacity(n_lanes);
    let mut rxs = Vec::with_capacity(n_lanes);
    for (w, conn) in conns.into_iter().enumerate() {
        let (mut tx, rx) = conn.split()?;
        tx.send(&Message::Hello { worker: w as u32 }.to_envelope())?;
        txs.push(tx);
        rxs.push(rx);
    }

    let built: Result<(Arc<WorldSeed>, Backend)> = (|| {
        let seed = Arc::new(WorldSeed::build(&cfg).context("mux plane: world build")?);
        let backend = Backend::new(&cfg, seed.clone())?;
        Ok((seed, backend))
    })();
    let (seed, backend) = match built {
        Ok(x) => x,
        Err(e) => {
            for tx in &mut txs {
                let _ = tx.send(&Message::Error { text: format!("{e:#}") }.to_envelope());
            }
            return Err(e);
        }
    };

    let lanes: Vec<Lane> = txs
        .into_iter()
        .map(|tx| Lane {
            inbox: Mutex::new(Inbox { queue: VecDeque::new(), in_flight: false, live: true }),
            core: Mutex::new(LaneCore::default()),
            tx: Mutex::new(tx),
            state: AtomicU8::new(LaneState::Idle as u8),
        })
        .collect();
    let (malicious, attack) = match opts.fault.and_then(|f| f.malicious) {
        Some(m) => (m.mask(cfg.seed, cfg.n_clients), Some(m.attack)),
        None => (Vec::new(), None),
    };
    let plane = Arc::new(Plane {
        cfg,
        seed,
        backend,
        lanes,
        sched: Scheduler {
            ready: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            live_lanes: AtomicUsize::new(n_lanes),
            failure: Mutex::new(None),
        },
        fault: opts.fault,
        malicious,
        attack,
        helpers: Mutex::new(Vec::new()),
    });

    let mut pumps = Vec::with_capacity(n_lanes);
    for (li, rx) in rxs.into_iter().enumerate() {
        let plane = plane.clone();
        pumps.push(
            std::thread::Builder::new()
                .name(format!("ecolora-mux-rx-{li}"))
                .spawn(move || pump_lane(&plane, li, rx))?,
        );
    }
    let n_workers = opts.workers.max(1);
    let mut workers = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let plane = plane.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("ecolora-mux-cpu-{w}"))
                .spawn(move || compute_loop(&plane))?,
        );
    }

    for h in pumps {
        h.join().map_err(|_| anyhow!("mux plane: rx pump panicked"))?;
    }
    for h in workers {
        h.join().map_err(|_| anyhow!("mux plane: compute worker panicked"))?;
    }
    let helpers = std::mem::take(&mut *lock_unpoisoned(&plane.helpers));
    for h in helpers {
        h.join().map_err(|_| anyhow!("mux plane: straggler helper panicked"))?;
    }
    match lock_unpoisoned(&plane.sched.failure).take() {
        Some(text) => bail!("mux plane: {text}"),
        None => Ok(()),
    }
}

/// RX pump: decode one lane's envelopes into its inbox until `Shutdown`
/// or peer loss. Decode failures fail the lane loudly (`Error` back to
/// the coordinator) — same as the threads plane's serve loop.
fn pump_lane(plane: &Plane, li: usize, mut rx: Box<dyn ConnRx>) {
    loop {
        let env = match rx.recv() {
            Ok(env) => env,
            // peer gone: the coordinator dropped us (or the run is over);
            // retire the lane as if Shutdown arrived
            Err(_) => {
                enqueue(plane, li, Message::Shutdown);
                return;
            }
        };
        match Message::from_envelope(&env) {
            Ok(msg) => {
                let is_shutdown = matches!(msg, Message::Shutdown);
                enqueue(plane, li, msg);
                if is_shutdown {
                    return;
                }
            }
            Err(e) => {
                lane_fail(plane, li, e);
                enqueue(plane, li, Message::Shutdown);
                return;
            }
        }
    }
}

/// Queue a message on a lane; arm the lane in the ready queue unless it
/// is already claimed (at most one in-flight message per lane).
fn enqueue(plane: &Plane, li: usize, msg: Message) {
    let lane = &plane.lanes[li];
    let mut inbox = lock_unpoisoned(&lane.inbox);
    if !inbox.live {
        return;
    }
    inbox.queue.push_back(msg);
    if !inbox.in_flight {
        inbox.in_flight = true;
        drop(inbox);
        lane.advance(LaneState::Tasked);
        push_ready(plane, li);
    }
}

fn push_ready(plane: &Plane, li: usize) {
    let mut ready = lock_unpoisoned(&plane.sched.ready);
    ready.push_back(li);
    plane.sched.cv.notify_one();
}

/// Mark a lane dead and wake the pool if it was the last one. The
/// decrement shares the ready mutex with the workers' check-then-wait so
/// the final wakeup cannot be lost.
fn retire_lane(plane: &Plane, li: usize) {
    let was_live = {
        let mut inbox = lock_unpoisoned(&plane.lanes[li].inbox);
        std::mem::replace(&mut inbox.live, false)
    };
    if was_live {
        let _ready = lock_unpoisoned(&plane.sched.ready);
        if plane.sched.live_lanes.fetch_sub(1, Ordering::AcqRel) == 1 {
            plane.sched.cv.notify_all();
        }
    }
}

/// Report a lane failure to the coordinator and record it as the plane's
/// exit error (first failure wins), then retire the lane.
fn lane_fail(plane: &Plane, li: usize, e: anyhow::Error) {
    let text = format!("{e:#}");
    let _ = lock_unpoisoned(&plane.lanes[li].tx)
        .send(&Message::Error { text: text.clone() }.to_envelope());
    lock_unpoisoned(&plane.sched.failure).get_or_insert(text);
    retire_lane(plane, li);
}

/// Release a lane after one message: re-arm it if more work is queued,
/// otherwise return it to Idle.
fn finish_lane(plane: &Plane, li: usize) {
    let lane = &plane.lanes[li];
    let mut inbox = lock_unpoisoned(&lane.inbox);
    if inbox.live && !inbox.queue.is_empty() {
        drop(inbox);
        lane.advance(LaneState::Tasked);
        push_ready(plane, li);
    } else {
        inbox.in_flight = false;
        drop(inbox);
        lane.advance(LaneState::Idle);
    }
}

/// One compute worker: pop ready lanes and drive their state machines
/// until every lane has retired.
fn compute_loop(plane: &Arc<Plane>) {
    loop {
        let li = {
            let mut ready = lock_unpoisoned(&plane.sched.ready);
            loop {
                if let Some(li) = ready.pop_front() {
                    break li;
                }
                if plane.sched.live_lanes.load(Ordering::Acquire) == 0 {
                    return;
                }
                ready = plane
                    .sched
                    .cv
                    .wait(ready)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let msg = lock_unpoisoned(&plane.lanes[li].inbox).queue.pop_front();
        let Some(msg) = msg else {
            finish_lane(plane, li);
            continue;
        };
        match msg {
            Message::TrainTask(task) => run_task(plane, li, task),
            Message::BaseSync { base } => {
                if let Err(e) = plane.backend.sync_base(base) {
                    lane_fail(plane, li, e);
                }
                finish_lane(plane, li);
            }
            Message::Shutdown => {
                retire_lane(plane, li);
                finish_lane(plane, li);
            }
            other => {
                lane_fail(plane, li, anyhow!("mux lane: unexpected {:?} message", other.kind()));
                finish_lane(plane, li);
            }
        }
    }
}

/// Train one task on a lane: Tasked → Training → Uploading → (Idle |
/// Tasked). An injected straggler delay rides a helper thread so the
/// sleep occupies the lane (as it must — the coordinator is timing this
/// client's uplink) but never a compute-pool slot.
fn run_task(plane: &Arc<Plane>, li: usize, task: TrainTask) {
    plane.lanes[li].advance(LaneState::Training);
    let res = {
        let mut core = lock_unpoisoned(&plane.lanes[li].core);
        handle_task(plane, &mut core, &task)
    };
    plane.lanes[li].advance(LaneState::Uploading);
    match res {
        Ok(res) => {
            let delay = plane.fault.and_then(|f| f.slow_delay(task.client as usize));
            if let Some(delay) = delay {
                let plane2 = plane.clone();
                let helper = std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    send_result(&plane2, li, res);
                    finish_lane(&plane2, li);
                });
                lock_unpoisoned(&plane.helpers).push(helper);
            } else {
                send_result(plane, li, res);
                finish_lane(plane, li);
            }
        }
        Err(e) => {
            lane_fail(plane, li, e);
            finish_lane(plane, li);
        }
    }
}

fn send_result(plane: &Plane, li: usize, res: TrainResult) {
    let msg = Message::TrainResult(res);
    let sent = lock_unpoisoned(&plane.lanes[li].tx).send(&msg.to_envelope());
    match sent {
        Ok(()) => {
            // sent: hand the payload buffer back to the lane's arena pool
            if let Message::TrainResult(res) = msg {
                if let UpPayload::SparseWire(b) = res.up {
                    lock_unpoisoned(&plane.lanes[li].core).arena.recycle(b);
                }
            }
        }
        Err(e) => lane_fail(plane, li, e),
    }
}

/// Execute one task against a lane's client state. Mirrors
/// `Participant::handle` statement for statement — keep the two in sync —
/// with the world shared plane-wide and the training step behind
/// [`Backend`].
fn handle_task(plane: &Plane, core: &mut LaneCore, task: &TrainTask) -> Result<TrainResult> {
    let cfg = &plane.cfg;
    let seed = &plane.seed;
    let ci = task.client as usize;
    ensure!(ci < cfg.n_clients, "task for unknown client {ci}");
    let lora_total = seed.schema.lora_total;

    // ---- downlink reconstruction ---------------------------------------
    let start_global: Option<Vec<f32>> = match &task.down {
        DownPayload::FloraInit(_) => None,
        DownPayload::DenseF32(g) => {
            ensure!(g.len() == lora_total, "downlink dense f32 length");
            Some(g.clone())
        }
        DownPayload::SparseWire(_) | DownPayload::DenseF16(_) => {
            let applied = core.applied_seq.entry(ci).or_insert(0);
            ensure!(
                task.down_seq == *applied + 1,
                "downlink reference desync for client {ci}: task carries stateful \
                 downlink #{}, this lane has applied {} (a delta was lost in \
                 transit — a restarted or disconnected worker cannot resume this \
                 client's channel; restart the run)",
                task.down_seq,
                *applied
            );
            *applied += 1;
            let reference = core.refs.entry(ci).or_insert_with(|| seed.lora_init.clone());
            match &task.down {
                DownPayload::SparseWire(b) => {
                    downlink::apply_sparse_down(
                        b,
                        reference,
                        &seed.kidx,
                        &mut core.dec,
                        &mut core.down_sv,
                    )?;
                }
                DownPayload::DenseF16(b) => {
                    downlink::apply_dense_f16(b, reference)?;
                }
                _ => unreachable!(),
            }
            Some(reference.clone())
        }
    };

    if !core.clients.contains_key(&ci) {
        let st = seed.client_state(cfg, ci);
        core.clients.insert(ci, st);
    }
    let client = core.clients.get_mut(&ci).unwrap();

    // ---- local init: FLoRA restart or Eq. 3 mixing ----------------------
    let (base_point, local): (Vec<f32>, Vec<f32>) = match (&task.down, &start_global) {
        (DownPayload::FloraInit(init), _) => {
            ensure!(init.len() == lora_total, "flora init length");
            (init.clone(), init.clone())
        }
        (_, Some(g)) => {
            let local = if let Some(eco) = cfg.eco {
                let staleness = (task.round.saturating_sub(client.tau)).max(1);
                let mut mixed = client.lora.clone();
                staleness::mix_into_local(eco.beta, staleness, g, &mut mixed);
                mixed
            } else {
                g.clone()
            };
            (g.clone(), local)
        }
        _ => unreachable!("start_global is Some for every non-restart payload"),
    };

    // ---- local training (behind the plane's backend) --------------------
    let mut brng = Rng::from_state(task.rng_state);
    let (local, mean_loss, exec_s) = plane.backend.train(cfg, seed, client, local, &mut brng)?;

    // ---- uplink ---------------------------------------------------------
    let update = &mut core.update;
    update.clear();
    update.reserve(lora_total);
    update.extend(local.iter().zip(&base_point).map(|(l, b)| l - b));
    // malicious clients corrupt the delta HERE — before sparsification and
    // encoding — mirroring `Participant::handle`
    if let Some(attack) = plane.attack {
        if plane.malicious.get(ci).copied().unwrap_or(false) {
            attack.apply(update, cfg.seed, task.round, ci);
        }
    }
    let (up, k) = match (&mut client.comp, cfg.eco) {
        (Some(comp), Some(_eco)) => {
            comp.compress_into(update, task.l0, task.l_prev, &mut core.comp_out);
            let ranges = segment_ranges(lora_total, (task.n_s as usize).max(1));
            let seg = task.segment as usize;
            ensure!(seg < ranges.len(), "segment {seg} out of range");
            let range = ranges[seg].clone();
            let bytes = comp.encode_range_arena(&core.comp_out, &range, &mut core.arena)?;
            (UpPayload::SparseWire(bytes), core.comp_out.k)
        }
        _ => {
            if cfg.method.restarts_lora() {
                (UpPayload::DenseModule(local.clone()), (0.0, 0.0))
            } else {
                (UpPayload::DenseUpdate(update.clone()), (0.0, 0.0))
            }
        }
    };

    // ---- persist client state ------------------------------------------
    client.lora = local;
    client.tau = task.round;

    Ok(TrainResult {
        round: task.round,
        slot: task.slot,
        client: task.client,
        segment: task.segment,
        n_samples: client.n_samples as u32,
        mean_loss,
        k_a: k.0,
        k_b: k.1,
        exec_s,
        stale_from_round: task.round,
        up,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_state_machine_allows_exactly_the_documented_transitions() {
        use LaneState::*;
        let all = [Idle, Tasked, Training, Uploading];
        let legal = [
            (Idle, Tasked),
            (Tasked, Training),
            (Tasked, Tasked),
            (Tasked, Idle),
            (Training, Uploading),
            (Uploading, Idle),
            (Uploading, Tasked),
        ];
        for &from in &all {
            for &to in &all {
                assert_eq!(
                    lane_step_ok(from, to),
                    legal.contains(&(from, to)),
                    "transition {from:?} -> {to:?}"
                );
            }
        }
    }

    #[test]
    fn lane_state_u8_roundtrip() {
        use LaneState::*;
        for s in [Idle, Tasked, Training, Uploading] {
            assert_eq!(LaneState::from_u8(s as u8), s);
        }
    }
}
