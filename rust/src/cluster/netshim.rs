//! Optional netsim shim at the transport layer.
//!
//! Wraps the coordinator-side send/receive halves so every framed message
//! is counted (direction, round, kind, exact bytes incl. the 4-byte frame
//! prefix) as it crosses the transport. After a round, the recorded
//! TrainTask/TrainResult flows replay through the discrete-event network
//! simulator under a bandwidth `Scenario`, giving Figure-3-style round
//! timing for the REAL protocol bytes — compression, envelope overhead
//! and all — instead of the analytic payload estimates.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::netsim::{NetSim, RoundPlan, RoundTiming, Scenario};
use crate::util::lock_unpoisoned;

use super::protocol::{Envelope, MsgKind};
use super::transport::{ConnRx, ConnTx};

/// One observed message crossing the transport.
#[derive(Debug, Clone, Copy)]
pub struct Flow {
    pub round: u64,
    pub kind: MsgKind,
    /// Framed size: header + payload + length prefix.
    pub bytes: usize,
    /// true = coordinator → worker (downlink direction).
    pub to_worker: bool,
    /// Round slot, for task/result messages (peeked from the payload —
    /// `slot` is the first field of both, see `protocol`).
    pub slot: Option<u32>,
}

fn slot_of(env: &Envelope) -> Option<u32> {
    match env.kind {
        MsgKind::TrainTask | MsgKind::TrainResult => env
            .payload
            .get(0..4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap())),
        _ => None,
    }
}

/// Shared traffic journal, filled by the metered halves.
#[derive(Debug, Default)]
pub struct TrafficLog {
    pub flows: Vec<Flow>,
}

/// Byte meter handed to `wrap_tx`/`wrap_rx`.
#[derive(Clone, Default)]
pub struct Meter {
    log: Arc<Mutex<TrafficLog>>,
}

/// 4-byte length prefix used by the TCP framing (counted uniformly so mem
/// and tcp runs report comparable numbers).
const FRAME_PREFIX: usize = 4;

impl Meter {
    pub fn new() -> Meter {
        Meter::default()
    }

    fn record(&self, env: &Envelope, to_worker: bool) {
        lock_unpoisoned(&self.log).flows.push(Flow {
            round: env.round,
            kind: env.kind,
            bytes: env.encoded_len() + FRAME_PREFIX,
            to_worker,
            slot: slot_of(env),
        });
    }

    pub fn wrap_tx(&self, inner: Box<dyn ConnTx>) -> Box<dyn ConnTx> {
        Box::new(MeteredTx { inner, meter: self.clone() })
    }

    pub fn wrap_rx(&self, inner: Box<dyn ConnRx>) -> Box<dyn ConnRx> {
        Box::new(MeteredRx { inner, meter: self.clone() })
    }

    /// Total bytes each direction for `round` (task/result messages only).
    pub fn round_bytes(&self, round: u64) -> (usize, usize) {
        let log = lock_unpoisoned(&self.log);
        let mut down = 0;
        let mut up = 0;
        for f in log.flows.iter().filter(|f| f.round == round) {
            match f.kind {
                MsgKind::TrainTask if f.to_worker => down += f.bytes,
                MsgKind::TrainResult if !f.to_worker => up += f.bytes,
                _ => {}
            }
        }
        (down, up)
    }

    /// Replay `round`'s traffic through the discrete-event simulator:
    /// one `RoundPlan` per slot, with the slot's task bytes, result bytes
    /// and compute seconds matched by slot id (recording order carries no
    /// meaning — results arrive in any order). `compute_s` is indexed by
    /// slot, as produced by `RoundState::exec_by_slot`.
    pub fn round_timing(
        &self,
        round: u64,
        compute_s: &[f64],
        scenario: &Scenario,
    ) -> Result<RoundTiming> {
        let n = compute_s.len();
        let mut dl = vec![None; n];
        let mut ul = vec![None; n];
        {
            let log = lock_unpoisoned(&self.log);
            for f in log.flows.iter().filter(|f| f.round == round) {
                let target = match (f.kind, f.to_worker) {
                    (MsgKind::TrainTask, true) => &mut dl,
                    (MsgKind::TrainResult, false) => &mut ul,
                    _ => continue,
                };
                if let Some(slot) = f.slot {
                    if let Some(entry) = target.get_mut(slot as usize) {
                        *entry = Some(f.bytes);
                    }
                }
            }
        }
        let plans: Vec<RoundPlan> = (0..n)
            .filter_map(|slot| match (dl[slot], ul[slot]) {
                (Some(d), Some(u)) => {
                    Some(RoundPlan { dl_bytes: d, compute_s: compute_s[slot], ul_bytes: u })
                }
                _ => None,
            })
            .collect();
        anyhow::ensure!(!plans.is_empty(), "netsim shim: no traffic recorded for round {round}");
        let mut sim = NetSim::homogeneous(plans.len(), scenario.link());
        let clients: Vec<usize> = (0..plans.len()).collect();
        Ok(sim.run_round(&clients, &plans))
    }
}

struct MeteredTx {
    inner: Box<dyn ConnTx>,
    meter: Meter,
}

impl ConnTx for MeteredTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.meter.record(env, true);
        self.inner.send(env)
    }
}

struct MeteredRx {
    inner: Box<dyn ConnRx>,
    meter: Meter,
}

impl ConnRx for MeteredRx {
    fn recv(&mut self) -> Result<Envelope> {
        let env = self.inner.recv()?;
        self.meter.record(&env, false);
        Ok(env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::{establish, ClusterMode};
    use crate::netsim::Scenario;

    /// Task/result payload with the given slot in the leading u32 (the
    /// field `round_timing` peeks) followed by padding to `len` bytes.
    fn slot_payload(slot: u32, len: usize) -> Vec<u8> {
        let mut p = slot.to_le_bytes().to_vec();
        p.resize(len, 0xEE);
        p
    }

    #[test]
    fn meter_records_and_replays_round_traffic() {
        let (coord, work) = establish(ClusterMode::Mem, 1).unwrap();
        let mut worker = work.into_iter().next().unwrap();
        let peer = std::thread::spawn(move || {
            // echo tasks back as results in REVERSE slot order: slot
            // matching must not depend on arrival order
            let mut seen = Vec::new();
            for _ in 0..3 {
                seen.push(worker.recv().unwrap());
            }
            for env in seen.into_iter().rev() {
                let reply = Envelope::new(
                    MsgKind::TrainResult,
                    env.round,
                    env.segment,
                    1,
                    env.payload[0..4].iter().copied().chain([0xAB; 36]).collect(),
                );
                worker.send(&reply).unwrap();
            }
        });
        let meter = Meter::new();
        let (tx, rx) = coord.into_iter().next().unwrap().split().unwrap();
        let mut tx = meter.wrap_tx(tx);
        let mut rx = meter.wrap_rx(rx);
        for slot in 0..3u32 {
            tx.send(&Envelope::new(MsgKind::TrainTask, 7, 0, 0, slot_payload(slot, 100))).unwrap();
        }
        for _ in 0..3 {
            rx.recv().unwrap();
        }
        peer.join().unwrap();

        let (down, up) = meter.round_bytes(7);
        assert_eq!(down, 3 * (28 + 100 + 4));
        assert_eq!(up, 3 * (28 + 40 + 4));
        assert_eq!(meter.round_bytes(8), (0, 0));

        let scenario = Scenario { name: "test", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
        let timing = meter.round_timing(7, &[0.5, 0.5, 0.5], &scenario).unwrap();
        assert!(timing.round_s > 0.5, "{timing:?}");
        assert!((timing.compute_s - 0.5).abs() < 1e-12);
        assert!(timing.comm_s > 0.0);
        // a round with no recorded traffic is an error, not a zero timing
        assert!(meter.round_timing(9, &[0.5], &scenario).is_err());
    }
}
