//! Optional netsim shim at the transport layer.
//!
//! Wraps the coordinator-side send/receive halves so every framed message
//! is counted (direction, round, kind, exact bytes incl. the 4-byte frame
//! prefix) as it crosses the transport. After a round, the recorded
//! TrainTask/TrainResult flows replay through the discrete-event network
//! simulator under a bandwidth `Scenario`, giving Figure-3-style round
//! timing for the REAL protocol bytes — compression, envelope overhead
//! and all — instead of the analytic payload estimates.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::netsim::{LinkSpec, NetSim, RoundPlan, RoundTiming, Scenario};
use crate::util::lock_unpoisoned;

use super::protocol::{Envelope, MsgKind};
use super::transport::{ConnRx, ConnTx};

/// What the shim simulates: a base bandwidth scenario plus an optional
/// heterogeneous tail — a fraction of each round's slots whose access
/// links are `slow_factor`× slower than the scenario's. Heterogeneity is
/// what makes quorum rounds measurably faster than synchronous ones: the
/// slow tail stops gating the round once K of N uploads suffice.
///
/// `agg_mbps` optionally models the server-side aggregation stage: the
/// round's uplink bytes are processed at that rate, divided by the shard
/// count — shards own disjoint segment slices, so their Eq. 2 work is
/// embarrassingly parallel. 0 leaves aggregation out of the simulated
/// round time (the pre-sharding behavior).
///
/// `shard_mbps` optionally models the coordinator→shard hop of a
/// distributed aggregation tier (`serve --expect-shards`): the round's
/// uplink bytes transit one more link before Eq. 2 runs, fanned out
/// across the shards' parallel links. 0 leaves the hop unmodeled — the
/// right default both for in-process shards (no extra wire) and when
/// the real framed shard-link bytes in the `shard_tx_bytes` CSV column
/// are what you're after.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimProfile {
    /// Base access-link scenario (every non-slow slot).
    pub scenario: Scenario,
    /// Fraction of slots (rounded up) on the slow tail, in [0, 1].
    pub slow_frac: f64,
    /// Bandwidth divisor for slow slots (1.0 = homogeneous fleet).
    pub slow_factor: f64,
    /// Server aggregation processing rate over the round's uplink bytes,
    /// Mbps (0 = aggregation not modeled).
    pub agg_mbps: f64,
    /// Coordinator→shard link rate for the remote aggregation tier, Mbps
    /// (0 = hop not modeled).
    pub shard_mbps: f64,
}

impl SimProfile {
    /// A homogeneous fleet on `scenario` (no slow tail, no modeled
    /// aggregation stage).
    pub fn uniform(scenario: Scenario) -> SimProfile {
        SimProfile { scenario, slow_frac: 0.0, slow_factor: 1.0, agg_mbps: 0.0, shard_mbps: 0.0 }
    }

    /// Per-slot link specs for a round of `n` slots: the FIRST
    /// `ceil(slow_frac · n)` slots get the slowed link (slot order is the
    /// coordinator's deterministic cohort order, so the assignment is
    /// reproducible).
    pub fn slot_links(&self, n: usize) -> Vec<LinkSpec> {
        let base = self.scenario.link();
        let n_slow = ((self.slow_frac.clamp(0.0, 1.0) * n as f64).ceil() as usize).min(n);
        let f = self.slow_factor.max(1.0);
        (0..n)
            .map(|slot| {
                if slot < n_slow {
                    LinkSpec {
                        ul_mbps: base.ul_mbps / f,
                        dl_mbps: base.dl_mbps / f,
                        latency_s: base.latency_s,
                    }
                } else {
                    base
                }
            })
            .collect()
    }
}

/// One observed message crossing the transport.
#[derive(Debug, Clone, Copy)]
pub struct Flow {
    /// Round the envelope belongs to.
    pub round: u64,
    /// Message kind (only task/result flows enter the replay).
    pub kind: MsgKind,
    /// Framed size: header + payload + length prefix.
    pub bytes: usize,
    /// true = coordinator → worker (downlink direction).
    pub to_worker: bool,
    /// Round slot, for task/result messages (peeked from the payload —
    /// `slot` is the first field of both, see `protocol`).
    pub slot: Option<u32>,
}

fn slot_of(env: &Envelope) -> Option<u32> {
    match env.kind {
        MsgKind::TrainTask | MsgKind::TrainResult => env
            .payload
            .get(0..4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap())),
        _ => None,
    }
}

/// Shared traffic journal, filled by the metered halves.
#[derive(Debug, Default)]
pub struct TrafficLog {
    /// Every observed flow, in recording order.
    pub flows: Vec<Flow>,
}

/// Byte meter handed to `wrap_tx`/`wrap_rx`.
#[derive(Clone, Default)]
pub struct Meter {
    log: Arc<Mutex<TrafficLog>>,
}

/// 4-byte length prefix used by the TCP framing (counted uniformly so mem
/// and tcp runs report comparable numbers).
const FRAME_PREFIX: usize = 4;

impl Meter {
    /// Fresh meter with an empty traffic journal.
    pub fn new() -> Meter {
        Meter::default()
    }

    fn record(&self, env: &Envelope, to_worker: bool) {
        lock_unpoisoned(&self.log).flows.push(Flow {
            round: env.round,
            kind: env.kind,
            bytes: env.encoded_len() + FRAME_PREFIX,
            to_worker,
            slot: slot_of(env),
        });
    }

    /// Wrap a send half so every outgoing envelope is journaled.
    pub fn wrap_tx(&self, inner: Box<dyn ConnTx>) -> Box<dyn ConnTx> {
        Box::new(MeteredTx { inner, meter: self.clone() })
    }

    /// Wrap a receive half so every incoming envelope is journaled.
    pub fn wrap_rx(&self, inner: Box<dyn ConnRx>) -> Box<dyn ConnRx> {
        Box::new(MeteredRx { inner, meter: self.clone() })
    }

    /// Total bytes each direction for `round` (task/result messages only).
    pub fn round_bytes(&self, round: u64) -> (usize, usize) {
        let log = lock_unpoisoned(&self.log);
        let mut down = 0;
        let mut up = 0;
        for f in log.flows.iter().filter(|f| f.round == round) {
            match f.kind {
                MsgKind::TrainTask if f.to_worker => down += f.bytes,
                MsgKind::TrainResult if !f.to_worker => up += f.bytes,
                _ => {}
            }
        }
        (down, up)
    }

    /// Replay `round`'s traffic through the discrete-event simulator:
    /// one `RoundPlan` per slot, with the slot's task bytes, result bytes
    /// and compute seconds matched by slot id (recording order carries no
    /// meaning — results arrive in any order). A slot that saw several
    /// flows in one direction — re-dispatch waves on the downlink, racer
    /// results on the uplink — contributes their SUM, since they all
    /// serialized over that slot's access link. `compute_s` is indexed by
    /// slot, as produced by `RoundState::exec_by_slot`. Slots whose result
    /// never crossed the transport during `round` (quorum stragglers) are
    /// excluded from the replay — their bytes surface in the round that
    /// eventually folds them, not here; `quorum` is the number of uploads
    /// that closed the round (pass `compute_s.len()` for synchronous
    /// rounds). When `profile.agg_mbps > 0`, a server aggregation stage
    /// over the replayed uplink bytes is appended to the round time,
    /// divided across `shards` parallel segment shards — pass the
    /// EFFECTIVE width `min(configured shards, n_s)`, since shards that
    /// own no segment contribute no parallelism. When
    /// `profile.shard_mbps > 0`, the coordinator→shard fan-out hop is
    /// modeled the same way — the round's uplink bytes re-transit the
    /// shard links (1/`shards` of the bytes on each, in parallel) before
    /// aggregation — and counted as communication time.
    pub fn round_timing(
        &self,
        round: u64,
        compute_s: &[f64],
        profile: &SimProfile,
        quorum: usize,
        shards: usize,
    ) -> Result<RoundTiming> {
        let n = compute_s.len();
        let mut dl = vec![None; n];
        let mut ul = vec![None; n];
        {
            let log = lock_unpoisoned(&self.log);
            for f in log.flows.iter().filter(|f| f.round == round) {
                let target = match (f.kind, f.to_worker) {
                    (MsgKind::TrainTask, true) => &mut dl,
                    (MsgKind::TrainResult, false) => &mut ul,
                    _ => continue,
                };
                if let Some(slot) = f.slot {
                    if let Some(entry) = target.get_mut(slot as usize) {
                        *entry = Some(entry.unwrap_or(0) + f.bytes);
                    }
                }
            }
        }
        let links = profile.slot_links(n);
        let mut plans: Vec<RoundPlan> = Vec::with_capacity(n);
        let mut specs: Vec<LinkSpec> = Vec::with_capacity(n);
        for slot in 0..n {
            if let (Some(d), Some(u)) = (dl[slot], ul[slot]) {
                plans.push(RoundPlan { dl_bytes: d, compute_s: compute_s[slot], ul_bytes: u });
                specs.push(links[slot]);
            }
        }
        anyhow::ensure!(!plans.is_empty(), "netsim shim: no traffic recorded for round {round}");
        let mut sim = NetSim::heterogeneous(&specs);
        let clients: Vec<usize> = (0..plans.len()).collect();
        let mut timing = sim.run_round_quorum(&clients, &plans, quorum.clamp(1, plans.len()));
        let ul_total: usize = plans.iter().map(|p| p.ul_bytes).sum();
        if profile.shard_mbps > 0.0 {
            let hop_s =
                (ul_total as f64 * 8.0 / 1e6) / profile.shard_mbps / shards.max(1) as f64;
            timing.comm_s += hop_s;
            timing.round_s += hop_s;
        }
        if profile.agg_mbps > 0.0 {
            let agg_s =
                (ul_total as f64 * 8.0 / 1e6) / profile.agg_mbps / shards.max(1) as f64;
            timing.agg_s = agg_s;
            timing.round_s += agg_s;
        }
        Ok(timing)
    }
}

struct MeteredTx {
    inner: Box<dyn ConnTx>,
    meter: Meter,
}

impl ConnTx for MeteredTx {
    fn send(&mut self, env: &Envelope) -> Result<()> {
        self.meter.record(env, true);
        self.inner.send(env)
    }
}

struct MeteredRx {
    inner: Box<dyn ConnRx>,
    meter: Meter,
}

impl ConnRx for MeteredRx {
    fn recv(&mut self) -> Result<Envelope> {
        let env = self.inner.recv()?;
        self.meter.record(&env, false);
        Ok(env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::{establish, ClusterMode};
    use crate::netsim::Scenario;

    /// Task/result payload with the given slot in the leading u32 (the
    /// field `round_timing` peeks) followed by padding to `len` bytes.
    fn slot_payload(slot: u32, len: usize) -> Vec<u8> {
        let mut p = slot.to_le_bytes().to_vec();
        p.resize(len, 0xEE);
        p
    }

    #[test]
    fn meter_records_and_replays_round_traffic() {
        let (coord, work) = establish(ClusterMode::Mem, 1).unwrap();
        let mut worker = work.into_iter().next().unwrap();
        let peer = std::thread::spawn(move || {
            // echo tasks back as results in REVERSE slot order: slot
            // matching must not depend on arrival order
            let mut seen = Vec::new();
            for _ in 0..3 {
                seen.push(worker.recv().unwrap());
            }
            for env in seen.into_iter().rev() {
                let reply = Envelope::new(
                    MsgKind::TrainResult,
                    env.round,
                    env.segment,
                    1,
                    env.payload[0..4].iter().copied().chain([0xAB; 36]).collect(),
                );
                worker.send(&reply).unwrap();
            }
        });
        let meter = Meter::new();
        let (tx, rx) = coord.into_iter().next().unwrap().split().unwrap();
        let mut tx = meter.wrap_tx(tx);
        let mut rx = meter.wrap_rx(rx);
        for slot in 0..3u32 {
            tx.send(&Envelope::new(MsgKind::TrainTask, 7, 0, 0, slot_payload(slot, 100))).unwrap();
        }
        for _ in 0..3 {
            rx.recv().unwrap();
        }
        peer.join().unwrap();

        let (down, up) = meter.round_bytes(7);
        assert_eq!(down, 3 * (crate::cluster::protocol::HEADER_LEN + 100 + 4));
        assert_eq!(up, 3 * (crate::cluster::protocol::HEADER_LEN + 40 + 4));
        assert_eq!(meter.round_bytes(8), (0, 0));

        let scenario = Scenario { name: "test", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
        let profile = SimProfile::uniform(scenario);
        let timing = meter.round_timing(7, &[0.5, 0.5, 0.5], &profile, 3, 1).unwrap();
        assert!(timing.round_s > 0.5, "{timing:?}");
        assert!((timing.compute_s - 0.5).abs() < 1e-12);
        assert!(timing.comm_s > 0.0);
        assert_eq!(timing.agg_s, 0.0, "aggregation not modeled by default");
        // a round with no recorded traffic is an error, not a zero timing
        assert!(meter.round_timing(9, &[0.5], &profile, 1, 1).is_err());

        // heterogeneous links: a 2-of-3 quorum closes on the fast slots
        // and must beat the synchronous round that waits for the slow one
        // ceil(0.9) = 1 slow slot
        let hetero =
            SimProfile { slow_frac: 0.3, slow_factor: 10.0, ..SimProfile::uniform(scenario) };
        let t_sync = meter.round_timing(7, &[0.5, 0.5, 0.5], &hetero, 3, 1).unwrap();
        let t_quorum = meter.round_timing(7, &[0.5, 0.5, 0.5], &hetero, 2, 1).unwrap();
        assert!(
            t_quorum.round_s < t_sync.round_s,
            "quorum {} !< sync {}",
            t_quorum.round_s,
            t_sync.round_s
        );
    }

    #[test]
    fn modeled_aggregation_shrinks_with_shard_count() {
        // replay the same round with a modeled aggregation stage: N
        // shards divide the server-side share by N, deterministically
        let (coord, work) = establish(ClusterMode::Mem, 1).unwrap();
        let mut worker = work.into_iter().next().unwrap();
        let peer = std::thread::spawn(move || {
            for _ in 0..2 {
                let env = worker.recv().unwrap();
                let reply = Envelope::new(
                    MsgKind::TrainResult,
                    env.round,
                    env.segment,
                    1,
                    env.payload[0..4].iter().copied().chain([0xCD; 96]).collect(),
                );
                worker.send(&reply).unwrap();
            }
        });
        let meter = Meter::new();
        let (tx, rx) = coord.into_iter().next().unwrap().split().unwrap();
        let mut tx = meter.wrap_tx(tx);
        let mut rx = meter.wrap_rx(rx);
        for slot in 0..2u32 {
            tx.send(&Envelope::new(MsgKind::TrainTask, 3, 0, 0, slot_payload(slot, 50))).unwrap();
        }
        for _ in 0..2 {
            rx.recv().unwrap();
        }
        peer.join().unwrap();

        let scenario = Scenario { name: "test", ul_mbps: 1.0, dl_mbps: 5.0, latency_s: 0.05 };
        let profile = SimProfile {
            scenario,
            slow_frac: 0.0,
            slow_factor: 1.0,
            agg_mbps: 0.001,
            shard_mbps: 0.0,
        };
        let one = meter.round_timing(3, &[0.1, 0.1], &profile, 2, 1).unwrap();
        let four = meter.round_timing(3, &[0.1, 0.1], &profile, 2, 4).unwrap();
        assert!(one.agg_s > 0.0, "{one:?}");
        assert!((four.agg_s - one.agg_s / 4.0).abs() < 1e-12, "4 shards quarter the agg share");
        assert!(four.round_s < one.round_s, "shard-parallel agg shortens the simulated round");
        assert_eq!(one.comm_s, four.comm_s, "link time is unaffected by server sharding");

        // the coordinator→shard hop rides the same replayed uplink
        // bytes: comm time grows by exactly the hop share, agg is
        // untouched, and more shards split the hop in parallel
        let hop = SimProfile { shard_mbps: 0.002, ..profile };
        let base = meter.round_timing(3, &[0.1, 0.1], &profile, 2, 2).unwrap();
        let hop2 = meter.round_timing(3, &[0.1, 0.1], &hop, 2, 2).unwrap();
        let hop4 = meter.round_timing(3, &[0.1, 0.1], &hop, 2, 4).unwrap();
        assert!(hop2.comm_s > base.comm_s, "hop adds communication time");
        assert_eq!(hop2.agg_s, base.agg_s, "hop leaves the agg stage alone");
        let share2 = hop2.comm_s - base.comm_s;
        let share4 = hop4.comm_s - meter.round_timing(3, &[0.1, 0.1], &profile, 2, 4).unwrap().comm_s;
        assert!((share4 - share2 / 2.0).abs() < 1e-12, "4 shard links halve the 2-link hop");
        assert!((hop2.round_s - (base.round_s + share2)).abs() < 1e-12);
    }

    #[test]
    fn slot_links_put_the_slow_tail_first() {
        let scenario = Scenario { name: "test", ul_mbps: 2.0, dl_mbps: 10.0, latency_s: 0.05 };
        let p = SimProfile { slow_frac: 0.25, slow_factor: 4.0, ..SimProfile::uniform(scenario) };
        let links = p.slot_links(4);
        assert_eq!(links.len(), 4);
        assert!((links[0].ul_mbps - 0.5).abs() < 1e-12);
        for l in &links[1..] {
            assert!((l.ul_mbps - 2.0).abs() < 1e-12);
        }
        // uniform profile: no slow slots at all
        let uni = SimProfile::uniform(scenario).slot_links(4);
        assert!(uni.iter().all(|l| (l.ul_mbps - 2.0).abs() < 1e-12));
    }
}
