//! Typed coordinator↔participant protocol and its byte-exact wire format.
//!
//! Every message travels as an [`Envelope`]: a fixed 44-byte header —
//! magic, protocol version, message kind, FNV-1a checksum, round id,
//! segment id, sample count, round deadline, stale-from round, payload
//! length — followed by a kind-specific payload. The checksum covers the
//! whole envelope except itself, so any single corrupted byte (header
//! field or payload) is rejected rather than misinterpreted; truncation
//! and version skew get dedicated errors.
//!
//! Version 2 added the two round-policy header fields
//! (`round_deadline`, `stale_from_round`) that drive K-of-N quorum
//! aggregation. Version 3 added the deployment handshake kinds —
//! [`Join`](Message::Join) / [`Welcome`](Message::Welcome) /
//! [`Reject`](Message::Reject) — that let an externally-spawned
//! `ecolora worker` process authenticate (shared token) and negotiate
//! (config digest) with an `ecolora serve` coordinator before entering
//! the task loop. Version 4 (this revision) lifts the aggregation plane
//! onto the wire: the router↔shard `ShardMsg` contract gains envelope
//! kinds ([`ShardJoin`](Message::ShardJoin) /
//! [`ShardBegin`](Message::ShardBegin) / [`ShardAdd`](Message::ShardAdd)
//! / [`ShardClose`](Message::ShardClose) /
//! [`ShardReport`](Message::ShardReport)) so `ecolora shard` processes
//! can own segment slices remotely. The header layout is unchanged from
//! v2. Peers speaking different versions reject each other's envelopes
//! outright — see docs/PROTOCOL.md for the normative layout and the
//! compatibility table.
//!
//! Payload contents reuse the existing `compress::wire` messages wherever
//! compression is on; dense fallbacks ship raw little-endian f32/f16.

use anyhow::{anyhow, bail, ensure, Result};

use crate::metrics::CommTotals;

use crate::fed::robust::RobustStats;

use super::shard::{AggStats, Payload, ShardReport};

/// Protocol magic ("EcoLoRA cluster").
pub const MAGIC: [u8; 2] = [0xEC, 0x57];
/// Protocol version carried in every envelope header. Bumped to 2 when
/// the `round_deadline`/`stale_from_round` header fields were added for
/// quorum rounds, to 3 when the `Join`/`Welcome`/`Reject` handshake
/// kinds were added for authenticated multi-process deployment, and to
/// 4 when the aggregation plane's `ShardJoin`/`ShardBegin`/`ShardAdd`/
/// `ShardClose`/`ShardReport` kinds were added for remote `ecolora
/// shard` processes, and to 5 when `ShardReport` grew the robust-
/// aggregation counters (`clients_trimmed`/`clip_applied`). Peers
/// speaking different versions reject each other's envelopes.
pub const PROTO_VERSION: u8 = 5;
/// `Join::requested_worker` wildcard: "assign me any free worker id".
pub const ANY_WORKER: u32 = u32::MAX;
/// `ShardJoin::requested_shard` wildcard: "assign me any free shard id".
pub const ANY_SHARD: u32 = u32::MAX;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 44;
/// Hard cap on one payload (base-model sync dominates; 1 GiB is generous).
pub const MAX_PAYLOAD: usize = 1 << 30;

/// Message discriminant (header byte 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// Worker → coordinator: identify this connection.
    Hello = 1,
    /// Coordinator → worker: train one sampled client this round.
    TrainTask = 2,
    /// Worker → coordinator: the client's uplink contribution.
    TrainResult = 3,
    /// Coordinator → workers: replace the frozen base (FLoRA merge).
    BaseSync = 4,
    /// Coordinator → workers: end of run.
    Shutdown = 5,
    /// Either direction: fatal peer failure, human-readable.
    Error = 6,
    /// Worker → coordinator: authenticated join request (v3 handshake).
    Join = 7,
    /// Coordinator → worker: join accepted, worker id assigned.
    Welcome = 8,
    /// Coordinator → worker: join refused; connection closes after this.
    Reject = 9,
    /// Shard process → coordinator: authenticated join request (v4).
    ShardJoin = 10,
    /// Coordinator → shard: open a round over a segment slice (v4).
    ShardBegin = 11,
    /// Coordinator → shard: one on-time uplink contribution (v4).
    ShardAdd = 12,
    /// Coordinator → shard: close the open round and report (v4).
    ShardClose = 13,
    /// Shard → coordinator: the round-close delta slice + tallies (v4).
    ShardReport = 14,
}

impl MsgKind {
    fn from_u8(x: u8) -> Result<MsgKind> {
        Ok(match x {
            1 => MsgKind::Hello,
            2 => MsgKind::TrainTask,
            3 => MsgKind::TrainResult,
            4 => MsgKind::BaseSync,
            5 => MsgKind::Shutdown,
            6 => MsgKind::Error,
            7 => MsgKind::Join,
            8 => MsgKind::Welcome,
            9 => MsgKind::Reject,
            10 => MsgKind::ShardJoin,
            11 => MsgKind::ShardBegin,
            12 => MsgKind::ShardAdd,
            13 => MsgKind::ShardClose,
            14 => MsgKind::ShardReport,
            other => bail!("envelope: unknown message kind {other}"),
        })
    }
}

/// Why a coordinator refused a `Join` (the `Reject` payload code).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum RejectCode {
    /// Presented auth token does not match the coordinator's secret.
    BadToken = 1,
    /// Config digests disagree: the two processes were launched with
    /// different run configurations and could not produce a well-defined
    /// federated run together.
    ConfigMismatch = 2,
    /// The requested worker (or shard) id is already connected.
    DuplicateWorker = 3,
    /// No free worker (or shard) slot: requested id out of range, every
    /// slot taken, or a shard join against a coordinator running its
    /// aggregation plane in-process.
    ClusterFull = 4,
    /// The peer's first message was not a well-formed `Join`/`ShardJoin`.
    Malformed = 5,
}

impl RejectCode {
    fn from_u8(x: u8) -> Result<RejectCode> {
        Ok(match x {
            1 => RejectCode::BadToken,
            2 => RejectCode::ConfigMismatch,
            3 => RejectCode::DuplicateWorker,
            4 => RejectCode::ClusterFull,
            5 => RejectCode::Malformed,
            other => bail!("payload: unknown reject code {other}"),
        })
    }

    /// Stable lower-snake name (log lines, operator diagnostics).
    pub fn name(self) -> &'static str {
        match self {
            RejectCode::BadToken => "bad_token",
            RejectCode::ConfigMismatch => "config_mismatch",
            RejectCode::DuplicateWorker => "duplicate_worker",
            RejectCode::ClusterFull => "cluster_full",
            RejectCode::Malformed => "malformed",
        }
    }
}

/// One framed protocol message.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Message discriminant (selects the payload codec).
    pub kind: MsgKind,
    /// Federated round this message belongs to (0 for control messages).
    pub round: u64,
    /// Round-robin segment id (task/result messages; 0 otherwise). Living
    /// in the fixed header — not the payload — is what lets the server's
    /// router pick a result's aggregation shard without decoding the
    /// payload body.
    pub segment: u32,
    /// FedAvg weight n_i (results; 0 otherwise).
    pub sample_count: u32,
    /// Milliseconds the coordinator allots the task before the slot may be
    /// resampled; 0 = no deadline (`RoundPolicy::Sync`). Set on
    /// `TrainTask`, 0 elsewhere. Added in protocol v2.
    pub round_deadline: u64,
    /// The round the carried update was computed against. For on-time
    /// results this equals `round`; the coordinator computes the staleness
    /// discount of a late uplink from this field rather than from `round`
    /// so a future transport-level retry can preserve the origin round.
    /// Added in protocol v2.
    pub stale_from_round: u64,
    /// Kind-specific payload bytes.
    pub payload: Vec<u8>,
}

/// FNV-1a over two byte ranges (header-before-checksum ++ header-after ++
/// payload); cheap, order-sensitive, catches any single-byte corruption.
fn fnv1a_parts(a: &[u8], b: &[u8]) -> u32 {
    let mut h = 0x811C_9DC5u32;
    for &x in a.iter().chain(b) {
        h ^= x as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

impl Envelope {
    /// Build an envelope with no deadline and `stale_from_round == round`
    /// (the common case for control and on-time messages).
    pub fn new(
        kind: MsgKind,
        round: u64,
        segment: u32,
        sample_count: u32,
        payload: Vec<u8>,
    ) -> Envelope {
        Envelope {
            kind,
            round,
            segment,
            sample_count,
            round_deadline: 0,
            stale_from_round: round,
            payload,
        }
    }

    /// Total encoded size (framing accounting for the netsim shim).
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialize to the byte-exact wire form (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Append the byte-exact wire form to `out` (same bytes as
    /// [`Envelope::encode`], no intermediate allocation — the coordinator
    /// journal frames received envelopes through a reusable scratch
    /// buffer on its hot path).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&MAGIC);
        out.push(PROTO_VERSION);
        out.push(self.kind as u8);
        out.extend_from_slice(&[0u8; 4]); // checksum backfilled below
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.segment.to_le_bytes());
        out.extend_from_slice(&self.sample_count.to_le_bytes());
        out.extend_from_slice(&self.round_deadline.to_le_bytes());
        out.extend_from_slice(&self.stale_from_round.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let c = fnv1a_parts(&out[start..start + 4], &out[start + 8..]);
        out[start + 4..start + 8].copy_from_slice(&c.to_le_bytes());
    }

    /// Parse and validate one encoded envelope (exact-length input).
    pub fn decode(bytes: &[u8]) -> Result<Envelope> {
        ensure!(
            bytes.len() >= HEADER_LEN,
            "envelope: truncated header ({} of {HEADER_LEN} bytes)",
            bytes.len()
        );
        ensure!(
            bytes[0..2] == MAGIC,
            "envelope: bad magic {:02x}{:02x}",
            bytes[0],
            bytes[1]
        );
        ensure!(
            bytes[2] == PROTO_VERSION,
            "envelope: protocol version mismatch (got {}, want {PROTO_VERSION})",
            bytes[2]
        );
        let kind = MsgKind::from_u8(bytes[3])?;
        let checksum = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        ensure!(
            fnv1a_parts(&bytes[0..4], &bytes[8..]) == checksum,
            "envelope: checksum mismatch (corrupt message)"
        );
        let round = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let segment = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let sample_count = u32::from_le_bytes(bytes[20..24].try_into().unwrap());
        let round_deadline = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
        let stale_from_round = u64::from_le_bytes(bytes[32..40].try_into().unwrap());
        let payload_len = u32::from_le_bytes(bytes[40..44].try_into().unwrap()) as usize;
        ensure!(payload_len <= MAX_PAYLOAD, "envelope: payload length {payload_len} over cap");
        ensure!(
            bytes.len() == HEADER_LEN + payload_len,
            "envelope: length mismatch ({} bytes, header says {})",
            bytes.len(),
            HEADER_LEN + payload_len
        );
        Ok(Envelope {
            kind,
            round,
            segment,
            sample_count,
            round_deadline,
            stale_from_round,
            payload: bytes[HEADER_LEN..].to_vec(),
        })
    }
}

// ---- payload codec helpers (little-endian throughout) ----------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    /// Build into a recycled buffer (cleared, capacity kept) — the
    /// router's remote fan-out reuses arena payload buffers this way.
    fn with(mut buf: Vec<u8>) -> Writer {
        buf.clear();
        Writer { buf }
    }

    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn finish(self) -> Vec<u8> {
        self.buf
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let b = self
            .buf
            .get(self.pos..self.pos + n)
            .ok_or_else(|| anyhow!("payload: truncated at byte {}", self.pos))?;
        self.pos += n;
        Ok(b)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        ensure!(n <= MAX_PAYLOAD, "payload: byte block of {n} over cap");
        Ok(self.take(n)?.to_vec())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        ensure!(n <= MAX_PAYLOAD / 4, "payload: f32 block of {n} over cap");
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        ensure!(self.pos == self.buf.len(), "payload: {} trailing bytes", self.buf.len() - self.pos);
        Ok(())
    }
}

// ---- typed messages --------------------------------------------------------

/// Coordinator → participant downlink content for one task.
#[derive(Debug, Clone, PartialEq)]
pub enum DownPayload {
    /// Exact global LoRA vector, f32 — dense baseline downlink.
    DenseF32(Vec<f32>),
    /// Sparse compressed delta against the client's reference.
    SparseWire(Vec<u8>),
    /// Dense f16 delta against the client's reference (`SparsMode::Off`).
    DenseF16(Vec<u8>),
    /// Fresh FLoRA restart module (train from this; no mixing).
    FloraInit(Vec<f32>),
}

/// Participant → coordinator uplink content.
#[derive(Debug, Clone, PartialEq)]
pub enum UpPayload {
    /// Compressed round-robin segment update (`compress::wire` bytes).
    SparseWire(Vec<u8>),
    /// Dense f32 update (local − base_point) over the whole vector.
    DenseUpdate(Vec<f32>),
    /// Dense f32 local module (FLoRA stacking upload).
    DenseModule(Vec<f32>),
}

/// One unit of work: "train client `client` on segment `segment`".
///
/// Wire note: `slot` is serialized as the FIRST payload field of both
/// `TrainTask` and `TrainResult` — the netsim shim peeks it without a
/// full decode. Keep it first.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainTask {
    /// Round this task belongs to.
    pub round: u64,
    /// Cohort slot (position in the round's sampled client list).
    pub slot: u32,
    /// Logical client to train.
    pub client: u32,
    /// Round-robin segment this client uploads.
    pub segment: u32,
    /// Round-robin segment count this round (min(N_s, N_t)).
    pub n_s: u32,
    /// Loss signal (L₀, L_{t−1}) driving Eq. 4.
    pub l0: f64,
    /// Previous-round mean loss (second half of the Eq. 4 signal).
    pub l_prev: f64,
    /// Per-task batch-RNG stream, forked by the coordinator so results
    /// are independent of worker scheduling order.
    pub rng_state: [u64; 4],
    /// Milliseconds the coordinator allots before the slot may be
    /// resampled to a replacement client (0 = no deadline, sync rounds).
    pub deadline_ms: u64,
    /// Sequence number of this downlink within the client's STATEFUL
    /// downlink channel: the n-th sparse/f16 delta the coordinator has
    /// ever built for this client (1-based); 0 for stateless payloads
    /// (exact dense vector, FLoRA restart init). The participant checks
    /// it against its own applied count so a stateful downlink lost in
    /// transit — which would silently desynchronize the client's
    /// reference reconstruction — fails loudly instead. New in v3.
    pub down_seq: u64,
    /// Downlink content (see [`DownPayload`]).
    pub down: DownPayload,
}

/// One finished unit of work.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainResult {
    /// Round the executed task belonged to.
    pub round: u64,
    /// Cohort slot the task occupied.
    pub slot: u32,
    /// Logical client that trained.
    pub client: u32,
    /// Round-robin segment the uplink covers.
    pub segment: u32,
    /// FedAvg weight n_i.
    pub n_samples: u32,
    /// Sample-weighted mean local loss over the local steps.
    pub mean_loss: f64,
    /// Density used for A matrices (0 when not compressing).
    pub k_a: f64,
    /// Density used for B matrices (0 when not compressing).
    pub k_b: f64,
    /// Seconds spent in compiled execution (perf accounting).
    pub exec_s: f64,
    /// Round the carried update was computed against (equals `round` for
    /// results produced by this revision; the coordinator derives the
    /// staleness discount of a late uplink from this field).
    pub stale_from_round: u64,
    /// Uplink content (see [`UpPayload`]).
    pub up: UpPayload,
}

/// The protocol, typed.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → coordinator: identify this connection.
    Hello {
        /// Worker index (0..n_workers).
        worker: u32,
    },
    /// Coordinator → worker: train one sampled client this round.
    TrainTask(TrainTask),
    /// Worker → coordinator: the client's uplink contribution.
    TrainResult(TrainResult),
    /// Coordinator → workers: replace the frozen base (FLoRA merge).
    BaseSync {
        /// The merged base every participant must sync to.
        base: Vec<f32>,
    },
    /// Coordinator → workers: end of run.
    Shutdown,
    /// Either direction: fatal peer failure, human-readable.
    Error {
        /// Human-readable failure description.
        text: String,
    },
    /// Worker → coordinator: authenticated join request, first message on
    /// an externally-dialed connection (v3 deployment handshake).
    Join {
        /// Shared-secret bearer token bytes (compared constant-time by
        /// the coordinator; see `cluster::handshake`).
        token: Vec<u8>,
        /// `FedConfig::digest()` of the worker's run configuration; the
        /// coordinator hard-rejects on mismatch.
        config_digest: u64,
        /// Worker id the process wants ([`ANY_WORKER`] = assign one).
        requested_worker: u32,
        /// Peer build version string (diagnostics only — the envelope
        /// version byte, not this field, gates compatibility).
        build: String,
    },
    /// Coordinator → worker: join accepted.
    Welcome {
        /// Assigned worker id (0..n_workers).
        worker: u32,
        /// Total worker slots in this deployment.
        n_workers: u32,
        /// Round the coordinator will dispatch next (0 on a fresh run;
        /// tells a rejoining worker where the run currently stands).
        resume_round: u64,
    },
    /// Coordinator → worker: join refused; the coordinator closes the
    /// connection after sending this.
    Reject {
        /// Machine-readable refusal category.
        code: RejectCode,
        /// Human-readable refusal detail.
        reason: String,
    },
    /// Shard process → coordinator: authenticated join request, first
    /// message on an externally-dialed aggregation connection (v4). The
    /// coordinator answers with the same [`Welcome`](Message::Welcome) /
    /// [`Reject`](Message::Reject) pair workers get — a shard's
    /// `Welcome.n_workers` field carries the SHARD count.
    ShardJoin {
        /// Shared-secret bearer token bytes (compared constant-time).
        token: Vec<u8>,
        /// `FedConfig::digest()` of the shard's run configuration.
        config_digest: u64,
        /// Shard id the process wants ([`ANY_SHARD`] = assign one).
        requested_shard: u32,
        /// Peer build version string (diagnostics only).
        build: String,
    },
    /// Coordinator → shard: open round `round` (header field) owning
    /// global segments `[seg_lo, seg_hi)` of an `n_s`-segment space —
    /// the wire form of `ShardMsg::Begin`.
    ShardBegin {
        /// Round index (rides the envelope header).
        round: u64,
        /// Round-robin segment count this round.
        n_s: u32,
        /// First owned global segment.
        seg_lo: u32,
        /// One past the last owned global segment.
        seg_hi: u32,
    },
    /// Coordinator → shard: one on-time contribution for the open round
    /// — the wire form of `ShardMsg::Add`. The segment id rides the
    /// envelope header (same field task/result messages use).
    ShardAdd {
        /// Cohort slot (accumulation order key; first payload field).
        slot: u32,
        /// Global segment id (rides the envelope header).
        seg: u32,
        /// FedAvg weight n_i.
        w: f64,
        /// The uplink payload body.
        payload: Payload,
    },
    /// Coordinator → shard: close the open round and reply with a
    /// [`ShardReport`](Message::ShardReport) — the wire form of
    /// `ShardMsg::Close`. Stragglers for a later fold travel as plain
    /// [`TrainResult`](Message::TrainResult) messages on the shard link.
    ShardClose {
        /// The folding round (rides the envelope header).
        now_round: u64,
        /// Staleness decay β (Eq. 3) for the fold.
        beta: f64,
        /// Dense-uplink parameter charge (`Method::dense_upload_params`).
        dense_params: u64,
    },
    /// Shard → coordinator: the round-close report (delta slice, comm
    /// tallies, late-fold identities, coverage, digest, error).
    ShardReport(Box<ShardReport>),
}

fn down_encode(w: &mut Writer, d: &DownPayload) {
    match d {
        DownPayload::DenseF32(v) => {
            w.u8(0);
            w.f32s(v);
        }
        DownPayload::SparseWire(b) => {
            w.u8(1);
            w.bytes(b);
        }
        DownPayload::DenseF16(b) => {
            w.u8(2);
            w.bytes(b);
        }
        DownPayload::FloraInit(v) => {
            w.u8(3);
            w.f32s(v);
        }
    }
}

fn down_decode(r: &mut Reader) -> Result<DownPayload> {
    Ok(match r.u8()? {
        0 => DownPayload::DenseF32(r.f32s()?),
        1 => DownPayload::SparseWire(r.bytes()?),
        2 => DownPayload::DenseF16(r.bytes()?),
        3 => DownPayload::FloraInit(r.f32s()?),
        other => bail!("payload: unknown downlink tag {other}"),
    })
}

fn up_encode(w: &mut Writer, u: &UpPayload) {
    match u {
        UpPayload::SparseWire(b) => {
            w.u8(0);
            w.bytes(b);
        }
        UpPayload::DenseUpdate(v) => {
            w.u8(1);
            w.f32s(v);
        }
        UpPayload::DenseModule(v) => {
            w.u8(2);
            w.f32s(v);
        }
    }
}

fn up_decode(r: &mut Reader) -> Result<UpPayload> {
    Ok(match r.u8()? {
        0 => UpPayload::SparseWire(r.bytes()?),
        1 => UpPayload::DenseUpdate(r.f32s()?),
        2 => UpPayload::DenseModule(r.f32s()?),
        other => bail!("payload: unknown uplink tag {other}"),
    })
}

fn shard_payload_encode(w: &mut Writer, p: &Payload) {
    match p {
        Payload::Wire(b) => {
            w.u8(0);
            w.bytes(b);
        }
        Payload::Dense(v) => {
            w.u8(1);
            w.f32s(v);
        }
    }
}

fn shard_payload_decode(r: &mut Reader) -> Result<Payload> {
    Ok(match r.u8()? {
        0 => Payload::Wire(r.bytes()?),
        1 => Payload::Dense(r.f32s()?),
        other => bail!("payload: unknown shard payload tag {other}"),
    })
}

fn shard_report_encode(w: &mut Writer, rep: &ShardReport) {
    w.u32(rep.shard as u32);
    w.u64(rep.base as u64);
    w.f32s(&rep.delta);
    w.u64(rep.stats.up.params);
    w.u64(rep.stats.up.bytes);
    w.u32(rep.stats.late_folds as u32);
    w.u32(rep.stats.orphaned as u32);
    w.u64(rep.stats.robust.trimmed);
    w.u64(rep.stats.robust.clipped);
    w.u32(rep.folded.len() as u32);
    for &(round, slot) in &rep.folded {
        w.u64(round);
        w.u32(slot);
    }
    w.u32(rep.covered.len() as u32);
    for &c in &rep.covered {
        w.u8(u8::from(c));
    }
    w.f64(rep.agg_s);
    w.u64(rep.late_evicted as u64);
    w.u64(rep.digest);
    match &rep.error {
        None => w.u8(0),
        Some(e) => {
            w.u8(1);
            w.bytes(e.as_bytes());
        }
    }
}

fn shard_report_decode(r: &mut Reader) -> Result<ShardReport> {
    let shard = r.u32()? as usize;
    let base = r.u64()? as usize;
    let delta = r.f32s()?;
    let stats = AggStats {
        up: CommTotals { params: r.u64()?, bytes: r.u64()? },
        late_folds: r.u32()? as usize,
        orphaned: r.u32()? as usize,
        robust: RobustStats { trimmed: r.u64()?, clipped: r.u64()? },
    };
    let n_folded = r.u32()? as usize;
    ensure!(n_folded <= MAX_PAYLOAD / 12, "payload: folded list of {n_folded} over cap");
    let mut folded = Vec::with_capacity(n_folded);
    for _ in 0..n_folded {
        let round = r.u64()?;
        let slot = r.u32()?;
        folded.push((round, slot));
    }
    let n_covered = r.u32()? as usize;
    ensure!(n_covered <= MAX_PAYLOAD, "payload: covered list of {n_covered} over cap");
    let mut covered = Vec::with_capacity(n_covered);
    for _ in 0..n_covered {
        covered.push(match r.u8()? {
            0 => false,
            1 => true,
            other => bail!("payload: bad covered flag {other}"),
        });
    }
    let agg_s = r.f64()?;
    let late_evicted = r.u64()? as usize;
    let digest = r.u64()?;
    let error = match r.u8()? {
        0 => None,
        1 => Some(String::from_utf8_lossy(&r.bytes()?).into_owned()),
        other => bail!("payload: bad error flag {other}"),
    };
    Ok(ShardReport {
        shard,
        base,
        delta,
        stats,
        folded,
        covered,
        agg_s,
        late_evicted,
        digest,
        error,
    })
}

impl Message {
    /// The envelope discriminant this message serializes under.
    pub fn kind(&self) -> MsgKind {
        match self {
            Message::Hello { .. } => MsgKind::Hello,
            Message::TrainTask(_) => MsgKind::TrainTask,
            Message::TrainResult(_) => MsgKind::TrainResult,
            Message::BaseSync { .. } => MsgKind::BaseSync,
            Message::Shutdown => MsgKind::Shutdown,
            Message::Error { .. } => MsgKind::Error,
            Message::Join { .. } => MsgKind::Join,
            Message::Welcome { .. } => MsgKind::Welcome,
            Message::Reject { .. } => MsgKind::Reject,
            Message::ShardJoin { .. } => MsgKind::ShardJoin,
            Message::ShardBegin { .. } => MsgKind::ShardBegin,
            Message::ShardAdd { .. } => MsgKind::ShardAdd,
            Message::ShardClose { .. } => MsgKind::ShardClose,
            Message::ShardReport(_) => MsgKind::ShardReport,
        }
    }

    /// Serialize into an [`Envelope`] (header fields + payload codec).
    pub fn to_envelope(&self) -> Envelope {
        self.to_envelope_in(Vec::new())
    }

    /// Like [`Message::to_envelope`], but builds the payload into `buf`
    /// (cleared first, capacity kept) — the router's remote shard fan-out
    /// recycles arena buffers through here so the steady-state encode
    /// path never allocates.
    pub fn to_envelope_in(&self, buf: Vec<u8>) -> Envelope {
        let mut w = Writer::with(buf);
        let (round, segment, sample_count, round_deadline, stale_from_round) = match self {
            Message::Hello { worker } => {
                w.u32(*worker);
                (0, 0, 0, 0, 0)
            }
            Message::TrainTask(t) => {
                w.u32(t.slot);
                w.u32(t.client);
                w.u32(t.n_s);
                w.f64(t.l0);
                w.f64(t.l_prev);
                for s in t.rng_state {
                    w.u64(s);
                }
                w.u64(t.down_seq);
                down_encode(&mut w, &t.down);
                (t.round, t.segment, 0, t.deadline_ms, t.round)
            }
            Message::TrainResult(r) => {
                w.u32(r.slot);
                w.u32(r.client);
                w.f64(r.mean_loss);
                w.f64(r.k_a);
                w.f64(r.k_b);
                w.f64(r.exec_s);
                up_encode(&mut w, &r.up);
                (r.round, r.segment, r.n_samples, 0, r.stale_from_round)
            }
            Message::BaseSync { base } => {
                w.f32s(base);
                (0, 0, 0, 0, 0)
            }
            Message::Shutdown => (0, 0, 0, 0, 0),
            Message::Error { text } => {
                w.bytes(text.as_bytes());
                (0, 0, 0, 0, 0)
            }
            Message::Join { token, config_digest, requested_worker, build } => {
                w.bytes(token);
                w.u64(*config_digest);
                w.u32(*requested_worker);
                w.bytes(build.as_bytes());
                (0, 0, 0, 0, 0)
            }
            Message::Welcome { worker, n_workers, resume_round } => {
                w.u32(*worker);
                w.u32(*n_workers);
                w.u64(*resume_round);
                (0, 0, 0, 0, 0)
            }
            Message::Reject { code, reason } => {
                w.u8(*code as u8);
                w.bytes(reason.as_bytes());
                (0, 0, 0, 0, 0)
            }
            Message::ShardJoin { token, config_digest, requested_shard, build } => {
                w.bytes(token);
                w.u64(*config_digest);
                w.u32(*requested_shard);
                w.bytes(build.as_bytes());
                (0, 0, 0, 0, 0)
            }
            Message::ShardBegin { round, n_s, seg_lo, seg_hi } => {
                w.u32(*n_s);
                w.u32(*seg_lo);
                w.u32(*seg_hi);
                (*round, 0, 0, 0, *round)
            }
            Message::ShardAdd { slot, seg, w: weight, payload } => {
                w.u32(*slot);
                w.f64(*weight);
                shard_payload_encode(&mut w, payload);
                (0, *seg, 0, 0, 0)
            }
            Message::ShardClose { now_round, beta, dense_params } => {
                w.f64(*beta);
                w.u64(*dense_params);
                (*now_round, 0, 0, 0, *now_round)
            }
            Message::ShardReport(rep) => {
                shard_report_encode(&mut w, rep);
                (0, 0, 0, 0, 0)
            }
        };
        Envelope {
            kind: self.kind(),
            round,
            segment,
            sample_count,
            round_deadline,
            stale_from_round,
            payload: w.finish(),
        }
    }

    /// Deserialize a decoded [`Envelope`] back into a typed message.
    pub fn from_envelope(env: &Envelope) -> Result<Message> {
        let mut r = Reader::new(&env.payload);
        let msg = match env.kind {
            MsgKind::Hello => Message::Hello { worker: r.u32()? },
            MsgKind::TrainTask => {
                let slot = r.u32()?;
                let client = r.u32()?;
                let n_s = r.u32()?;
                let l0 = r.f64()?;
                let l_prev = r.f64()?;
                let mut rng_state = [0u64; 4];
                for s in &mut rng_state {
                    *s = r.u64()?;
                }
                let down_seq = r.u64()?;
                let down = down_decode(&mut r)?;
                Message::TrainTask(TrainTask {
                    round: env.round,
                    slot,
                    client,
                    segment: env.segment,
                    n_s,
                    l0,
                    l_prev,
                    rng_state,
                    deadline_ms: env.round_deadline,
                    down_seq,
                    down,
                })
            }
            MsgKind::TrainResult => {
                let slot = r.u32()?;
                let client = r.u32()?;
                let mean_loss = r.f64()?;
                let k_a = r.f64()?;
                let k_b = r.f64()?;
                let exec_s = r.f64()?;
                let up = up_decode(&mut r)?;
                Message::TrainResult(TrainResult {
                    round: env.round,
                    slot,
                    client,
                    segment: env.segment,
                    n_samples: env.sample_count,
                    mean_loss,
                    k_a,
                    k_b,
                    exec_s,
                    stale_from_round: env.stale_from_round,
                    up,
                })
            }
            MsgKind::BaseSync => Message::BaseSync { base: r.f32s()? },
            MsgKind::Shutdown => Message::Shutdown,
            MsgKind::Error => {
                let raw = r.bytes()?;
                Message::Error { text: String::from_utf8_lossy(&raw).into_owned() }
            }
            MsgKind::Join => {
                let token = r.bytes()?;
                let config_digest = r.u64()?;
                let requested_worker = r.u32()?;
                let build = String::from_utf8_lossy(&r.bytes()?).into_owned();
                Message::Join { token, config_digest, requested_worker, build }
            }
            MsgKind::Welcome => Message::Welcome {
                worker: r.u32()?,
                n_workers: r.u32()?,
                resume_round: r.u64()?,
            },
            MsgKind::Reject => {
                let code = RejectCode::from_u8(r.u8()?)?;
                let reason = String::from_utf8_lossy(&r.bytes()?).into_owned();
                Message::Reject { code, reason }
            }
            MsgKind::ShardJoin => {
                let token = r.bytes()?;
                let config_digest = r.u64()?;
                let requested_shard = r.u32()?;
                let build = String::from_utf8_lossy(&r.bytes()?).into_owned();
                Message::ShardJoin { token, config_digest, requested_shard, build }
            }
            MsgKind::ShardBegin => Message::ShardBegin {
                round: env.round,
                n_s: r.u32()?,
                seg_lo: r.u32()?,
                seg_hi: r.u32()?,
            },
            MsgKind::ShardAdd => {
                let slot = r.u32()?;
                let w = r.f64()?;
                let payload = shard_payload_decode(&mut r)?;
                Message::ShardAdd { slot, seg: env.segment, w, payload }
            }
            MsgKind::ShardClose => Message::ShardClose {
                now_round: env.round,
                beta: r.f64()?,
                dense_params: r.u64()?,
            },
            MsgKind::ShardReport => Message::ShardReport(Box::new(shard_report_decode(&mut r)?)),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;
    use crate::util::rng::Rng;

    fn random_message(rng: &mut Rng) -> Message {
        match rng.below(14) {
            0 => Message::Hello { worker: rng.below(64) as u32 },
            1 => {
                let n = rng.below(200);
                Message::TrainTask(TrainTask {
                    round: rng.below(1000) as u64,
                    slot: rng.below(16) as u32,
                    client: rng.below(100) as u32,
                    segment: rng.below(8) as u32,
                    n_s: rng.below(8) as u32 + 1,
                    l0: rng.normal(),
                    l_prev: rng.normal(),
                    rng_state: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
                    deadline_ms: rng.below(100_000) as u64,
                    down_seq: rng.below(1000) as u64,
                    down: match rng.below(4) {
                        0 => DownPayload::DenseF32((0..n).map(|_| rng.normal() as f32).collect()),
                        1 => DownPayload::SparseWire((0..n).map(|_| rng.below(256) as u8).collect()),
                        2 => DownPayload::DenseF16((0..n).map(|_| rng.below(256) as u8).collect()),
                        _ => DownPayload::FloraInit((0..n).map(|_| rng.normal() as f32).collect()),
                    },
                })
            }
            2 => {
                let n = rng.below(200);
                let round = rng.below(1000) as u64;
                Message::TrainResult(TrainResult {
                    round,
                    stale_from_round: round.saturating_sub(rng.below(3) as u64),
                    slot: rng.below(16) as u32,
                    client: rng.below(100) as u32,
                    segment: rng.below(8) as u32,
                    n_samples: rng.below(500) as u32 + 1,
                    mean_loss: rng.normal(),
                    k_a: rng.next_f64(),
                    k_b: rng.next_f64(),
                    exec_s: rng.next_f64(),
                    up: match rng.below(3) {
                        0 => UpPayload::SparseWire((0..n).map(|_| rng.below(256) as u8).collect()),
                        1 => UpPayload::DenseUpdate((0..n).map(|_| rng.normal() as f32).collect()),
                        _ => UpPayload::DenseModule((0..n).map(|_| rng.normal() as f32).collect()),
                    },
                })
            }
            3 => Message::BaseSync {
                base: (0..rng.below(300)).map(|_| rng.normal() as f32).collect(),
            },
            4 => Message::Shutdown,
            5 => Message::Error { text: format!("err-{}", rng.below(1000)) },
            6 => Message::Join {
                token: (0..rng.below(64)).map(|_| rng.below(256) as u8).collect(),
                config_digest: rng.next_u64(),
                requested_worker: if rng.below(4) == 0 {
                    ANY_WORKER
                } else {
                    rng.below(64) as u32
                },
                build: format!("0.{}.{}", rng.below(10), rng.below(10)),
            },
            7 => Message::Welcome {
                worker: rng.below(64) as u32,
                n_workers: rng.below(64) as u32 + 1,
                resume_round: rng.below(1000) as u64,
            },
            8 => Message::Reject {
                code: match rng.below(5) {
                    0 => RejectCode::BadToken,
                    1 => RejectCode::ConfigMismatch,
                    2 => RejectCode::DuplicateWorker,
                    3 => RejectCode::ClusterFull,
                    _ => RejectCode::Malformed,
                },
                reason: format!("reason-{}", rng.below(1000)),
            },
            9 => Message::ShardJoin {
                token: (0..rng.below(64)).map(|_| rng.below(256) as u8).collect(),
                config_digest: rng.next_u64(),
                requested_shard: if rng.below(4) == 0 {
                    ANY_SHARD
                } else {
                    rng.below(8) as u32
                },
                build: format!("0.{}.{}", rng.below(10), rng.below(10)),
            },
            10 => {
                let n_s = rng.below(16) as u32 + 1;
                let seg_lo = rng.below(n_s as usize) as u32;
                Message::ShardBegin {
                    round: rng.below(1000) as u64,
                    n_s,
                    seg_lo,
                    seg_hi: seg_lo + rng.below((n_s - seg_lo) as usize + 1) as u32,
                }
            }
            11 => {
                let n = rng.below(200);
                Message::ShardAdd {
                    slot: rng.below(16) as u32,
                    seg: rng.below(8) as u32,
                    w: rng.next_f64(),
                    payload: if rng.below(2) == 0 {
                        Payload::Wire((0..n).map(|_| rng.below(256) as u8).collect())
                    } else {
                        Payload::Dense((0..n).map(|_| rng.normal() as f32).collect())
                    },
                }
            }
            12 => Message::ShardClose {
                now_round: rng.below(1000) as u64,
                beta: rng.next_f64(),
                dense_params: rng.below(100_000) as u64,
            },
            _ => Message::ShardReport(Box::new(ShardReport {
                shard: rng.below(8),
                base: rng.below(10_000),
                delta: (0..rng.below(200)).map(|_| rng.normal() as f32).collect(),
                stats: AggStats {
                    up: CommTotals {
                        params: rng.below(1_000_000) as u64,
                        bytes: rng.below(1_000_000) as u64,
                    },
                    late_folds: rng.below(10),
                    orphaned: rng.below(10),
                    robust: RobustStats {
                        trimmed: rng.below(20) as u64,
                        clipped: rng.below(20) as u64,
                    },
                },
                folded: (0..rng.below(6))
                    .map(|_| (rng.below(100) as u64, rng.below(16) as u32))
                    .collect(),
                covered: (0..rng.below(8)).map(|_| rng.below(2) == 1).collect(),
                agg_s: rng.next_f64(),
                late_evicted: rng.below(4),
                digest: rng.next_u64(),
                error: if rng.below(4) == 0 {
                    Some(format!("poison-{}", rng.below(100)))
                } else {
                    None
                },
            })),
        }
    }

    #[test]
    fn message_roundtrip_property() {
        propcheck(300, |rng| {
            let msg = random_message(rng);
            let env = msg.to_envelope();
            let bytes = env.encode();
            let dec_env = Envelope::decode(&bytes).unwrap();
            assert_eq!(dec_env, env);
            let dec_msg = Message::from_envelope(&dec_env).unwrap();
            assert_eq!(dec_msg, msg);
        });
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        propcheck(60, |rng| {
            let bytes = random_message(rng).to_envelope().encode();
            // every strict prefix must fail to decode
            let step = (bytes.len() / 17).max(1);
            let mut cut = 0;
            while cut < bytes.len() {
                assert!(
                    Envelope::decode(&bytes[..cut]).is_err(),
                    "prefix of {cut}/{} decoded",
                    bytes.len()
                );
                cut += step;
            }
        });
    }

    #[test]
    fn single_corrupt_byte_rejected() {
        propcheck(200, |rng| {
            let env = random_message(rng).to_envelope();
            let bytes = env.encode();
            let pos = rng.below(bytes.len());
            let flip = (rng.below(255) + 1) as u8; // non-zero => byte changes
            let mut bad = bytes.clone();
            bad[pos] ^= flip;
            // either the envelope is rejected outright, or (for a corrupt
            // checksum colliding — impossible for 1 byte with FNV) never OK
            assert!(
                Envelope::decode(&bad).is_err(),
                "corrupt byte at {pos} accepted"
            );
        });
    }

    #[test]
    fn version_mismatch_is_a_distinct_error() {
        let env = Message::Shutdown.to_envelope();
        let mut bytes = env.encode();
        bytes[2] = PROTO_VERSION + 1;
        // rewrite a valid checksum so ONLY the version differs
        let c = super::fnv1a_parts(&bytes[0..4], &bytes[8..]);
        bytes[4..8].copy_from_slice(&c.to_le_bytes());
        let err = Envelope::decode(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("version mismatch"), "{msg}");
        assert!(msg.contains(&format!("got {}", PROTO_VERSION + 1)), "{msg}");
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = Message::Hello { worker: 3 }.to_envelope().encode();
        bytes.push(0);
        assert!(Envelope::decode(&bytes).is_err());
    }

    #[test]
    fn handshake_messages_roundtrip_exactly() {
        // the v3 handshake triple must survive the codec byte-for-byte,
        // including an empty token and the ANY_WORKER wildcard
        let msgs = [
            Message::Join {
                token: vec![],
                config_digest: 0xDEAD_BEEF_0123_4567,
                requested_worker: ANY_WORKER,
                build: String::new(),
            },
            Message::Join {
                token: b"s3cret".to_vec(),
                config_digest: 1,
                requested_worker: 3,
                build: "0.1.0".into(),
            },
            Message::Welcome { worker: 2, n_workers: 8, resume_round: 41 },
            Message::Reject { code: RejectCode::BadToken, reason: "auth token mismatch".into() },
            Message::Reject { code: RejectCode::ConfigMismatch, reason: String::new() },
        ];
        for msg in msgs {
            let env = msg.to_envelope();
            assert_eq!(env.round, 0, "handshake messages are round-less");
            let dec = Message::from_envelope(&Envelope::decode(&env.encode()).unwrap()).unwrap();
            assert_eq!(dec, msg);
        }
    }

    #[test]
    fn shard_messages_roundtrip_exactly() {
        // the shard-plane messages (v4, report extended in v5) must
        // survive the codec, with the
        // round/segment ids riding the HEADER (the router picks a
        // result's shard without decoding the body; replay tooling reads
        // rounds the same way)
        let report = ShardReport {
            shard: 1,
            base: 128,
            delta: vec![0.5, -1.25, 3.0],
            stats: AggStats {
                up: CommTotals { params: 4096, bytes: 1024 },
                late_folds: 2,
                orphaned: 1,
                robust: RobustStats { trimmed: 4, clipped: 2 },
            },
            folded: vec![(3, 7), (4, 0)],
            covered: vec![true, false, true],
            agg_s: 0.125,
            late_evicted: 1,
            digest: 0xABCD_EF01_2345_6789,
            error: Some("shard 1: slot 7 decode: bad stream".into()),
        };
        let msgs = [
            Message::ShardJoin {
                token: b"s3cret".to_vec(),
                config_digest: 42,
                requested_shard: ANY_SHARD,
                build: "0.1.0".into(),
            },
            Message::ShardBegin { round: 9, n_s: 8, seg_lo: 4, seg_hi: 8 },
            Message::ShardAdd {
                slot: 3,
                seg: 5,
                w: 2.5,
                payload: Payload::Wire(vec![1, 2, 3]),
            },
            Message::ShardAdd {
                slot: 0,
                seg: 0,
                w: 1.0,
                payload: Payload::Dense(vec![0.0, 1.0]),
            },
            Message::ShardClose { now_round: 9, beta: 0.7, dense_params: 4096 },
            Message::ShardReport(Box::new(report)),
        ];
        for msg in msgs {
            let env = msg.to_envelope();
            match &msg {
                Message::ShardBegin { round, .. } => assert_eq!(env.round, *round),
                Message::ShardAdd { seg, .. } => assert_eq!(env.segment, *seg),
                Message::ShardClose { now_round, .. } => assert_eq!(env.round, *now_round),
                _ => assert_eq!(env.round, 0),
            }
            let dec = Message::from_envelope(&Envelope::decode(&env.encode()).unwrap()).unwrap();
            assert_eq!(dec, msg);
        }
    }

    #[test]
    fn to_envelope_in_reuses_the_buffer_and_matches_to_envelope() {
        propcheck(60, |rng| {
            let msg = random_message(rng);
            // a dirty recycled buffer must not leak into the payload
            let dirty = vec![0xAAu8; rng.below(64)];
            let env_scratch = msg.to_envelope_in(dirty);
            assert_eq!(env_scratch, msg.to_envelope());
        });
    }

    #[test]
    fn unknown_reject_code_is_rejected() {
        let env = Message::Reject { code: RejectCode::ClusterFull, reason: "x".into() }
            .to_envelope();
        let mut payload = env.payload.clone();
        payload[0] = 99; // not a known RejectCode discriminant
        let bad = Envelope::new(MsgKind::Reject, 0, 0, 0, payload);
        let dec = Envelope::decode(&bad.encode()).unwrap();
        assert!(Message::from_envelope(&dec).is_err());
    }

    #[test]
    fn payload_trailing_bytes_rejected() {
        // a Shutdown with spurious payload must not silently parse
        let env = Envelope::new(MsgKind::Shutdown, 0, 0, 0, vec![1, 2, 3]);
        let dec = Envelope::decode(&env.encode()).unwrap();
        assert!(Message::from_envelope(&dec).is_err());
    }

    #[test]
    fn header_fields_survive_roundtrip() {
        let env = Envelope::new(MsgKind::TrainResult, 7, 3, 41, vec![9; 12]);
        let dec = Envelope::decode(&env.encode()).unwrap();
        assert_eq!(dec.round, 7);
        assert_eq!(dec.segment, 3);
        assert_eq!(dec.sample_count, 41);
        assert_eq!(dec.kind, MsgKind::TrainResult);
        assert_eq!(dec.round_deadline, 0, "Envelope::new defaults to no deadline");
        assert_eq!(dec.stale_from_round, 7, "Envelope::new defaults stale_from to round");
        assert_eq!(dec.payload, vec![9; 12]);
    }

    #[test]
    fn round_policy_header_fields_survive_roundtrip() {
        let env = Envelope {
            kind: MsgKind::TrainTask,
            round: 9,
            segment: 1,
            sample_count: 0,
            round_deadline: 2_500,
            stale_from_round: 8,
            payload: vec![0xAB; 8],
        };
        let dec = Envelope::decode(&env.encode()).unwrap();
        assert_eq!(dec, env);
        assert_eq!(dec.round_deadline, 2_500);
        assert_eq!(dec.stale_from_round, 8);
    }

    #[test]
    fn task_deadline_and_result_staleness_travel_in_the_header() {
        // deadline_ms rides the TrainTask header; stale_from_round rides
        // the TrainResult header — both must survive the typed roundtrip
        let task = TrainTask {
            round: 5,
            slot: 2,
            client: 17,
            segment: 1,
            n_s: 3,
            l0: 2.0,
            l_prev: 1.5,
            rng_state: [1, 2, 3, 4],
            deadline_ms: 750,
            down_seq: 0,
            down: DownPayload::DenseF32(vec![0.5; 16]),
        };
        let env = Message::TrainTask(task.clone()).to_envelope();
        assert_eq!(env.round_deadline, 750);
        assert_eq!(env.stale_from_round, 5);
        match Message::from_envelope(&Envelope::decode(&env.encode()).unwrap()).unwrap() {
            Message::TrainTask(t) => assert_eq!(t, task),
            other => panic!("expected TrainTask, got {:?}", other.kind()),
        }

        let res = TrainResult {
            round: 6,
            slot: 2,
            client: 17,
            segment: 1,
            n_samples: 12,
            mean_loss: 1.25,
            k_a: 0.5,
            k_b: 0.25,
            exec_s: 0.01,
            stale_from_round: 5,
            up: UpPayload::DenseUpdate(vec![0.0; 16]),
        };
        let env = Message::TrainResult(res.clone()).to_envelope();
        assert_eq!(env.stale_from_round, 5);
        match Message::from_envelope(&Envelope::decode(&env.encode()).unwrap()).unwrap() {
            Message::TrainResult(r) => assert_eq!(r, res),
            other => panic!("expected TrainResult, got {:?}", other.kind()),
        }
    }
}
