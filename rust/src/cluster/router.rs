//! The router in front of the sharded aggregation plane.
//!
//! Uplink results are dispatched to shards by the segment id the v2
//! envelope header already carries (`protocol::Envelope::segment`):
//! the segment space `[0, n_s)` is partitioned into `shards` contiguous,
//! near-equal slices ([`ShardMap`]), one shard worker thread each. During
//! the collect phase the router forwards payloads as they arrive —
//! shards decode concurrently with the control plane's wait — and at
//! round close it gathers every shard's delta slice back into one
//! global-length delta plus merged tallies ([`GatheredAgg`]).
//!
//! The router never touches the model math: order-sensitive aggregation
//! lives entirely inside each shard (slot order within a segment), so
//! gather order only affects commutative bookkeeping.

use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{bail, ensure, Result};

use crate::compress::KindIndex;

use super::protocol::TrainResult;
use super::shard::{run_shard, AggStats, Payload, ShardMsg, ShardReport};

/// Contiguous near-equal partition of the segment space `[0, n_s)` into
/// `shards` slices (the remainder spread over the first slices, same rule
/// as `model::segment_ranges`). Slices may be empty when `shards > n_s`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    n_s: usize,
    shards: usize,
}

impl ShardMap {
    /// Partition `n_s` segments across `shards` aggregators.
    pub fn new(n_s: usize, shards: usize) -> ShardMap {
        assert!(n_s >= 1 && shards >= 1, "shard map needs n_s >= 1 and shards >= 1");
        ShardMap { n_s, shards }
    }

    /// Shard count (including empty shards).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Segment count being partitioned.
    pub fn n_segments(&self) -> usize {
        self.n_s
    }

    /// Global segment range `[lo, hi)` owned by `shard`.
    pub fn range(&self, shard: usize) -> (usize, usize) {
        assert!(shard < self.shards);
        let base = self.n_s / self.shards;
        let rem = self.n_s % self.shards;
        let lo = shard * base + shard.min(rem);
        let hi = lo + base + usize::from(shard < rem);
        (lo, hi)
    }

    /// The shard owning global segment `seg`. Out-of-range segments
    /// (possible on malformed or stale late uplinks) map to shard 0,
    /// whose fold will orphan them — deterministic, never a panic.
    pub fn shard_of(&self, seg: usize) -> usize {
        if seg >= self.n_s {
            return 0;
        }
        let base = self.n_s / self.shards;
        let rem = self.n_s % self.shards;
        let fat = rem * (base + 1); // segments living on the (base+1)-sized shards
        if seg < fat {
            seg / (base + 1)
        } else {
            rem + (seg - fat) / base
        }
    }
}

/// One on-time contribution the control plane accepted and wants routed
/// (produced by `control::ControlPlane::accept`).
#[derive(Debug)]
pub struct RoutedAdd {
    /// Cohort slot (per-segment accumulation order key).
    pub slot: u32,
    /// Global round-robin segment id (from the v2 envelope header).
    pub segment: usize,
    /// FedAvg weight n_i.
    pub weight: f64,
    /// The uplink payload body.
    pub payload: Payload,
}

/// Everything the aggregation plane hands the control plane at round
/// close: the global delta plus merged tallies and plane telemetry.
pub struct GatheredAgg {
    /// Global-length weighted-average delta (Eq. 2), zeros where no
    /// segment contribution landed.
    pub delta: Vec<f32>,
    /// Merged per-shard tallies (comm accounting, folds, orphans).
    pub stats: AggStats,
    /// (origin round, slot) identities that late-folded this round.
    pub folded: Vec<(u64, u32)>,
    /// Per global segment: did it receive at least one contribution?
    pub covered: Vec<bool>,
    /// Max wall seconds any one shard spent decoding + accumulating.
    pub shard_agg_s_max: f64,
    /// Max router→shard queue backlog observed during the round.
    pub queue_max: usize,
    /// Late arrivals evicted by the per-shard byte-cap backstop this
    /// round (the control plane's global meter adds its own count).
    pub late_evicted: usize,
    /// Shard count that produced this aggregate.
    pub shards: usize,
    /// Per-shard delta digest in shard-id order (`ShardReport::digest`)
    /// — journaled at round close, verified by `serve --resume` replay.
    pub shard_digests: Vec<u64>,
}

/// Router + shard-thread pool. One per cluster run; geometry can change
/// per round (it never does in practice — `n_s` is fixed by the config —
/// but the contract allows it).
pub struct Router {
    map: ShardMap,
    txs: Vec<mpsc::Sender<ShardMsg>>,
    reports_rx: mpsc::Receiver<ShardReport>,
    handles: Vec<JoinHandle<()>>,
    depth: Arc<AtomicIsize>,
    queue_max: usize,
    total: usize,
    beta: f64,
    dense_params: usize,
}

impl Router {
    /// Spawn `shards` shard worker threads over a `total`-parameter
    /// vector. `weights` are the per-client FedAvg weights (late-fold
    /// input), `beta` the Eq. 3 staleness decay, `dense_params` the
    /// dense-uplink parameter charge.
    pub fn new(
        total: usize,
        shards: usize,
        weights: Arc<Vec<f64>>,
        kidx: Arc<KindIndex>,
        beta: f64,
        dense_params: usize,
    ) -> Result<Router> {
        ensure!(shards >= 1, "router needs at least one shard");
        let depth = Arc::new(AtomicIsize::new(0));
        let (reports_tx, reports_rx) = mpsc::channel();
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for id in 0..shards {
            let (tx, rx) = mpsc::channel();
            let (w, k, rep, d) =
                (weights.clone(), kidx.clone(), reports_tx.clone(), depth.clone());
            let handle = std::thread::Builder::new()
                .name(format!("ecolora-shard-{id}"))
                .spawn(move || run_shard(id, total, w, k, rx, rep, d))?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(Router {
            map: ShardMap::new(1, shards),
            txs,
            reports_rx,
            handles,
            depth,
            queue_max: 0,
            total,
            beta,
            dense_params,
        })
    }

    /// Shard count this router fans out to.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Open round `t` with `n_s` round-robin segments: rebuild the shard
    /// map and tell every shard which slice it owns.
    pub fn begin_round(&mut self, t: u64, n_s: usize) -> Result<()> {
        self.map = ShardMap::new(n_s.max(1), self.txs.len());
        self.queue_max = 0;
        for (shard, tx) in self.txs.iter().enumerate() {
            let (seg_lo, seg_hi) = self.map.range(shard);
            if tx.send(ShardMsg::Begin { round: t, n_s: self.map.n_segments(), seg_lo, seg_hi }).is_err()
            {
                bail!("shard {shard} died before round {t}");
            }
        }
        Ok(())
    }

    fn bump_depth(&mut self) {
        let now = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.queue_max = self.queue_max.max(now.max(0) as usize);
    }

    /// Forward one accepted on-time contribution to its owning shard.
    pub fn route(&mut self, add: RoutedAdd) -> Result<()> {
        let shard = self.map.shard_of(add.segment);
        self.bump_depth();
        if self.txs[shard]
            .send(ShardMsg::Add {
                slot: add.slot,
                seg: add.segment,
                w: add.weight,
                payload: add.payload,
            })
            .is_err()
        {
            bail!("shard {shard} died mid-round");
        }
        Ok(())
    }

    /// Forward one straggler from an earlier round to the shard owning
    /// its segment (under the CURRENT map; `n_s` is fixed in practice).
    pub fn route_late(&mut self, res: TrainResult) -> Result<()> {
        let shard = self.map.shard_of(res.segment as usize);
        self.bump_depth();
        if self.txs[shard].send(ShardMsg::Late(Box::new(res))).is_err() {
            bail!("shard {shard} died mid-round");
        }
        Ok(())
    }

    /// Close round `t`: every shard folds in slot order, late-folds its
    /// straggler slice, and reports; the router scatters the shard deltas
    /// into one global vector and merges the tallies. Fails loudly if any
    /// shard poisoned the round (decode error, geometry mismatch).
    pub fn close_round(&mut self, t: u64) -> Result<GatheredAgg> {
        for (shard, tx) in self.txs.iter().enumerate() {
            let msg = ShardMsg::Close {
                beta: self.beta,
                now_round: t,
                dense_params: self.dense_params,
            };
            if tx.send(msg).is_err() {
                bail!("shard {shard} died before close of round {t}");
            }
        }
        let mut reports: Vec<Option<ShardReport>> = (0..self.txs.len()).map(|_| None).collect();
        for _ in 0..self.txs.len() {
            let rep = self
                .reports_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("aggregation plane died during round {t} close"))?;
            let id = rep.shard;
            ensure!(id < reports.len() && reports[id].is_none(), "duplicate report from shard {id}");
            reports[id] = Some(rep);
        }

        let mut out = GatheredAgg {
            delta: vec![0.0f32; self.total],
            stats: AggStats::default(),
            folded: Vec::new(),
            covered: Vec::new(),
            shard_agg_s_max: 0.0,
            queue_max: self.queue_max,
            late_evicted: 0,
            shards: self.txs.len(),
            shard_digests: Vec::with_capacity(self.txs.len()),
        };
        // gather in shard-id order: deltas scatter to disjoint spans and
        // the tallies are commutative, so this order is cosmetic
        for rep in reports.into_iter().map(|r| r.expect("filled above")) {
            if let Some(e) = rep.error {
                bail!("round {t}: {e}");
            }
            out.delta[rep.base..rep.base + rep.delta.len()].copy_from_slice(&rep.delta);
            out.stats.merge(&rep.stats);
            out.folded.extend(rep.folded);
            out.covered.extend(rep.covered);
            out.shard_agg_s_max = out.shard_agg_s_max.max(rep.agg_s);
            out.late_evicted += rep.late_evicted;
            out.shard_digests.push(rep.digest);
        }
        Ok(out)
    }

    /// Orderly end of run: stop every shard thread and join it.
    pub fn shutdown(self) -> Result<()> {
        for tx in &self.txs {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        drop(self.txs);
        for (id, h) in self.handles.into_iter().enumerate() {
            if h.join().is_err() {
                bail!("shard thread {id} panicked");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn shard_map_partitions_exactly() {
        // property: for random (n_s, shards) every segment is owned by
        // exactly one shard, ranges are contiguous, and shard_of agrees
        // with range()
        propcheck(300, |rng| {
            let n_s = rng.below(40) + 1;
            let shards = rng.below(12) + 1;
            let map = ShardMap::new(n_s, shards);
            let mut owner = vec![usize::MAX; n_s];
            let mut expect_lo = 0usize;
            for s in 0..shards {
                let (lo, hi) = map.range(s);
                assert_eq!(lo, expect_lo, "no gap/overlap between shards");
                assert!(hi >= lo && hi <= n_s);
                for seg in lo..hi {
                    assert_eq!(owner[seg], usize::MAX, "segment {seg} owned twice");
                    owner[seg] = s;
                    assert_eq!(map.shard_of(seg), s, "shard_of disagrees with range");
                }
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n_s, "every segment owned");
            assert!(owner.iter().all(|&o| o != usize::MAX));
            // near-equal: sizes differ by at most one
            let sizes: Vec<usize> = (0..shards).map(|s| {
                let (lo, hi) = map.range(s);
                hi - lo
            }).collect();
            let min = sizes.iter().min().unwrap();
            let max = sizes.iter().max().unwrap();
            assert!(max - min <= 1, "near-equal shard sizes: {sizes:?}");
        });
    }

    #[test]
    fn out_of_range_segment_routes_to_shard_zero() {
        let map = ShardMap::new(4, 2);
        assert_eq!(map.shard_of(9), 0);
    }

    #[test]
    fn more_shards_than_segments_leaves_trailing_shards_empty() {
        let map = ShardMap::new(2, 5);
        assert_eq!(map.range(0), (0, 1));
        assert_eq!(map.range(1), (1, 2));
        for s in 2..5 {
            let (lo, hi) = map.range(s);
            assert_eq!(lo, hi, "shard {s} must own nothing");
        }
        assert_eq!(map.shard_of(0), 0);
        assert_eq!(map.shard_of(1), 1);
    }

    #[test]
    fn single_shard_owns_everything() {
        let map = ShardMap::new(7, 1);
        assert_eq!(map.range(0), (0, 7));
        for seg in 0..7 {
            assert_eq!(map.shard_of(seg), 0);
        }
    }
}
